"""Distribution-layer tests: sharding rules, ZeRO-1, compressed all-reduce."""
import numpy as np
import pytest


def test_sharding_rules_and_fallback(subproc):
    out = subproc("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import ShardingRules, tree_shardings, zero1_shardings
    from repro.dist.sharding import TRAIN_OVERRIDES

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    rules = ShardingRules(mesh)
    # heads divisible by model -> sharded
    assert rules.spec_for(('embed', 'heads'), (64, 32), 'wq') == P(None, 'model')
    # 3 heads not divisible by 4 -> replicated + fallback recorded
    assert rules.spec_for(('embed', 'heads'), (64, 3), 'wq3') == P(None, None)
    assert any(p == 'wq3' for p, _, _ in rules.fallbacks)
    # batch over (pod,data): pod absent -> data only
    assert rules.spec_for(('batch', 'seq'), (8, 16), 'tok') == P('data', None)
    # train profile: FSDP on embed
    tr = rules.with_overrides(**TRAIN_OVERRIDES)
    assert tr.spec_for(('embed', 'mlp'), (64, 128), 'wi') == P('data', 'model')
    # same mesh axis never used twice in one spec
    assert tr.spec_for(('mlp', 'mlp'), (128, 128), 'ww') == P('model', None)
    print('OK')
    """, n_devices=8)
    assert "OK" in out


def test_zero1_adds_shard_on_free_dim(subproc):
    out = subproc("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import ShardingRules, zero1_shardings
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    rules = ShardingRules(mesh)
    specs = {'w': jax.ShapeDtypeStruct((64, 128), 'float32')}
    axes = {'w': ('embed', 'mlp')}
    sh = zero1_shardings(rules, specs, axes, zero_axes=('data',))
    assert sh['w'].spec == P('data', 'model'), sh['w'].spec
    # when embed already took data (train profile) -> no double use
    rules2 = ShardingRules(mesh, dict(rules.rules, embed=('data',)))
    sh2 = zero1_shardings(rules2, specs, axes, zero_axes=('data',))
    assert sh2['w'].spec == P('data', 'model')
    print('OK')
    """, n_devices=8)
    assert "OK" in out


def test_compressed_allreduce_matches_mean(subproc):
    out = subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist import make_compressed_allreduce
    mesh = jax.make_mesh((4,), ('data',))
    tr = make_compressed_allreduce(mesh, 'data')

    # per-device distinct values, replicated container: emulate by shard_map
    # over a [4, n] array where row i is device i's local gradient
    from jax.experimental.shard_map import shard_map
    rng = np.random.default_rng(0)
    local = rng.normal(size=(4, 1000)).astype(np.float32)
    want = local.mean(0)

    def per_device(v):  # v: this device's row [1, n] -> replicated mean
        from repro.dist.grad_compress import _compressed_psum_flat
        return _compressed_psum_flat(v[0], 'data', 4)[None]

    got = shard_map(per_device, mesh=mesh, in_specs=P('data'), out_specs=P('data'),
                    check_rep=False)(jnp.asarray(local))
    got = np.asarray(got)
    # every device row holds the same reduced result
    for i in range(4):
        np.testing.assert_allclose(got[i], got[0], atol=1e-6)
    # int8 two-phase quantization error is bounded (~1% of range)
    err = np.abs(got[0] - want).max()
    rng_ = np.abs(want).max()
    assert err < 0.05 * rng_ + 0.05, (err, rng_)
    print('ERR', err, 'OK')
    """, n_devices=8)
    assert "OK" in out


def test_error_feedback_converges(subproc):
    """With error feedback, repeated compressed reductions of the SAME
    gradient converge to the true value (residual correction)."""
    out = subproc("""
    import jax.numpy as jnp, numpy as np
    from repro.dist import ErrorFeedback
    g = {'w': jnp.asarray(np.random.default_rng(1).normal(size=512).astype(np.float32))}
    res = ErrorFeedback.init(g)
    acc = jnp.zeros(512)
    n = 30
    for _ in range(n):
        sent, res = ErrorFeedback.apply(g, res)
        acc = acc + sent['w']
    # average of sent == true gradient despite int8 rounding each round
    err = float(jnp.max(jnp.abs(acc / n - g['w'])))
    assert err < 2e-3, err
    print('OK', err)
    """, n_devices=4)
    assert "OK" in out


def test_cache_axes_shapes():
    import jax
    from repro.configs import get_smoke_config
    from repro.dist import cache_axes
    from repro.models import lm
    for arch in ("llama3.2-1b", "mamba2-370m", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        cache = lm.cache_specs(cfg, batch=2, max_seq=16)
        axes = cache_axes(cache)
        assert len(axes) == len(cache)
        flat_c = jax.tree.leaves(cache)
        # axes leaves are tuples of axis names; NamedTuple states must still
        # be descended into, so only stop at pure name tuples
        is_ax = lambda x: (isinstance(x, tuple) and not hasattr(x, "_fields")
                           and all(e is None or isinstance(e, str) for e in x))
        flat_a = jax.tree.leaves(axes, is_leaf=is_ax)
        assert len(flat_c) == len(flat_a)
        for c, a in zip(flat_c, flat_a):
            assert len(a) == len(c.shape)
