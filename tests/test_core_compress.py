import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress, decompress, is_compressible
from repro.core.compress import delta_axes, delta_specs
from repro.models import lm
from repro.utils import flatten_with_paths


@pytest.fixture(scope="module")
def two_models():
    cfg = get_smoke_config("wizard-llama2-7b")
    base = lm.init_params(cfg, jax.random.PRNGKey(0))
    # fine-tuned = base + small perturbation
    ft = jax.tree.map(
        lambda p: p + 0.02 * jax.random.normal(jax.random.PRNGKey(1), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    return cfg, base, ft


def test_compress_tree_and_report(two_models):
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=4, h_g=32)
    deltas, report = compress(base, ft, spec)
    assert report.n_compressed > 0
    # paper convention ratio should be close to the spec target
    assert report.ratio_paper == pytest.approx(spec.ratio(), rel=0.05)
    # honest ratio includes indices, must be lower
    assert report.ratio_honest < report.ratio_paper
    flat = flatten_with_paths(deltas)
    # embeddings / norms never compressed
    assert all(v is None for k, v in flat.items() if "embed" in k or "ln" in k)


def test_decompress_is_base_plus_dense_delta(two_models):
    """decompress == base + reconstruct_dense(delta), leaf by leaf. (Note:
    random-rescaled deltas are NOT closer to ft in l2 for alpha>=2 — the
    method preserves function, not weights; see test_system.py.)"""
    from repro.core import reconstruct_dense
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=8, m=1, h_g=64)
    deltas, _ = compress(base, ft, spec)
    approx = decompress(base, deltas)
    from repro.core import PackedDelta
    fb = flatten_with_paths(base)
    fa = flatten_with_paths(approx)
    fd = flatten_with_paths(deltas, is_leaf=lambda x: isinstance(x, PackedDelta))
    for k, d in fd.items():
        if d is None:
            np.testing.assert_array_equal(np.asarray(fa[k], np.float32),
                                          np.asarray(fb[k], np.float32))
        else:
            pass  # covered by separate-computation equivalence below
    # at least one compressed leaf moved
    moved = [k for k in fd if fd[k] is not None and
             np.abs(np.asarray(fa[k], np.float32) - np.asarray(fb[k], np.float32)).max() > 0]
    assert moved


def test_forward_with_deltas_matches_merged(two_models):
    """Separate computation == merged weights, numerically."""
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=2.0, k_bits=8, m=1, h_g=64)
    deltas, _ = compress(base, ft, spec)
    merged = decompress(base, deltas)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)}
    out_sep = lm.forward(cfg, base, batch, deltas=deltas)
    out_merged = lm.forward(cfg, merged, batch)
    np.testing.assert_allclose(np.asarray(out_sep), np.asarray(out_merged),
                               atol=0.15, rtol=0.05)


def test_delta_specs_match_real_compression(two_models):
    """Dry-run SDS twins must structurally match actual compressed deltas."""
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=8, h_g=32)
    real, _ = compress(base, ft, spec)
    specs = delta_specs(lm.param_specs(cfg), spec)
    t1 = jax.tree.structure(real)
    t2 = jax.tree.structure(specs)
    assert t1 == t2
    for a, b in zip(jax.tree.leaves(real), jax.tree.leaves(specs)):
        assert a.shape == b.shape, (a.shape, b.shape)
        assert a.dtype == b.dtype


def test_delta_axes_yield_shardings(two_models):
    """delta_axes must pair with delta_specs under the sharding mapper and
    produce a NamedSharding for every array leaf (1x1 mesh suffices)."""
    from repro.dist import ShardingRules, tree_shardings
    cfg, *_ = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=8, h_g=32)
    p_specs = lm.param_specs(cfg)
    specs = delta_specs(p_specs, spec)
    axes = delta_axes(p_specs, lm.param_axes(cfg), spec, model_axis_size=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = tree_shardings(ShardingRules(mesh), specs, axes)
    n_arrays = len(jax.tree.leaves(specs))
    n_shard = len([s for s in jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
        if isinstance(x := s, jax.sharding.NamedSharding)])
    assert n_arrays > 0 and n_shard == n_arrays


def test_is_compressible_rules():
    sds = jax.ShapeDtypeStruct((128, 64), jnp.bfloat16)
    assert is_compressible("attn/wq", sds)
    assert not is_compressible("embed/tok", sds)
    assert not is_compressible("moe/router", sds)
    assert not is_compressible("attn/ln1", jax.ShapeDtypeStruct((128,), jnp.float32))
