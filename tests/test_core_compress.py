import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress, decompress, is_compressible
from repro.core.compress import _pick_hg, delta_axes, delta_leaf_spec, delta_specs
from repro.models import lm
from repro.utils import flatten_with_paths


@pytest.fixture(scope="module")
def two_models():
    cfg = get_smoke_config("wizard-llama2-7b")
    base = lm.init_params(cfg, jax.random.PRNGKey(0))
    # fine-tuned = base + small perturbation
    ft = jax.tree.map(
        lambda p: p + 0.02 * jax.random.normal(jax.random.PRNGKey(1), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    return cfg, base, ft


def test_compress_tree_and_report(two_models):
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=4, h_g=32)
    deltas, report = compress(base, ft, spec)
    assert report.n_compressed > 0
    # paper convention ratio should be close to the spec target
    assert report.ratio_paper == pytest.approx(spec.ratio(), rel=0.05)
    # honest ratio includes indices, must be lower
    assert report.ratio_honest < report.ratio_paper
    flat = flatten_with_paths(deltas)
    # embeddings / norms never compressed
    assert all(v is None for k, v in flat.items() if "embed" in k or "ln" in k)


def test_decompress_is_base_plus_dense_delta(two_models):
    """decompress == base + reconstruct_dense(delta), leaf by leaf. (Note:
    random-rescaled deltas are NOT closer to ft in l2 for alpha>=2 — the
    method preserves function, not weights; see test_system.py.)"""
    from repro.core import reconstruct_dense
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=8, m=1, h_g=64)
    deltas, _ = compress(base, ft, spec)
    approx = decompress(base, deltas)
    from repro.core import PackedDelta
    fb = flatten_with_paths(base)
    fa = flatten_with_paths(approx)
    fd = flatten_with_paths(deltas, is_leaf=lambda x: isinstance(x, PackedDelta))
    for k, d in fd.items():
        if d is None:
            np.testing.assert_array_equal(np.asarray(fa[k], np.float32),
                                          np.asarray(fb[k], np.float32))
        else:
            pass  # covered by separate-computation equivalence below
    # at least one compressed leaf moved
    moved = [k for k in fd if fd[k] is not None and
             np.abs(np.asarray(fa[k], np.float32) - np.asarray(fb[k], np.float32)).max() > 0]
    assert moved


def test_forward_with_deltas_matches_merged(two_models):
    """Separate computation == merged weights, numerically."""
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=2.0, k_bits=8, m=1, h_g=64)
    deltas, _ = compress(base, ft, spec)
    merged = decompress(base, deltas)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)}
    out_sep = lm.forward(cfg, base, batch, deltas=deltas)
    out_merged = lm.forward(cfg, merged, batch)
    np.testing.assert_allclose(np.asarray(out_sep), np.asarray(out_merged),
                               atol=0.15, rtol=0.05)


def test_delta_specs_match_real_compression(two_models):
    """Dry-run SDS twins must structurally match actual compressed deltas."""
    cfg, base, ft = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=8, h_g=32)
    real, _ = compress(base, ft, spec)
    specs = delta_specs(lm.param_specs(cfg), spec)
    t1 = jax.tree.structure(real)
    t2 = jax.tree.structure(specs)
    assert t1 == t2
    for a, b in zip(jax.tree.leaves(real), jax.tree.leaves(specs)):
        assert a.shape == b.shape, (a.shape, b.shape)
        assert a.dtype == b.dtype


def test_delta_axes_yield_shardings(two_models):
    """delta_axes must pair with delta_specs under the sharding mapper and
    produce a NamedSharding for every array leaf (1x1 mesh suffices)."""
    from repro.dist import ShardingRules, tree_shardings
    cfg, *_ = two_models
    spec = DeltaDQSpec(alpha=4.0, k_bits=4, m=8, h_g=32)
    p_specs = lm.param_specs(cfg)
    specs = delta_specs(p_specs, spec)
    axes = delta_axes(p_specs, lm.param_axes(cfg), spec, model_axis_size=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = tree_shardings(ShardingRules(mesh), specs, axes)
    n_arrays = len(jax.tree.leaves(specs))
    n_shard = len([s for s in jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
        if isinstance(x := s, jax.sharding.NamedSharding)])
    assert n_arrays > 0 and n_shard == n_arrays


# ---------------------------------------------------------------------------
# Determinism + shape-spec consistency (the seeding/_pick_hg/keep satellites)
# ---------------------------------------------------------------------------
_DIGEST_SCRIPT = """
import hashlib
import jax, jax.numpy as jnp
import numpy as np
from repro.core import DeltaDQSpec, compress

k = jax.random.PRNGKey(3)
base = {"attn": {"wq": jax.random.normal(jax.random.fold_in(k, 0), (32, 16)),
                 "wo": jax.random.normal(jax.random.fold_in(k, 1), (32, 16))},
        "mlp": {"wi": jax.random.normal(jax.random.fold_in(k, 2), (32, 24))}}
ft = jax.tree.map(lambda p: p + 0.01, base)
deltas, _ = compress(base, ft, DeltaDQSpec(alpha=4.0, k_bits=4, m=2, h_g=16))
h = hashlib.sha256()
for leaf in jax.tree.leaves(deltas):
    h.update(np.asarray(leaf).tobytes())
print("DIGEST:" + h.hexdigest())
"""


def test_compress_bit_identical_across_hash_seeds():
    """Regression for the hash(path) leaf seeding: the same (base, ft)
    pair must produce bit-identical packed deltas in two processes with
    different PYTHONHASHSEED (str hash randomization must not reach the
    per-leaf dropout RNG)."""
    digests = []
    for seed in ("0", "42"):
        env = dict(os.environ, PYTHONHASHSEED=seed, JAX_PLATFORMS="cpu")
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run([sys.executable, "-c", _DIGEST_SCRIPT],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr
        digests.append([l for l in out.stdout.splitlines()
                        if l.startswith("DIGEST:")][0])
    assert digests[0] == digests[1], digests


def test_pick_hg_unsatisfiable_raises_clear_error():
    """h_g below alpha can never be satisfied by halving — the error must
    say so up front and name h_in, h_g and alpha (regression: the old
    loop walked hg to < 1 and raised a misleading divisibility error)."""
    with pytest.raises(ValueError, match=r"h_g=8.*alpha=16"):
        _pick_hg(64, DeltaDQSpec(alpha=16.0, h_g=8))
    # satisfiable at the start but every dividing halving lands < alpha
    with pytest.raises(ValueError) as ei:
        _pick_hg(24, DeltaDQSpec(alpha=16.0, h_g=16))
    msg = str(ei.value)
    assert "h_in=24" in msg and "h_g=16" in msg and "alpha=16" in msg
    # sanity: the happy paths still resolve
    assert _pick_hg(64, DeltaDQSpec(alpha=8.0, h_g=16)) == 16
    assert _pick_hg(48, DeltaDQSpec(alpha=8.0, h_g=32)) == 16


@pytest.mark.parametrize("h_in,h_g,alpha", [
    (96, 24, 5.0),    # keep = round(24/5) = 5 — rounds, doesn't floor
    (96, 48, 9.0),    # keep = round(48/9) = 5
    (64, 16, 3.0),    # keep = round(16/3) = 5
    (64, 16, 6.0),    # keep = round(16/6) = 3
    (80, 40, 7.0),    # keep = round(40/7) = 6
])
def test_delta_leaf_spec_matches_real_packing(h_in, h_g, alpha):
    """Shape-only dry-run twins and real packing derive `keep` from ONE
    helper (dropout.keep_count): sweep awkward h_g/alpha combos and
    assert the spec's shapes match what packing actually produces."""
    from repro.core import groupwise_dropout_pack

    h_out = 16
    spec = DeltaDQSpec(alpha=alpha, k_bits=4, m=2, h_g=h_g)
    sds = jax.ShapeDtypeStruct((h_in, h_out), jnp.bfloat16)
    twin = delta_leaf_spec(sds, spec)
    delta = jax.random.normal(jax.random.PRNGKey(0), (h_in, h_out)) * 0.01
    real = groupwise_dropout_pack(jax.random.PRNGKey(1), delta,
                                  h_g=twin.h_g, alpha=alpha, k_bits=4, m=2)
    assert twin.keep == real.keep
    assert twin.idx.shape == real.idx.shape
    assert twin.codes.shape == real.codes.shape and \
        twin.codes.dtype == real.codes.dtype


def test_is_compressible_rules():
    sds = jax.ShapeDtypeStruct((128, 64), jnp.bfloat16)
    assert is_compressible("attn/wq", sds)
    assert not is_compressible("embed/tok", sds)
    assert not is_compressible("moe/router", sds)
    assert not is_compressible("attn/ln1", jax.ShapeDtypeStruct((128,), jnp.float32))


# ---------------------------------------------------------------------------
# Multi-codec compression (the DeltaCodec interface satellites)
# ---------------------------------------------------------------------------
from repro.core.codecs import BitDeltaSpec, LowRankSpec  # noqa: E402

CODEC_SPECS = {
    "deltadq": DeltaDQSpec(alpha=8.0, k_bits=4, m=2, h_g=16),
    "bitdelta": BitDeltaSpec(),
    "lowrank": LowRankSpec(rank=4, k_bits=4),
}


def test_deltadq_spec_importable_from_old_paths():
    """Back-compat: DeltaDQSpec moved to codecs.py but stays importable
    from compress (this module's import above) and the package root."""
    import importlib
    import repro.core
    from repro.core import codecs as codecs_mod
    compress_mod = importlib.import_module("repro.core.compress")
    assert compress_mod.DeltaDQSpec is codecs_mod.DeltaDQSpec
    assert repro.core.DeltaDQSpec is codecs_mod.DeltaDQSpec


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_compress_accepts_any_codec_spec(two_models, name):
    cfg, base, ft = two_models
    deltas, report = compress(base, ft, CODEC_SPECS[name])
    assert report.n_compressed > 0
    assert set(report.per_codec) == {name}
    assert set(report.leaf_codecs.values()) == {name}
    assert report.ratio_honest > 1.0
    # every compressed leaf reconstructs to the base weight's shape
    from repro.core import reconstruct_dense_any
    from repro.core.codecs import is_codec_leaf
    from repro.utils import flatten_with_paths
    fb = flatten_with_paths(base)
    fd = flatten_with_paths(deltas, is_leaf=is_codec_leaf)
    for k, d in fd.items():
        if d is not None:
            assert reconstruct_dense_any(d).shape == fb[k].shape


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_compress_by_codec_name_uses_default_spec(two_models, name):
    cfg, base, ft = two_models
    from repro.core.codecs import get_codec
    deltas, report = compress(base, ft, codec=name)
    assert report.spec == get_codec(name).default_spec()
    assert set(report.per_codec) == {name}


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_delta_specs_match_real_compression_all_codecs(two_models, name):
    """Dry-run twins structurally match actual compression for EVERY
    registered codec, not just DeltaDQ."""
    cfg, base, ft = two_models
    spec = CODEC_SPECS[name]
    real, _ = compress(base, ft, spec)
    specs = delta_specs(lm.param_specs(cfg), spec)
    assert jax.tree.structure(real) == jax.tree.structure(specs)
    for a, b in zip(jax.tree.leaves(real), jax.tree.leaves(specs)):
        assert a.shape == b.shape, (name, a.shape, b.shape)
        assert a.dtype == b.dtype


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_delta_axes_yield_shardings_all_codecs(two_models, name):
    from repro.dist import ShardingRules, tree_shardings
    cfg, *_ = two_models
    spec = CODEC_SPECS[name]
    p_specs = lm.param_specs(cfg)
    specs = delta_specs(p_specs, spec)
    axes = delta_axes(p_specs, lm.param_axes(cfg), spec, model_axis_size=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = tree_shardings(ShardingRules(mesh), specs, axes)
    n_arrays = len(jax.tree.leaves(specs))
    n_shard = len([s for s in jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
        if isinstance(s, jax.sharding.NamedSharding)])
    assert n_arrays > 0 and n_shard == n_arrays


def test_bitdelta_report_bits_hand_computed():
    """CompressionReport delegates to codec storage_bits: check BitDelta's
    accounting against bytes computed by hand from the leaf shapes."""
    k = jax.random.PRNGKey(5)
    base = {"attn": {"wq": jax.random.normal(jax.random.fold_in(k, 0), (32, 16)),
                     "wo": jax.random.normal(jax.random.fold_in(k, 1), (64, 16))},
            "mlp": {"wi": jax.random.normal(jax.random.fold_in(k, 2), (32, 24))}}
    ft = jax.tree.map(lambda p: p + 0.01, base)
    deltas, report = compress(base, ft, BitDeltaSpec())
    assert report.n_compressed == 3
    # per leaf: 1 bit/element sign bitmap + one f32 scale
    value = 32 * 16 + 64 * 16 + 32 * 24          # bits (1 per element)
    total = value + 3 * 32                       # + one f32 scale per leaf
    dense = 16 * value                           # bf16 dense delta
    assert report.packed_value_bits == pytest.approx(value)
    assert report.packed_total_bits == pytest.approx(total)
    assert report.dense_delta_bits == pytest.approx(dense)
    pc = report.per_codec["bitdelta"]
    assert pc["n_leaves"] == 3
    assert pc["total_bits"] == pytest.approx(total)
    assert report.ratio_paper == pytest.approx(16.0)
    assert report.ratio_honest == pytest.approx(dense / total)


def test_auto_picker_meets_budget_and_records_choices(two_models):
    cfg, base, ft = two_models
    deltas, report = compress(base, ft, codec="auto", budget_bits=2.0)
    assert report.spec is None and report.budget_bits == 2.0
    assert report.budget_met, report.auto_choices
    assert len(report.auto_choices) == report.n_compressed > 0
    for path, ch in report.auto_choices.items():
        assert ch["bits_per_element"] <= 2.0, (path, ch)
        assert ch["codec"] == report.leaf_codecs[path]
        assert ch["rel_error"] >= 0.0
    assert "auto(budget=2.0" in report.summary()


def test_auto_requires_budget_and_budget_requires_auto(two_models):
    cfg, base, ft = two_models
    with pytest.raises(ValueError, match="budget_bits"):
        compress(base, ft, codec="auto")
    with pytest.raises(ValueError, match="auto"):
        compress(base, ft, codec="bitdelta", budget_bits=1.0)


def test_spec_codec_mismatch_raises(two_models):
    cfg, base, ft = two_models
    with pytest.raises(ValueError, match="does not belong"):
        compress(base, ft, BitDeltaSpec(), codec="deltadq")
