"""Online tenant lifecycle: hot registration, rollout, retire, registry.

The acceptance bar for the tenant-table envelope:
* hot registration of tenant N+1 into a running engine triggers **zero
  decode-step recompiles** (the decode jit cache stays at one entry),
* an engine that hot-registers tenants mid-traffic is **token-identical**
  to an engine constructed with all tenants up front — for in-flight
  sequences and for the newly registered tenant,
* a version rollout serves the new version to new requests only;
  in-flight sequences drain against the old table row, which is then
  reclaimed,
* the registry's cold tiers round-trip: a tenant evicted to host RAM or
  the disk spool promotes back and serves the same tokens.

Plus regression tests for the live-mutation bug family fixed alongside:
kv claim/release raising ValueError (not assert), atomic
``_refresh_stacked`` (failed dynamic registration leaves the engine
untouched), and ``DeltaStore.register`` refusing silent same-name
replacement.

Determinism: every engine runs on a VirtualClock; every random draw is
explicitly seeded.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import CompileGuard
from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import (
    ContinuousEngine,
    DeltaRegistry,
    DeltaStore,
    Metrics,
    SlotKVCache,
    Tracer,
    VirtualClock,
    validate_chrome_trace,
)
from repro.serve.registry import _load_npz, _save_npz
from repro.utils import flatten_with_paths

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SPEC = DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32)


def _ft_of(base, rng, t, scale=0.05):
    return jax.tree.map(
        lambda p, t=t: p + scale * jax.random.normal(
            jax.random.fold_in(rng, 7 + t), p.shape,
            jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)


def _make_tenants(cfg, base, n, rng, scale=0.05):
    out = []
    for t in range(n):
        deltas, _ = compress(base, _ft_of(base, rng, t, scale), SPEC)
        out.append(deltas)
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 4, rng)
    return cfg, base, tenants


def _prompts(cfg, n, length=8):
    rs = np.random.RandomState(0)
    return [rs.randint(0, cfg.vocab, size=length) for _ in range(n)]


def _engine(cfg, base, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("clock", VirtualClock(0.0))
    return ContinuousEngine(cfg, base, **kw)


# ---------------------------------------------------------------------------
# Tentpole: hot registration without recompile, token-identical
# ---------------------------------------------------------------------------

def test_hot_register_no_recompile_token_identical(setup):
    """Register tenant N+1 mid-traffic: zero decode recompiles, and both
    in-flight and new-tenant tokens match an all-up-front engine."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 3)

    ref = _engine(cfg, base, tenant_capacity=4)
    for i, d in enumerate(tenants[:3]):
        ref.register_tenant(f"t{i}", d)
    ref_reqs = [ref.submit(f"t{i}", prompts[i], max_new_tokens=6)
                for i in range(3)]
    ref.run()

    eng = _engine(cfg, base, tenant_capacity=4)
    for i, d in enumerate(tenants[:2]):
        eng.register_tenant(f"t{i}", d)
    r0 = eng.submit("t0", prompts[0], max_new_tokens=6)
    r1 = eng.submit("t1", prompts[1], max_new_tokens=6)
    # decode a few steps so t0/t1 are genuinely in flight
    for _ in range(3):
        eng.step(eng._now())
    guard = CompileGuard(eng, budgets={"decode": 1}, max_new={"decode": 0})
    eng.register_tenant("t2", tenants[2])          # HOT, mid-traffic
    r2 = eng.submit("t2", prompts[2], max_new_tokens=6)
    eng.run()

    # zero decode-step recompiles across the hot registration
    guard.check()
    # in-flight sequences untouched; the new tenant matches up-front
    assert list(r0.tokens) == list(ref_reqs[0].tokens)
    assert list(r1.tokens) == list(ref_reqs[1].tokens)
    assert list(r2.tokens) == list(ref_reqs[2].tokens)


def test_table_seeded_from_prepopulated_store(setup):
    """Tenants registered before the first step serve identically to
    tenants hot-registered after it — the identity contract both ways."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 2)
    a = _engine(cfg, base, tenant_capacity=3)
    a.register_tenant("t0", tenants[0])
    ra = a.submit("t0", prompts[0], max_new_tokens=5)
    a.run()
    b = _engine(cfg, base, tenant_capacity=3)
    b.step(b._now())                    # engine already running
    b.register_tenant("t0", tenants[0])
    rb = b.submit("t0", prompts[0], max_new_tokens=5)
    b.run()
    assert list(ra.tokens) == list(rb.tokens)


def test_rollout_old_version_drains_new_requests_switch(setup):
    """Re-registering a live tenant: in-flight stays on the old row, new
    requests see the new version, the old row is reclaimed after drain."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 2, length=6)
    eng = _engine(cfg, base, tenant_capacity=3)
    eng.register_tenant("t0", tenants[0])

    ref = _engine(cfg, base, tenant_capacity=3)
    ref.register_tenant("t0", tenants[0])
    ref_old = ref.submit("t0", prompts[0], max_new_tokens=8)
    ref.run()
    ref2 = _engine(cfg, base, tenant_capacity=3)
    ref2.register_tenant("t0", tenants[1])        # "new version" up front
    ref_new = ref2.submit("t0", prompts[1], max_new_tokens=8)
    ref2.run()

    r_old = eng.submit("t0", prompts[0], max_new_tokens=8)
    for _ in range(3):
        eng.step(eng._now())
    old_row = eng._rows["t0"]
    eng.register_tenant("t0", tenants[1])         # rollout mid-sequence
    new_row = eng._rows["t0"]
    assert new_row != old_row
    assert old_row in eng._retiring
    r_new = eng.submit("t0", prompts[1], max_new_tokens=8)
    eng.run()
    assert list(r_old.tokens) == list(ref_old.tokens)   # drained on old row
    assert list(r_new.tokens) == list(ref_new.tokens)   # served new version
    assert not eng._retiring                            # row reclaimed
    CompileGuard(eng, budgets={"decode": 1}).check()


def test_retire_frees_row_and_refuses_in_flight(setup):
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 2)
    eng = _engine(cfg, base, tenant_capacity=2)
    eng.register_tenant("t0", tenants[0])
    free_before = eng._table.n_free
    r = eng.submit("t0", prompts[0], max_new_tokens=4)
    eng.step(eng._now())
    with pytest.raises(RuntimeError, match="in-flight"):
        eng.unregister_tenant("t0")
    eng.run()
    assert r.done
    eng.unregister_tenant("t0")
    assert eng._table.n_free == free_before + 1
    with pytest.raises(KeyError):
        eng.submit("t0", prompts[1], max_new_tokens=4)
    # the name is re-registrable after retirement
    eng.register_tenant("t0", tenants[1])
    CompileGuard(eng, budgets={"decode": 1}).check()


def test_table_full_and_incompatible_tenant_rejected(setup):
    cfg, base, tenants = setup
    eng = _engine(cfg, base, tenant_capacity=1)
    eng.register_tenant("t0", tenants[0])
    with pytest.raises(ValueError, match="full"):
        eng.register_tenant("t1", tenants[1])
    # a rejected registration is a no-op: t0 still serves
    r = eng.submit("t0", _prompts(cfg, 1)[0], max_new_tokens=3)
    eng.run()
    assert r.done


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_kv_claim_release_raise_value_error():
    """Double-claim / double-free must raise ValueError, not assert —
    the guard has to survive ``python -O``."""
    cfg = get_smoke_config("llama3.2-1b")
    kv = SlotKVCache(cfg, n_slots=2, max_seq=8)
    kv.claim(0)
    with pytest.raises(ValueError, match="not free"):
        kv.claim(0)
    kv.release(0)
    with pytest.raises(ValueError, match="double-freed"):
        kv.release(0)
    assert kv.n_free == 2


def test_store_register_refuses_silent_replace(setup):
    cfg, base, tenants = setup
    store = DeltaStore()
    store.register("t0", tenants[0])
    with pytest.raises(ValueError, match="already registered"):
        store.register("t0", tenants[1])
    v = store.version
    store.register("t0", tenants[1], replace=True)
    assert store.version > v


def test_dynamic_reregister_refused_in_flight_engine_untouched(setup):
    """Dynamic mode: re-registering a tenant with in-flight sequences is
    refused, and the failed attempt leaves every piece of engine state
    (store, stacked groups, rows) exactly as before — the atomic
    ``_refresh_stacked`` contract."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 2)
    eng = _engine(cfg, base)                      # dynamic (no capacity)
    eng.register_tenant("t0", tenants[0])

    ref = _engine(cfg, base)
    ref.register_tenant("t0", tenants[0])
    rr = ref.submit("t0", prompts[0], max_new_tokens=6)
    ref.run()

    r = eng.submit("t0", prompts[0], max_new_tokens=6)
    eng.step(eng._now())
    version = eng.store.version
    rows = dict(eng._rows)
    with pytest.raises(RuntimeError, match="in-flight"):
        eng.register_tenant("t0", tenants[1])
    assert eng.store.version == version           # store rolled back
    assert eng._rows == rows                      # stacked rows untouched
    eng.run()
    assert list(r.tokens) == list(rr.tokens)      # sequence unharmed


def test_registry_promote_with_full_table_keeps_host_tree(setup):
    """Regression: promoting a warm tenant when the table is full evicts
    a victim, whose spill pass must NOT pick the tenant being promoted
    (which would null its host tree mid-promotion)."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 3)
    eng = _engine(cfg, base, tenant_capacity=2)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None,
                        spool_dir=None, host_capacity=1)
    for i in range(2):
        reg.ingest(f"t{i}", deltas=tenants[i])
    reg.pump()
    for i in range(2):
        reg.submit(f"t{i}", prompts[i], max_new_tokens=3)
    eng.run()
    reg.ingest("t2", deltas=tenants[2])
    reg.pump()                                    # evicts LRU -> warm
    warm = [n for n, r in reg._records.items() if r.state == "warm"]
    assert len(warm) == 1
    r = reg.submit(warm[0], prompts[0], max_new_tokens=3)   # promote
    eng.run()
    assert r.done
    assert reg._records[warm[0]].state == "hot"
    assert reg._records[warm[0]].host is not None


# ---------------------------------------------------------------------------
# Registry lifecycle
# ---------------------------------------------------------------------------

def test_registry_ingest_compress_register_serve(setup):
    cfg, base, _ = setup
    rng = jax.random.PRNGKey(0)
    eng = _engine(cfg, base, tenant_capacity=3)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec="auto")
    rec = reg.ingest("a", _ft_of(base, rng, 0))
    assert rec.state == "ready" and rec.compress_s is not None
    assert reg.pump() == ["a"]
    assert rec.state == "hot" and rec.register_s is not None
    r = reg.submit("a", _prompts(cfg, 1)[0], max_new_tokens=4)
    eng.run()
    assert r.done and len(r.tokens) == 4
    CompileGuard(eng, budgets={"decode": 1}).check()


def test_registry_cold_spool_roundtrip_identity(setup, tmp_path):
    """Evict -> spill to disk -> promote serves the same tokens."""
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 1)
    eng = _engine(cfg, base, tenant_capacity=2)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None,
                        spool_dir=str(tmp_path / "spool"), host_capacity=0)
    reg.ingest("a", deltas=tenants[0])
    reg.pump()
    r1 = reg.submit("a", prompts[0], max_new_tokens=5)
    eng.run()
    reg.evict("a")
    rec = reg._records["a"]
    assert rec.state == "cold" and rec.host is None
    assert rec.spool and os.path.exists(rec.spool)
    r2 = reg.submit("a", prompts[0], max_new_tokens=5)   # disk promote
    eng.run()
    assert rec.state == "hot"
    assert list(r2.tokens) == list(r1.tokens)


def test_registry_watch_dir_scan(setup, tmp_path):
    cfg, base, _ = setup
    rng = jax.random.PRNGKey(0)
    eng = _engine(cfg, base, tenant_capacity=2)
    watch = tmp_path / "watch"
    reg = DeltaRegistry(eng, base, spec=SPEC, codec="auto",
                        watch_dir=str(watch))
    assert reg.scan() == []                       # no dir yet: no-op
    ft = _ft_of(base, rng, 1)
    _save_npz(str(watch / "support-bot.npz"),
              {p: np.asarray(l) for p, l in flatten_with_paths(ft).items()})
    assert reg.scan() == ["support-bot"]
    assert reg.scan() == []                       # seen files not re-ingested
    reg.pump()
    r = reg.submit("support-bot", _prompts(cfg, 1)[0], max_new_tokens=4)
    eng.run()
    assert r.done


def test_registry_rollout_rollback(setup):
    cfg, base, tenants = setup
    prompts = _prompts(cfg, 1)
    eng = _engine(cfg, base, tenant_capacity=3)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None)
    reg.ingest("a", deltas=tenants[0]); reg.pump()
    r1 = reg.submit("a", prompts[0], max_new_tokens=5); eng.run()
    reg.ingest("a", deltas=tenants[1]); reg.pump()      # v2 rollout
    assert reg._records["a"].version == 2
    reg.rollback("a")                                   # back to v1
    r3 = reg.submit("a", prompts[0], max_new_tokens=5); eng.run()
    assert list(r3.tokens) == list(r1.tokens)
    with pytest.raises(KeyError):
        reg.rollback("never-registered")
    reg.ingest("b", deltas=tenants[2]); reg.pump()
    with pytest.raises(ValueError, match="no previous"):
        reg.rollback("b")


def test_lifecycle_events_reach_metrics_and_tracer(setup, tmp_path):
    cfg, base, tenants = setup
    eng = _engine(cfg, base, tenant_capacity=2)
    tracer = Tracer()
    eng.bus.attach(tracer)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None,
                        spool_dir=str(tmp_path / "spool"), host_capacity=0)
    reg.ingest("a", deltas=tenants[0]); reg.pump()
    reg.ingest("a", deltas=tenants[1]); reg.pump()      # rollout
    reg.ingest("b", deltas=tenants[2]); reg.pump()
    reg.evict("a")                                      # warm -> cold spill
    reg.promote("a")                                    # back to hot
    eng.unregister_tenant("b")                          # retire
    m = eng.metrics
    for kind in ("tenant_register", "tenant_rollout", "tenant_ready",
                 "tenant_evict", "tenant_promote", "tenant_retire"):
        assert m.lifecycle.get(kind, 0) >= 1, kind
    rep = m.report()
    assert rep["tenant_lifecycle"]["tenant_ready"] == 3
    names = {e["name"] for e in tracer.events if e.get("ph") == "i"}
    assert {"tenant_register", "tenant_rollout", "tenant_retire",
            "tenant_ready", "tenant_promote", "tenant_evict"} <= names
    validate_chrome_trace(tracer.to_chrome_trace())


def test_registry_background_worker(setup):
    """background=True: compression runs on the worker thread, pump()
    (serving-loop thread) picks up the finished record."""
    import time as _time
    cfg, base, _ = setup
    rng = jax.random.PRNGKey(0)
    eng = _engine(cfg, base, tenant_capacity=2)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None, background=True)
    try:
        rec = reg.ingest("a", _ft_of(base, rng, 0))
        deadline = _time.time() + 60.0
        hot = []
        while not hot and _time.time() < deadline:
            hot = reg.pump()
            _time.sleep(0.01)
        assert hot == ["a"] and rec.state == "hot"
        r = reg.submit("a", _prompts(cfg, 1)[0], max_new_tokens=3)
        eng.run()
        assert r.done
    finally:
        reg.close()


def test_registry_compress_failure_recorded_not_raised(setup):
    cfg, base, _ = setup
    eng = _engine(cfg, base, tenant_capacity=2)
    reg = DeltaRegistry(eng, base, spec=SPEC, codec=None)
    rec = reg.ingest("bad", {"not": "a-param-tree"})
    assert rec.state == "failed" and rec.error
    assert reg.pump() == []                      # nothing went hot
    with pytest.raises(ValueError, match="ft_params or deltas"):
        reg.ingest("empty")


def test_npz_sidecar_roundtrips_bf16(tmp_path):
    arrs = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": jnp.arange(4, dtype=jnp.bfloat16)}
    path = str(tmp_path / "x.npz")
    _save_npz(path, {k: np.asarray(v) for k, v in arrs.items()})
    back = _load_npz(path)
    assert back["a"].dtype == np.float32
    assert back["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(back["a"], np.asarray(arrs["a"]))
    np.testing.assert_array_equal(back["b"], np.asarray(arrs["b"]))


# ---------------------------------------------------------------------------
# Property suite: lifecycle interleaved with traffic
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.sampled_from(["register", "retire", "rollout",
                                     "traffic", "steps"]),
                    min_size=3, max_size=10),
           st.integers(0, 2 ** 31 - 1))
    def test_lifecycle_interleaving_never_corrupts(setup, ops, seed):
        """Any interleaving of register/retire/rollout with traffic keeps
        the engine serving, the decode jit cache at one entry, and the
        table's free-row accounting consistent."""
        cfg, base, tenants = setup
        prompts = _prompts(cfg, 4)
        rs = np.random.RandomState(seed)
        eng = _engine(cfg, base, tenant_capacity=3)
        live, version = {}, {}
        pending = []
        for op in ops:
            names = sorted(live)
            if op == "register" and len(live) < 3:
                n = f"t{len(version)}"
                try:
                    eng.register_tenant(n, tenants[rs.randint(4)])
                    version[n] = 0
                    live[n] = True
                except ValueError:
                    pass                      # retiring rows not drained yet
            elif op == "rollout" and names:
                n = names[rs.randint(len(names))]
                try:
                    eng.register_tenant(n, tenants[rs.randint(4)])
                except ValueError:
                    pass                      # no free row for the new version
            elif op == "retire" and names:
                n = names[rs.randint(len(names))]
                try:
                    eng.unregister_tenant(n)
                    del live[n]
                except RuntimeError:
                    pass                      # in-flight: correctly refused
            elif op == "traffic" and names:
                n = names[rs.randint(len(names))]
                pending.append(eng.submit(n, prompts[rs.randint(4)],
                                          max_new_tokens=3))
            elif op == "steps":
                for _ in range(2):
                    eng.step(eng._now())
            # invariants after every op
            CompileGuard(eng, budgets={"decode": 1}).check()
            rows = set(eng._rows.values())
            assert len(rows) == len(eng._rows)          # rows unique
            assert 0 not in rows                        # row 0 is base
            assert not rows & set(eng._table._free)     # live != free
        eng.run()
        for r in pending:
            assert r.done
