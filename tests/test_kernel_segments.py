"""Parity tests for the mixed-tenant segment dispatch and the XLA
fallback formulations.

Three layers are checked against the dense-reconstruct oracle:

* ``kernels.fallback`` — gather / per-row / segment formulations (the
  CPU serving hot path), including the bitwise-stability property the
  token-identity contract depends on;
* ``kernels.ops.delta_spmm_segments`` — the batched slot Pallas kernel
  in interpret mode (+ the scan fallback);
* ``core.apply.slot_delta_matmul`` — the dispatch seam the engine uses,
  in both "segments" and "per_row" modes.

The slow-marked sweep covers the full supported envelope
(h_g x keep x k_bits); the fast subset runs per-PR.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import groupwise_dropout_pack
from repro.core.apply import (
    get_slot_dispatch,
    set_slot_dispatch,
    stack_tenant_deltas,
    slot_delta_matmul,
    wrap_slot_deltas,
)
from repro.core.pack import PackedDelta, reconstruct_dense
from repro.kernels import fallback, ops
from repro.serve.scheduler import tenant_segments


def _pack(h_in, h_out, h_g, alpha, k, seed=0, scale=0.01):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_in, h_out)) * scale
    return groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=k)


def _stacked(n, h_in=128, h_out=256, h_g=64, alpha=8, k=4):
    ps = [_pack(h_in, h_out, h_g, alpha, k, seed=s) for s in range(n)]
    return stack_tenant_deltas([{"w": p} for p in ps])["w"], ps


def _segments(rows):
    return jax.tree.map(jnp.asarray, tenant_segments(np.asarray(rows)))


# ---------------------------------------------------------------------------
# XLA fallback formulations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,h_in,h_out,h_g,alpha,k", [
    (1, 128, 256, 64, 8, 4),
    (8, 128, 96, 32, 4, 2),
    (200, 256, 128, 64, 8, None),
])
def test_gather_vs_dense_correction(T, h_in, h_out, h_g, alpha, k):
    p = _pack(h_in, h_out, h_g, alpha, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, h_in))
    want = np.asarray(x @ reconstruct_dense(p))
    np.testing.assert_allclose(np.asarray(fallback.gather_correction(x, p)),
                               want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fallback.dense_correction(x, p)),
                               want, atol=1e-6, rtol=1e-6)


def test_gather_correction_batch_extent_bit_stable():
    """The token-identity contract: a row's correction must be the same
    bits whether computed alone, in a group, or in a full slot batch."""
    p = _pack(128, 256, 64, 8, 4, scale=0.5)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128)) * 2.0
    full = np.asarray(jax.jit(lambda x: fallback.gather_correction(x, p))(x))
    for sl in (slice(0, 1), slice(2, 5), slice(3, 8)):
        part = np.asarray(
            jax.jit(lambda x: fallback.gather_correction(x, p))(x[sl]))
        np.testing.assert_array_equal(part, full[sl])


def test_rows_vs_shared_vals_bit_identical():
    """Per-row gather with every row on the same tenant must bit-match
    the shared-tenant gather (what makes per_row == per-tenant exact)."""
    p = _pack(128, 256, 64, 8, 4, scale=0.5)
    B = 4
    rows = np.zeros(B, np.int32)
    stk, _ = _stacked(1)
    gat = PackedDelta(stk.idx[rows], stk.codes[rows],
                      jnp.asarray(stk.scale)[rows],
                      jnp.asarray(stk.zero)[rows],
                      stk.h_in, stk.h_out, stk.h_g, stk.keep,
                      stk.alpha, stk.k_bits, stk.m)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 128))
    y_rows = np.asarray(jax.jit(
        lambda x: fallback.gather_correction_rows(x[:, None, :], gat))(x))[:, 0]
    y_shared = np.asarray(jax.jit(
        lambda x: fallback.gather_correction(x, stk.index(0)))(x))
    np.testing.assert_array_equal(y_rows, y_shared)


def test_gather_rows_no_dense_materialization_parity():
    """The slots fallback must match per-row dense without ever building
    the [B, h_in, h_out] stack (which blew up memory when rows shared a
    tenant)."""
    stk, ps = _stacked(2)
    rows = np.array([1, 1, 1, 0, 1, 1], np.int32)   # dup-heavy batch
    gat = PackedDelta(stk.idx[rows], stk.codes[rows],
                      jnp.asarray(stk.scale)[rows],
                      jnp.asarray(stk.zero)[rows],
                      stk.h_in, stk.h_out, stk.h_g, stk.keep,
                      stk.alpha, stk.k_bits, stk.m)
    x = jax.random.normal(jax.random.PRNGKey(4), (len(rows), 1, 128))
    want = jnp.einsum("b...d,bdf->b...f", x, reconstruct_dense(stk)[rows])
    got = ops.delta_spmm_slots(x, gat, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Segment dispatch (fallback scan + Pallas kernel, interpret mode)
# ---------------------------------------------------------------------------
def _segment_oracle(x, stk, rows):
    dense = reconstruct_dense(stk)                   # [R, h_in, h_out]
    return jnp.einsum("b...d,bdf->b...f", x, dense[np.asarray(rows)])


@pytest.mark.parametrize("rows", [
    [0, 0, 0, 0],              # single tenant
    [2, 0, 2, 1, 0, 2, 1, 0],  # mixed, duplicates
    [1, 2, 0],                 # all distinct
])
def test_segment_fallback_parity(rows):
    stk, _ = _stacked(3)
    B = len(rows)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 128))
    seg = _segments(rows)
    xs = jnp.take(x, seg.order, axis=0)
    y = fallback.segment_correction(xs, stk, seg.seg_rows, seg.seg_offsets)
    y = jnp.take(y, seg.inv_order, axis=0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_segment_oracle(x, stk, rows)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("h_out", [256, 96, 251])
def test_segment_kernel_interpret_parity(h_out):
    stk, _ = _stacked(3, h_out=h_out)
    rows = [2, 0, 2, 1, 0, 2, 1, 0]
    B = len(rows)
    x = jax.random.normal(jax.random.PRNGKey(6), (B, 128))
    seg = _segments(rows)
    xs = jnp.take(x, seg.order, axis=0)
    y = ops.delta_spmm_segments(xs, stk, seg.seg_rows, seg.seg_offsets,
                                interpret=True)
    y = jnp.take(y, seg.inv_order, axis=0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_segment_oracle(x, stk, rows)),
                               atol=1e-4, rtol=1e-4)


def test_segment_kernel_multi_row_blocks():
    """T spanning several row tiles: segment/tile overlap logic."""
    stk, _ = _stacked(2, h_in=64, h_out=128, h_g=32, alpha=4)
    rows = [0] * 5 + [1] * 11          # 16 rows, tb forced to 8
    B = len(rows)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, 64))
    seg = _segments(rows)
    xs = jnp.take(x, seg.order, axis=0)
    y = ops.delta_spmm_segments(xs, stk, seg.seg_rows, seg.seg_offsets,
                                tb=8, interpret=True)
    y = jnp.take(y, seg.inv_order, axis=0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_segment_oracle(x, stk, rows)),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# apply-level dispatch seam
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["segments", "per_row"])
def test_slot_delta_matmul_modes(mode):
    stk_tree, _ = _stacked(3)
    rows = np.array([2, 0, 2, 1, 0, 1], np.int32)
    B = len(rows)
    x = jax.random.normal(jax.random.PRNGKey(8), (B, 1, 128))
    sd = wrap_slot_deltas({"w": stk_tree}, jnp.asarray(rows),
                          segments=_segments(rows))["w"]
    want = _segment_oracle(x, stk_tree, rows)
    prev = get_slot_dispatch()
    try:
        set_slot_dispatch(mode)
        got = jax.jit(lambda x, sd: slot_delta_matmul(x, sd))(x, sd)
    finally:
        set_slot_dispatch(prev)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_values_path_bit_identical_to_packed():
    """The pre-decoded residency path (values + res_map on the
    SlotDelta) must produce the EXACT bits of the packed segment
    dispatch — decode-ahead-of-time is the same elementwise math as
    decode-in-step, and the contraction is shared. Includes a permuted
    res_map (residency rows need not align with tenant rows)."""
    from repro.core.apply import SlotDelta
    from repro.core.pack import decode_values

    stk_tree, _ = _stacked(3)
    rows = np.array([2, 0, 2, 1, 0, 1], np.int32)
    B = len(rows)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, 1, 128))
    seg = _segments(rows)
    packed = SlotDelta(stk_tree, jnp.asarray(rows), seg)
    want = np.asarray(jax.jit(slot_delta_matmul)(x, packed))

    # identity res_map: residency row == tenant row
    vals = decode_values(stk_tree)
    ident = SlotDelta(stk_tree, jnp.asarray(rows), seg, vals,
                      jnp.arange(vals.shape[0], dtype=jnp.int32))
    got = np.asarray(jax.jit(slot_delta_matmul)(x, ident))
    np.testing.assert_array_equal(got, want)

    # permuted residency buffer: slot order differs from tenant order
    perm = np.array([2, 0, 1], np.int32)       # residency slot -> tenant row
    buf = jnp.asarray(np.asarray(vals)[perm])
    res_map = np.zeros(vals.shape[0], np.int32)
    for slot, row in enumerate(perm):
        res_map[row] = slot
    permd = SlotDelta(stk_tree, jnp.asarray(rows), seg, buf,
                      jnp.asarray(res_map))
    got = np.asarray(jax.jit(slot_delta_matmul)(x, permd))
    np.testing.assert_array_equal(got, want)


def test_segments_layout_shapes_static():
    """Different tenant mixes must produce identical array shapes (one
    decode jit compilation regardless of the batch's tenant diversity)."""
    shapes = set()
    for rows in ([0, 0, 0, 0], [1, 2, 3, 0], [2, 2, 1, 1]):
        seg = tenant_segments(np.asarray(rows, np.int32))
        shapes.add((seg.order.shape, seg.inv_order.shape,
                    seg.seg_rows.shape, seg.seg_offsets.shape))
    assert len(shapes) == 1


def test_segments_layout_contents():
    seg = tenant_segments(np.array([2, 0, 2, 1], np.int32))
    np.testing.assert_array_equal(seg.order, [1, 3, 0, 2])
    np.testing.assert_array_equal(seg.seg_rows, [0, 1, 2, 0])
    np.testing.assert_array_equal(seg.seg_offsets, [0, 1, 2, 4, 4])
    np.testing.assert_array_equal(
        np.asarray(seg.order)[np.asarray(seg.inv_order)], np.arange(4))


# ---------------------------------------------------------------------------
# Full-envelope sweep (slow; fast subset above runs per-PR)
# ---------------------------------------------------------------------------
def _envelope_points():
    pts = []
    for h_g in (16, 64, 256):
        for keep in (1, 16, 128):
            if keep > h_g or h_g % keep:
                continue
            for k_bits in (None, 1, 2, 4, 8):
                pts.append((h_g, keep, k_bits))
    return pts


@pytest.mark.slow
@pytest.mark.parametrize("h_g,keep,k_bits", _envelope_points())
def test_kernel_envelope_sweep(h_g, keep, k_bits):
    """delta_spmm / fused / segments (interpret) vs the dense oracle
    across the whole supported envelope."""
    alpha = h_g // keep
    h_in, h_out = h_g * 2, 128
    p = _pack(h_in, h_out, h_g, alpha, k_bits, seed=h_g + keep)
    x = jax.random.normal(jax.random.PRNGKey(9), (16, h_in))
    dense = reconstruct_dense(p)
    want = np.asarray(x @ dense)
    np.testing.assert_allclose(
        np.asarray(ops.delta_spmm(x, p, interpret=True)), want,
        atol=1e-3, rtol=1e-3)
    w = jax.random.normal(jax.random.PRNGKey(10), (h_in, h_out)) * 0.05
    np.testing.assert_allclose(
        np.asarray(ops.fused_base_delta(x, w, p, interpret=True)),
        np.asarray(x @ (w + dense)), atol=1e-3, rtol=1e-3)
    # 2-tenant stack through the segments kernel
    p2 = _pack(h_in, h_out, h_g, alpha, k_bits, seed=h_g + keep + 1)
    stk = stack_tenant_deltas([{"w": p}, {"w": p2}])["w"]
    rows = [1, 0, 1, 1]
    seg = _segments(rows)
    xs = jnp.take(x[:4], seg.order, axis=0)
    y = ops.delta_spmm_segments(xs, stk, seg.seg_rows, seg.seg_offsets,
                                interpret=True)
    y = jnp.take(y, seg.inv_order, axis=0)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_segment_oracle(x[:4], stk, rows)),
        atol=1e-3, rtol=1e-3)
