"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Sweeps shapes/dtypes per the deliverable; hypothesis drives random
envelope-internal configurations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import groupwise_dropout_pack
from repro.kernels import ops, ref

# hypothesis is optional: only the property-based test needs it, the
# deterministic parity sweeps must run everywhere (they are the only
# validation of the Pallas kernels on CPU containers)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SWEEP = [
    # (T, h_in, h_out, h_g, alpha, k_bits)
    (64, 256, 128, 64, 8, 4),
    (32, 512, 256, 128, 4, 8),
    (128, 256, 384, 32, 2, 2),
    (16, 128, 128, 16, 8, 1),
    (8, 64, 96, 16, 4, None),
    (100, 256, 96, 256, 16, 4),     # padding path (T not multiple of tile)
    (1, 128, 64, 32, 4, 4),         # decode shape (T=1)
]


def _pack(h_in, h_out, h_g, alpha, k, seed=0, scale=0.01):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_in, h_out)) * scale
    return groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=k)


@pytest.mark.parametrize("T,h_in,h_out,h_g,alpha,k", SWEEP)
def test_delta_spmm_vs_ref(T, h_in, h_out, h_g, alpha, k):
    p = _pack(h_in, h_out, h_g, alpha, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, h_in))
    np.testing.assert_allclose(np.asarray(ops.delta_spmm(x, p, interpret=True)),
                               np.asarray(ref.delta_spmm_ref(x, p)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,h_in,h_out,h_g,alpha,k", SWEEP[:5])
def test_fused_base_delta_vs_ref(T, h_in, h_out, h_g, alpha, k):
    p = _pack(h_in, h_out, h_g, alpha, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, h_in))
    w = jax.random.normal(jax.random.PRNGKey(2), (h_in, h_out)) * 0.05
    np.testing.assert_allclose(np.asarray(ops.fused_base_delta(x, w, p, interpret=True)),
                               np.asarray(ref.fused_base_delta_ref(x, w, p)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,h_in,h_out,h_g,alpha,k", SWEEP[:5])
def test_dequant_vs_ref(T, h_in, h_out, h_g, alpha, k):
    p = _pack(h_in, h_out, h_g, alpha, k)
    np.testing.assert_allclose(np.asarray(ops.dequant(p, interpret=True)),
                               np.asarray(ref.dequant_tile_ref(p)),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    p = _pack(256, 128, 64, 8, 4)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 256)).astype(dtype)
    got = ops.delta_spmm(x, p, interpret=True)
    want = ref.delta_spmm_ref(x.astype(jnp.float32), p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.05 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=0.05 if dtype == jnp.bfloat16 else 1e-4)


def test_fallback_outside_envelope():
    # h_g > MAX_HG routes to the XLA fallback and still matches the oracle
    p = _pack(1024, 32, 1024, 8, 4)
    assert not ops.kernel_supported(p)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 1024))
    np.testing.assert_allclose(np.asarray(ops.delta_spmm(x, p, interpret=True)),
                               np.asarray(ref.delta_spmm_ref(x, p)),
                               atol=1e-4, rtol=1e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(
        t_exp=st.integers(0, 6),
        g_exp=st.integers(0, 3),
        hg_exp=st.integers(4, 8),
        alpha=st.sampled_from([2, 4, 8, 16]),
        k=st.sampled_from([1, 2, 4, 8, None]),
        ho_mult=st.integers(1, 3),
    )
    def test_kernel_hypothesis(t_exp, g_exp, hg_exp, alpha, k, ho_mult):
        h_g = 2 ** hg_exp
        if h_g < alpha:
            h_g = alpha
        h_in = h_g * (2 ** g_exp)
        h_out = 64 * ho_mult
        T = 2 ** t_exp
        p = _pack(h_in, h_out, h_g, alpha, k, seed=t_exp + hg_exp)
        x = jax.random.normal(jax.random.PRNGKey(5), (T, h_in))
        np.testing.assert_allclose(
            np.asarray(ops.delta_spmm(x, p, interpret=True)),
            np.asarray(ref.delta_spmm_ref(x, p)),
            atol=1e-3, rtol=1e-3)
