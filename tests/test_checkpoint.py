"""Fault tolerance: checkpoint/restart, failure simulation, elastic re-mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data import PretrainMixture
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import make_train_step


def _train(cfg, params, opt, data, step_fn, start, n):
    ms = None
    for i in range(start, start + n):
        params, opt, ms = step_fn(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
    return params, opt, ms


def test_failure_restart_bitexact(tmp_path):
    """Kill mid-training, restore, continue: bitwise identical to no-failure."""
    cfg = get_smoke_config("llama3.2-1b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    data = PretrainMixture(vocab=cfg.vocab, seq_len=16, batch=4)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))

    # uninterrupted run: 6 steps
    p_ref, o_ref, _ = _train(cfg, params, adamw.init(params), data, step_fn, 0, 6)

    # interrupted: 3 steps -> checkpoint -> "crash" -> restore -> 3 more
    ck = Checkpointer(str(tmp_path / "ck"))
    p1, o1, _ = _train(cfg, params, adamw.init(params), data, step_fn, 0, 3)
    ck.save(3, {"params": p1, "opt": o1}, extra={"data_step": 3})
    del p1, o1  # crash
    state, manifest = ck.restore({"params": params, "opt": adamw.init(params)})
    assert manifest["extra"]["data_step"] == 3
    p2, o2, _ = _train(cfg, state["params"], state["opt"], data, step_fn,
                       manifest["extra"]["data_step"], 3)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"))
    state = {"w": jnp.arange(1000, dtype=jnp.float32)}
    ck.save(1, state, blocking=False)
    ck.wait()
    restored, _ = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))


def test_latest_step_and_multiple(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"))
    ck.save(1, {"x": jnp.ones(3)})
    ck.save(7, {"x": jnp.ones(3) * 7})
    assert ck.latest_step() == 7
    r, _ = ck.restore({"x": jnp.zeros(3)})
    assert float(r["x"][0]) == 7.0
    r1, _ = ck.restore({"x": jnp.zeros(3)}, step=1)
    assert float(r1["x"][0]) == 1.0


@pytest.mark.slow  # three short training runs across meshes in subprocesses
def test_elastic_remesh_restore(subproc):
    """Save on a (2,2) mesh, restore on (4,1) AND on (1,1): training continues
    with identical loss trajectory — the elastic-rescale path."""
    out = subproc("""
    import jax, numpy as np, jax.numpy as jnp, tempfile, os
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import Checkpointer
    from repro.configs import get_smoke_config
    from repro.data import PretrainMixture
    from repro.models import lm
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig
    from repro.train import make_train_step
    from repro.dist import ShardingRules, tree_shardings

    cfg = get_smoke_config('llama3.2-1b')
    data = PretrainMixture(vocab=cfg.vocab, seq_len=16, batch=4)
    step_fn = make_train_step(cfg, AdamWConfig(lr=1e-3))

    def run(mesh_shape, restore_dir=None, start=0, n=3, save_dir=None):
        mesh = jax.make_mesh(mesh_shape, ('data', 'model'))
        rules = ShardingRules(mesh)
        p_specs, p_axes = lm.param_specs(cfg), lm.param_axes(cfg)
        p_sh = tree_shardings(rules, p_specs, p_axes)
        with mesh:
            params = lm.init_params(cfg, jax.random.PRNGKey(0))
            params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, p_sh)
            opt = adamw.init(params)
            if restore_dir:
                ck = Checkpointer(restore_dir)
                state, man = ck.restore({'params': params, 'opt': opt})
                params, opt = state['params'], state['opt']
                start = man['extra']['data_step']
            sf = jax.jit(step_fn)
            loss = None
            for i in range(start, start + n):
                params, opt, m = sf(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
                loss = float(m['loss'])
            if save_dir:
                Checkpointer(save_dir).save(start + n, {'params': params, 'opt': opt},
                                            extra={'data_step': start + n})
            return params, loss

    d = tempfile.mkdtemp()
    # reference: 6 steps on (2,2)
    _, ref_loss = run((2, 2), n=6)
    # elastic: 3 steps on (2,2) -> save -> restore on (4,1) -> 3 more
    run((2, 2), n=3, save_dir=d)
    _, el_loss = run((4, 1), restore_dir=d, n=3)
    # and restore on a single device mesh
    _, sd_loss = run((1, 1), restore_dir=d, n=3)
    print('REF', ref_loss, 'EL', el_loss, 'SD', sd_loss)
    assert abs(ref_loss - el_loss) < 2e-3, (ref_loss, el_loss)
    assert abs(ref_loss - sd_loss) < 2e-3, (ref_loss, sd_loss)
    print('OK')
    """, n_devices=8, timeout=900)
    assert "OK" in out
