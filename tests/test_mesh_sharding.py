"""Sharded multi-device serving: layouts, shard_map delta path, identity.

Everything runs on CPU with fake devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` via the
``subproc`` fixture) — the same way the CI multi-device job runs it.
"""
import numpy as np
import pytest


def test_serve_param_shardings_column_parallel(subproc):
    """Serve layout: matmul weights shard their output axis over `model`;
    embeddings, norms and conv taps replicate; indivisible dims fall back."""
    out = subproc("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh, param_shardings

    mesh = make_serving_mesh(8)
    cfg = get_smoke_config('llama3.2-1b')
    sh = param_shardings(cfg, mesh)
    # attn wq [L, d, q_dim=64]: output columns sharded
    assert sh['attn']['wq'].spec == P(None, None, 'model'), sh['attn']['wq'].spec
    assert sh['attn']['wo'].spec == P(None, None, 'model')
    assert sh['mlp']['wi'].spec == P(None, None, 'model')
    # contraction axes never sharded; embeddings/norms replicated
    assert sh['embed']['tok'].spec == P()
    assert sh['attn']['ln1'].spec == P()

    # ssm arch: inner projections sharded, conv taps replicated
    cfg2 = get_smoke_config('mamba2-370m')
    sh2 = param_shardings(cfg2, mesh)
    assert sh2['ssm']['conv_x_w'].spec == P()
    assert sh2['ssm']['wx'].spec[-1] in ('model', None)
    print('OK')
    """, n_devices=8)
    assert "OK" in out


def test_delta_shardings_replicated_and_output_sharded(subproc):
    out = subproc("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.core import DeltaDQSpec, compress
    from repro.core.pack import PackedDelta
    from repro.launch.mesh import make_serving_mesh, delta_shardings
    from repro.models import lm

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(lambda p: p * 1.01 if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16))
    mesh = make_serving_mesh(8)

    repl = delta_shardings(deltas, mesh)
    leaf = repl['attn']['wq']
    assert leaf.idx.spec == P() and leaf.scale.spec == P()

    shard = delta_shardings(deltas, mesh, shard_output=True)
    leaf = shard['attn']['wq']          # idx [L, G, K, O]: O on model
    assert leaf.idx.spec == P(None, None, None, 'model'), leaf.idx.spec
    assert leaf.scale.spec == P()
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # ~25s shard_map sweep; multi-device CI + nightly run it
def test_sharded_delta_correction_bit_identical(subproc):
    """The shard_map'd output-column-partitioned correction must be
    bit-identical to the replicated fallback, for both the shared-delta
    and the row-gathered (slot) stack cases."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import DeltaDQSpec, compress
    from repro.core.pack import reconstruct_dense
    from repro.kernels import ops
    from repro.launch.mesh import make_serving_mesh
    from repro.models import lm

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(lambda p: p + 0.02 * jax.random.normal(
        jax.random.fold_in(rng, 7), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16))
    mesh = make_serving_mesh(8)
    d = deltas['attn']['wq'].index(0)

    # the replicated reference is the engine's actual unsharded path
    # (core.apply.delta_matmul with no mesh installed): the contract is
    # sharded serving == replicated serving, whatever formulation the
    # replicated hot path uses
    from repro.core import apply as capply
    for dt in (jnp.float32, jnp.bfloat16):
        x = (jax.random.normal(jax.random.PRNGKey(1), (2, 3, d.h_in)) * 0.1).astype(dt)
        ref = jax.jit(lambda x: capply.delta_matmul(x, d))(x)
        got = jax.jit(lambda x: ops.delta_correction_sharded(
            x, d, mesh, use_pallas=False))(x)
        assert (np.asarray(ref) == np.asarray(got)).all(), dt

    # large-T (prefill-sized) token counts take the dense-reconstruct
    # formulation; the sharded path must still match exactly
    xl = (jax.random.normal(jax.random.PRNGKey(3), (1, 256, d.h_in)) * 0.1)
    ref = jax.jit(lambda x: capply.delta_matmul(x, d))(xl)
    got = jax.jit(lambda x: ops.delta_correction_sharded(
        x, d, mesh, use_pallas=False))(xl)
    assert (np.asarray(ref) == np.asarray(got)).all()

    # row-gathered stack: one tenant delta per batch row
    import jax.numpy as jnp
    B = 4
    stack = jax.tree.map(lambda a: jnp.stack([a] * B), (d.idx, d.codes))
    from repro.core.pack import PackedDelta
    ds = PackedDelta(stack[0], stack[1],
                     jnp.full((B,), jnp.float32(d.scale)),
                     jnp.full((B,), jnp.int32(d.zero)),
                     d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m)
    xb = (jax.random.normal(jax.random.PRNGKey(2), (B, 1, d.h_in)) * 0.1
          ).astype(jnp.bfloat16)
    from repro.kernels import fallback
    ref = jax.jit(lambda x: fallback.gather_correction_rows(x, ds)
                  .astype(x.dtype))(xb)
    got = jax.jit(lambda x: ops.delta_correction_sharded(
        x, ds, mesh, use_pallas=False))(xb)
    assert (np.asarray(ref) == np.asarray(got)).all()

    # indivisible output or foreign stack -> caller must fall back
    assert ops.delta_correction_sharded(xb[:3], ds, mesh) is None  # B mismatch
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # two full engine streams in a subprocess
def test_sharded_engine_token_identity_mixed_stream(subproc):
    """Sharded decode == single-device ContinuousEngine, token for token:
    3 tenants + raw-base requests (packed-delta dispatch AND the dense
    zero-delta fallback row), mixed lengths, staggered arrivals."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 3, RATIO_SPECS[128], rng)

    def run(mesh):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=64,
                               clock=VirtualClock(tick=0.01), mesh=mesh)
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        reqs = []
        for i in range(9):
            L = 4 + (i % 3) * 4
            tenant = None if i % 4 == 3 else f'tenant{i % 3}'
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
            reqs.append(eng.submit(tenant, prompt, max_new_tokens=8,
                                   arrival=i * 0.05))
        eng.run()
        assert all(r.done for r in reqs)
        return eng, [r.output() for r in reqs]

    _, ref = run(None)                       # single-device first
    eng, got = run(make_serving_mesh(8))
    for i, (a, b) in enumerate(zip(ref, got)):
        assert (a == b).all(), (i, a.tolist(), b.tolist())

    # the sharded engine really holds a sharded base
    wq = eng.base['attn']['wq']
    assert len(wq.sharding.device_set) == 8
    assert wq.sharding.spec[-1] == 'model'
    print('OK')
    """, n_devices=8)
    assert "OK" in out


def test_sharded_mixed_codec_token_identity(subproc):
    """Mixed-codec fleet (DeltaDQ + BitDelta codec groups) under the
    (2, 4) mesh: tokens must match BOTH the single-device mixed engine
    and per-tenant-alone engines — the codec-group zero row contributes
    exactly 0.0 on every shard."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import BitDeltaSpec
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 2,
                            [RATIO_SPECS[128], BitDeltaSpec()], rng)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(rng, 100 + i), (4 + (i % 2) * 4,), 0, cfg.vocab))
        for i in range(6)]

    def run(mesh, names):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=64,
                               clock=VirtualClock(tick=0.01), mesh=mesh)
        for name, deltas, rep in tenants:
            if name in names:
                eng.register_tenant(name, deltas, rep)
        reqs = [eng.submit(f'tenant{i % 2}', p, max_new_tokens=6,
                           arrival=i * 0.05)
                for i, p in enumerate(prompts)
                if f'tenant{i % 2}' in names]
        eng.run()
        assert all(r.done for r in reqs)
        return eng, [r.output() for r in reqs]

    both = {'tenant0', 'tenant1'}
    _, ref = run(None, both)                       # single-device mixed
    alone = {}
    for name, _, _ in tenants:                     # per-tenant-alone refs
        _, outs = run(None, {name})
        alone[name] = outs
    eng, got = run(make_serving_mesh(8, data=2), both)
    assert len(eng._groups) == 2
    for i, (a, b) in enumerate(zip(ref, got)):
        assert (a == b).all(), ('mesh-vs-1dev', i, a.tolist(), b.tolist())
    for name, _, _ in tenants:
        mine = [o for i, o in enumerate(got) if f'tenant{i % 2}' == name]
        for i, (a, b) in enumerate(zip(alone[name], mine)):
            assert (a == b).all(), ('alone', name, i)
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # two full mesh engine streams in a subprocess
def test_sharded_delta_placement_token_identity(subproc):
    """Output-column-sharded packed deltas (shard_deltas='auto', the
    delta_shardings(shard_output=True) layout) must serve token-identical
    to the replicated delta layout, and actually shard the stacked
    dispatch tree where h_out divides the model axis."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core.apply import SlotDelta
    from repro.core.pack import PackedDelta
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 2, RATIO_SPECS[128], rng)
    mesh = make_serving_mesh(8)

    def run(shard_deltas):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=64,
                               clock=VirtualClock(tick=0.01), mesh=mesh,
                               shard_deltas=shard_deltas)
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        reqs = []
        for i in range(6):
            L = 4 + (i % 2) * 4
            tenant = None if i == 5 else f'tenant{i % 2}'
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, 200 + i), (L,), 0, cfg.vocab))
            reqs.append(eng.submit(tenant, prompt, max_new_tokens=6,
                                   arrival=i * 0.05))
        eng.run()
        assert all(r.done for r in reqs)
        return eng, [r.output() for r in reqs]

    eng_r, ref = run('replicated')
    eng_s, got = run('auto')
    for i, (a, b) in enumerate(zip(ref, got)):
        assert (a == b).all(), (i, a.tolist(), b.tolist())

    # the stacked dispatch tree is really output-sharded where divisible
    def leaves(t):
        if isinstance(t, PackedDelta):
            yield t
        elif isinstance(t, dict):
            for v in t.values():
                yield from leaves(v)

    n_sharded = 0
    for leaf in leaves(eng_s._stacked):
        spec = leaf.idx.sharding.spec
        if leaf.h_out % 8 == 0:
            assert spec[-1] == 'model', (leaf.h_out, spec)
            n_sharded += 1
        else:
            assert all(s is None for s in spec), (leaf.h_out, spec)
    assert n_sharded > 0
    for leaf in leaves(eng_r._stacked):
        assert all(s is None for s in leaf.idx.sharding.spec)
    print('OK')
    """, n_devices=8)
    assert "OK" in out


def test_kv_cache_insert_evict_roundtrip_sharded(subproc):
    """Slot insert/release round-trips under a sharded cache layout: the
    inserted row reads back exactly, other rows are untouched, and the
    persistent cache keeps its NamedSharding across insert and decode."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import cache_shardings, make_serving_mesh
    from repro.models import lm
    from repro.serve.kv import SlotKVCache

    cfg = get_smoke_config('llama3.2-1b')
    # model=2 so n_kv=2 KV rings actually shard along the heads axis
    mesh = make_serving_mesh(8, data=4)
    csh = cache_shardings(cfg, mesh, 4, 16)
    assert any(s.spec[2] == 'model' for s in jax.tree.leaves(csh)
               if hasattr(s, 'spec') and len(s.spec) == 4)

    kv = SlotKVCache(cfg, 4, 16, shardings=csh)
    before = jax.tree.map(np.asarray, kv.cache)

    def row(seed):
        rc = lm.init_cache(cfg, 1, 16)
        return jax.tree.map(
            lambda a: (a + seed).astype(a.dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, rc)

    kv.claim(1)
    kv.insert(1, row(1.0))
    after = jax.tree.map(np.asarray, kv.cache)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert (b[0] == a[0]).all() and (b[2:] == a[2:]).all()  # untouched
    got = jax.tree.leaves(after)[0][1]
    want = np.asarray(jax.tree.leaves(row(1.0))[0][0], got.dtype)
    assert (got == want).all()

    # release + reinsert a different row: old row data fully overwritten
    kv.release(1)
    kv.claim(1)
    kv.insert(1, row(2.0))
    again = jax.tree.map(np.asarray, kv.cache)
    got2 = jax.tree.leaves(again)[0][1]
    want2 = np.asarray(jax.tree.leaves(row(2.0))[0][0], got2.dtype)
    assert (got2 == want2).all()

    # layout survives the donated in-place update
    for leaf, s in zip(jax.tree.leaves(kv.cache), jax.tree.leaves(csh)):
        assert leaf.sharding == s, (leaf.sharding, s)
    assert kv.n_free == 3
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # ~35s, two engine streams; multi-device CI + nightly run it
def test_mesh_and_plain_engines_coexist(subproc):
    """A plain engine built AFTER a mesh engine must not inherit the
    mesh: each engine installs its own apply-mode before stepping, so
    the reverse construction order still compares sharded vs truly
    single-device (regression: stale process-global mesh)."""
    out = subproc("""
    import numpy as np, jax
    from repro.configs import get_smoke_config
    from repro.core import apply as ap
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 2, RATIO_SPECS[128], rng)

    def run(mesh):
        eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=64,
                               clock=VirtualClock(tick=0.01), mesh=mesh)
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        reqs = [eng.submit(f'tenant{i % 2}',
                           np.asarray(jax.random.randint(
                               jax.random.fold_in(rng, 40 + i), (6,), 0,
                               cfg.vocab)),
                           max_new_tokens=6, arrival=0.0) for i in range(3)]
        eng.run()
        return [r.output() for r in reqs]

    got = run(make_serving_mesh(8))      # mesh engine FIRST
    assert ap.get_mesh() is not None
    ref = run(None)                      # plain engine after: must clear it
    assert ap.get_mesh() is None
    for a, b in zip(ref, got):
        assert (a == b).all(), (a.tolist(), b.tolist())
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # two full engine streams in a subprocess
def test_moe_arch_sharded_token_identity(subproc):
    """MoE arch under the mesh: expert weights shard their output axis
    and run the batched (expert-site) base path, while attn/mlp deltas
    dispatch through shard_map — tokens must still match single-device.
    (MoE expert-site deltas themselves are rejected by slot dispatch, so
    the tenant's moe subtree is pruned to None.)"""
    out = subproc("""
    import dataclasses
    import numpy as np, jax
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('qwen3-moe-30b-a3b')
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    (name, deltas, rep), = synth_tenants(cfg, base, 1, RATIO_SPECS[8], rng)
    deltas = dict(deltas, moe=None)   # expert-site deltas can't slot-dispatch

    def run(mesh):
        eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                               clock=VirtualClock(tick=0.01), mesh=mesh)
        eng.register_tenant(name, deltas, rep)
        reqs = [eng.submit(t, np.asarray(jax.random.randint(
                    jax.random.fold_in(rng, 60 + i), (6,), 0, cfg.vocab)),
                    max_new_tokens=4, arrival=0.0)
                for i, t in enumerate([name, None, name])]
        eng.run()
        return [r.output() for r in reqs]

    ref = run(None)
    got = run(make_serving_mesh(8))
    for a, b in zip(ref, got):
        assert (a == b).all(), (a.tolist(), b.tolist())
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # two full engine streams in a subprocess
def test_mesh_affinity_residency_token_identity(subproc):
    """Affinity admission + pre-decoded residency under a (2, 4) mesh:
    the sharded values path (value buffers output-column-sharded with
    the codes, per-pool segment blocks) must be token-identical to the
    single-device default path, and the value path must actually run
    (hit rate > 0, value steps > 0)."""
    out = subproc("""
    import numpy as np, jax
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock
    from repro.models import lm

    cfg = get_smoke_config('llama3.2-1b')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 3, RATIO_SPECS[32], rng)

    def run(mesh, **kw):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=32,
                               clock=VirtualClock(tick=0.01), mesh=mesh, **kw)
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        reqs = [eng.submit(f'tenant{i % 3}' if i % 4 else None,
                           np.asarray(jax.random.randint(
                               jax.random.fold_in(rng, 70 + i),
                               (4 + (i % 2) * 4,), 0, cfg.vocab)),
                           max_new_tokens=4, arrival=0.01 * i)
                for i in range(6)]
        m = eng.run()
        return [r.output() for r in reqs], m.report()

    ref, _ = run(None)
    got, rep = run(make_serving_mesh(8, data=2), admission='affinity',
                   residency_budget_bytes=64 << 20)
    for a, b in zip(ref, got):
        assert (a == b).all(), (a.tolist(), b.tolist())
    assert rep['residency']['value_steps'] > 0, rep['residency']
    assert rep['residency']['hit_rate'] > 0
    assert len(rep['unique_tenants_per_shard_mean']) == 2
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # two full engine streams in a subprocess
def test_ssm_arch_sharded_token_identity(subproc):
    """State-carrying mixer (exact-length buckets) also decodes token-
    identically under the mesh."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('mamba2-370m')
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 2, RATIO_SPECS[8], rng)

    def run(mesh):
        eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                               clock=VirtualClock(tick=0.01), mesh=mesh)
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        reqs = [eng.submit(f'tenant{i % 2}',
                           np.asarray(jax.random.randint(
                               jax.random.fold_in(rng, 50 + i), (6,), 0,
                               cfg.vocab)),
                           max_new_tokens=4, arrival=0.0) for i in range(3)]
        eng.run()
        return [r.output() for r in reqs]

    ref = run(None)
    got = run(make_serving_mesh(8))
    for a, b in zip(ref, got):
        assert (a == b).all(), (a.tolist(), b.tolist())
    print('OK')
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow  # ~30s data=2 drain/refill; multi-device CI + nightly run it
def test_data_sharded_kv_pools_and_engine_identity(subproc):
    """data=2 mesh serving end to end: slot rows shard over `data` in
    contiguous pools, SlotKVCache accounts per pool, inserts into one
    pool never disturb the other pool's rows (bit-exact), and a
    drain/refill trace through the data=2 engine is token-identical to
    the single-device data=1 engine — a freed slot's stale KV can never
    leak into another shard's decode."""
    out = subproc("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import cache_shardings, make_serving_mesh
    from repro.launch.serve import RATIO_SPECS, synth_tenants
    from repro.models import lm
    from repro.serve import ContinuousEngine
    from repro.serve.kv import SlotKVCache
    from repro.serve.scheduler import VirtualClock

    cfg = get_smoke_config('llama3.2-1b')
    mesh = make_serving_mesh(8, data=2)
    csh = cache_shardings(cfg, mesh, 4, 16)
    # slot rows shard over `data`: the batch axis of at least one KV
    # leaf carries the data axis
    assert any(getattr(s, 'spec', (None,))[0] == 'data'
               for s in jax.tree.leaves(csh)), 'no data-sharded slot rows'

    kv = SlotKVCache(cfg, 4, 16, shardings=csh, data_shards=2)
    before = jax.tree.map(np.asarray, kv.cache)

    def row(seed):
        rc = lm.init_cache(cfg, 1, 16)
        return jax.tree.map(
            lambda a: (a + seed).astype(a.dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, rc)

    kv.claim(3)                       # shard-1 pool (slots 2..3)
    kv.insert(3, row(1.0))
    assert kv.n_free_shard(0) == 2 and kv.n_free_shard(1) == 1
    after = jax.tree.map(np.asarray, kv.cache)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert (b[:3] == a[:3]).all()          # shard-0 pool + slot 2 untouched
    kv.release(3)
    assert kv.shard_occupancy() == [0.0, 0.0]

    # engine-level: drain a full wave, refill, diff vs data=1 single-device
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, 2, RATIO_SPECS[128], rng)

    def run(mesh_):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=64, mesh=mesh_,
                               clock=VirtualClock(tick=0.01))
        for name, deltas, rep in tenants:
            eng.register_tenant(name, deltas, rep)
        outs = []
        for wave in range(2):          # second wave reuses freed slots
            reqs = [eng.submit(f'tenant{i % 2}',
                               np.asarray(jax.random.randint(
                                   jax.random.fold_in(rng, 50 + 10 * wave + i),
                                   (4 + (i % 2) * 4,), 0, cfg.vocab)),
                               max_new_tokens=5, arrival=0.0)
                    for i in range(4)]
            eng.run()
            assert (eng._row == 0).all()       # freed slots parked on row 0
            outs += [r.output() for r in reqs]
        return outs, eng

    got, eng2 = run(make_serving_mesh(8, data=2))
    assert eng2.data == 2 and eng2.sched.data_shards == 2
    ref, _ = run(None)
    for a, b in zip(ref, got):
        assert (a == b).all(), (a.tolist(), b.tolist())
    print('OK')
    """, n_devices=8)
    assert "OK" in out
