import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines


@pytest.fixture
def delta():
    return jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 0.01


def test_magnitude_sparsity_and_selection(delta):
    out = baselines.magnitude(None, delta, alpha=8)
    frac = float((out != 0).mean())
    assert abs(frac - 1 / 8) < 0.01
    # kept entries are exactly the largest-|.| ones
    kept_min = float(jnp.abs(out[out != 0]).min())
    dropped_max = float(jnp.abs(delta[out == 0]).max())
    assert kept_min >= dropped_max - 1e-9


def test_dare_rescale(delta):
    out = baselines.dare(jax.random.PRNGKey(1), delta, alpha=4)
    frac = float((out != 0).mean())
    assert abs(frac - 0.25) < 0.03
    nz = out != 0
    np.testing.assert_allclose(np.asarray(out[nz]), np.asarray(delta[nz] * 4), rtol=1e-5)


def test_deltazip_sparsity_and_quant(delta):
    out = baselines.deltazip(None, delta, alpha=8, k_bits=4)
    # alpha_sparse = 8*4/16 = 2 -> half the entries kept per column
    frac = float((out != 0).mean())
    assert abs(frac - 0.5) < 0.05
    # values are quantized: per column, at most 16 levels per 128-row group
    col = np.asarray(out[:, 0])
    nz = col[col != 0]
    n_groups = out.shape[0] // 128
    assert len(np.unique(np.round(nz, 8))) <= 16 * n_groups + 1


def test_method_bits(delta):
    n = delta.size
    assert baselines.method_bits("dare", delta.shape, alpha=8) == pytest.approx(2 * n)
    assert baselines.method_bits("deltazip", delta.shape, alpha=8) == pytest.approx(2 * n)
    assert baselines.method_bits("magnitude", delta.shape, alpha=16) == pytest.approx(n)


def test_random_unbiased_magnitude_biased():
    """The mechanism behind the paper's Table 2 pattern (magnitude -> 0.00
    accuracy at high alpha, random survives): rescaled random dropout is an
    UNBIASED estimator of the delta contribution, while magnitude pruning
    systematically shrinks it (a coherent bias that compounds across layers
    when |delta| values are balanced, Fig. 4). Single-layer l2 alone does
    not capture this — accuracy does (benchmarks/table23_ultra.py)."""
    rng = jax.random.PRNGKey(3)
    h_in, h_out = 1024, 16
    # balanced delta: near-equal magnitudes with random signs (Fig. 4 shape)
    signs = jnp.sign(jax.random.normal(rng, (h_in, h_out)))
    mags = 0.01 + 0.001 * jax.random.normal(jax.random.fold_in(rng, 5), (h_in, h_out))
    d = signs * mags
    x = jax.random.normal(jax.random.fold_in(rng, 1), (8, h_in))
    y = x @ d
    alpha = 16.0

    from repro.core import groupwise_dropout_pack, reconstruct_dense
    # mean over seeds of the random estimator converges to y (unbiased);
    # residual noise after n draws ~ sqrt(alpha-1)/sqrt(n) = 0.34 at n=128
    acc = jnp.zeros_like(y)
    n = 128
    for s in range(n):
        p = groupwise_dropout_pack(jax.random.PRNGKey(s), d, h_g=128, alpha=alpha)
        acc = acc + x @ reconstruct_dense(p)
    bias_rand = float(jnp.linalg.norm(acc / n - y) / jnp.linalg.norm(y))

    y_mag = x @ baselines.magnitude(None, d, alpha=alpha)
    bias_mag = float(jnp.linalg.norm(y_mag - y) / jnp.linalg.norm(y))

    assert bias_rand < 0.5, bias_rand          # noise floor, shrinks as 1/sqrt(n)
    assert bias_mag > 0.8, bias_mag            # balanced |d| -> ~(1-1/a) lost
    assert bias_rand < bias_mag / 1.5
