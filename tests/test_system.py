"""End-to-end system test: the paper's full pipeline on a tiny model.

base pretrain -> SFT fine-tune -> delta -> DeltaDQ compress -> multi-tenant
serve -> the compressed tenant retains the fine-tuned capability (sorting
task accuracy) while the raw base model does not.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchConfig
from repro.core import DeltaDQSpec, compress
from repro.data import PretrainMixture, SortTask
from repro.data.pipeline import EOS, SEP
from repro.models import lm
from repro.optim import adamw, schedule
from repro.optim.adamw import AdamWConfig
from repro.serve import Engine
from repro.train import make_train_step

# full pretrain->SFT->compress->serve pipeline: minutes of CPU training
pytestmark = pytest.mark.slow

TINY = ArchConfig(
    name="tiny-sys", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, head_dim=16, d_ff=128, vocab=64, act="silu", tie_embeddings=True,
)


def _train(cfg, params, data, steps, lr=5e-3):
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=lr, weight_decay=0.0)))
    for i in range(steps):
        params, opt, m = step(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
    return params, float(m["loss"])


def _task_accuracy(engine: Engine, tenant, task: SortTask, n_batches=2) -> float:
    """Exact-match digit accuracy of generated completions."""
    correct = total = 0
    for s in range(n_batches):
        prompts, targets = task.prompts_at(100 + s)
        gen = engine.generate(tenant, prompts, max_new_tokens=task.n_digits)
        correct += (gen[:, :task.n_digits] == targets).sum()
        total += targets.size
    return correct / total


@pytest.fixture(scope="module")
def pipeline():
    """Paper regime: base knows the task FORMAT (random answers), SFT adds a
    small decisive delta — that is what makes aggressive dropout lossless."""
    from repro.data import FormatOnlyTask
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(TINY, rng)
    pre = PretrainMixture(vocab=TINY.vocab, seq_len=24, batch=16, seed=0)
    base, _ = _train(TINY, base, pre, 20)
    fmt = FormatOnlyTask(vocab=TINY.vocab, seq_len=24, batch=16, n_digits=4, seed=2)
    base, _ = _train(TINY, base, fmt, 120, lr=3e-3)

    task = SortTask(vocab=TINY.vocab, seq_len=24, batch=16, n_digits=4, seed=1)
    ft, ft_loss = _train(TINY, dict(jax.tree.map(lambda x: x, base)), task, 180, lr=1.5e-3)
    return base, ft, task, ft_loss


def test_sft_learned_task(pipeline):
    base, ft, task, ft_loss = pipeline
    assert ft_loss < 0.5  # fine-tune actually learned to sort


def test_full_deltadq_pipeline(pipeline):
    base, ft, task, _ = pipeline
    eng = Engine(TINY, base, max_seq=32)

    results = {}
    for name, spec in {
        "a2": DeltaDQSpec(alpha=2.0, k_bits=None, h_g=16),
        "a4_k8": DeltaDQSpec(alpha=4.0, k_bits=8, m=1, h_g=16),
    }.items():
        deltas, report = compress(base, ft, spec)
        eng.register_tenant(name, deltas, report)
        results[name] = _task_accuracy(eng, name, task)

    acc_base = _task_accuracy(eng, None, task)
    eng_ft = Engine(TINY, ft, max_seq=32)
    acc_ft = _task_accuracy(eng_ft, None, task)

    # fine-tuned model masters the task; base does not
    assert acc_ft > 0.85, acc_ft
    assert acc_base < 0.6, acc_base
    # compressed tenants retain most of the capability
    for name, acc in results.items():
        assert acc > 0.8 * acc_ft, (name, acc, acc_ft)

    rep = eng.memory_report()
    assert rep["delta_bytes_total"] < 2 * rep["base_bytes"]
