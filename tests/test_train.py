import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import PretrainMixture
from repro.models import lm
from repro.optim import adamw, schedule
from repro.optim.adamw import AdamWConfig
from repro.train import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-1b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    data = PretrainMixture(vocab=cfg.vocab, seq_len=32, batch=8)
    return cfg, params, data


def test_loss_decreases(setup):
    cfg, params, data = setup
    opt_cfg = AdamWConfig(lr=5e-3, schedule=schedule.cosine_with_warmup(3, 40))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatch_equivalence(setup):
    """n_micro=1 vs n_micro=4 give (nearly) identical updates."""
    cfg, params, data = setup
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = data.batch_at(0)
    outs = []
    for nm in (1, 4):
        opt = adamw.init(params)
        step = jax.jit(make_train_step(cfg, opt_cfg, n_micro=nm))
        p2, _, m = step(params, opt, batch, jax.random.PRNGKey(0))
        outs.append((p2, float(m["loss"])))
    # loss of n_micro=4 is the mean over chunks of per-chunk losses; grads equal
    flat1 = jax.tree.leaves(outs[0][0])
    flat4 = jax.tree.leaves(outs[1][0])
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-3)


@pytest.mark.slow  # full grad trace through every delta site (~27s)
def test_loss_differentiable_through_delta_path(setup):
    """grad through deltas= must work: the fusion-pinning barrier in
    apply_linear carries a straight-through VJP (regression: a bare
    optimization_barrier has no differentiation rule)."""
    from repro.core import DeltaDQSpec, compress
    cfg, params, data = setup
    ft = jax.tree.map(lambda p: p * 1.01 if p.ndim >= 2 else p, params)
    deltas, _ = compress(params, ft, DeltaDQSpec(alpha=4.0, k_bits=8, h_g=16))
    batch = data.batch_at(0)
    g = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, deltas=deltas)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0.0


def test_schedules():
    s = schedule.cosine_with_warmup(10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    inv = schedule.inverse_sqrt(16)
    assert float(inv(jnp.int32(4))) == pytest.approx(0.25)
    assert float(inv(jnp.int32(64))) == pytest.approx(0.5)


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_weight_decay_mask():
    from repro.optim.adamw import _decay_mask
    assert _decay_mask("attn/wq") == 1.0
    assert _decay_mask("attn/ln1") == 0.0
    assert _decay_mask("final_norm/scale") == 0.0
