"""Tracing + telemetry invariants (trace.py, telemetry.py, metrics glue).

Two layers:

* Pure-host tests (no jax): streaming histograms, SLO counters, the
  snapshot writer, Prometheus exposition, path attribution, and the
  tracer driven by a synthetic event stream — these pin the schema and
  the bounded-memory behavior.
* One engine integration fixture (smoke config, VirtualClock): a traced
  run whose exported Chrome trace must validate AND agree with the
  metrics report event-for-event — metrics and tracer consume the same
  bus, so any disagreement is a bug in one of them.

Everything runs on VirtualClock / explicit timestamps: no wall-clock
value reaches an assertion.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.serve.metrics import Metrics, TenantStats
from repro.serve.telemetry import (
    SLOCounters,
    StreamingHistogram,
    TelemetrySnapshotWriter,
    prometheus_text,
)
from repro.serve.trace import (
    EventBus,
    ServeEvent,
    Tracer,
    attribution,
    note_path,
    path_label,
    validate_chrome_trace,
)


# ---------------------------------------------------------------------------
# StreamingHistogram
# ---------------------------------------------------------------------------
def test_histogram_exact_below_cap_matches_numpy():
    h = StreamingHistogram()
    rng = np.random.RandomState(0)
    xs = rng.exponential(0.05, size=200)
    for x in xs:
        h.record(x)
    assert h.exact
    for q in (0, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q))
    assert h.mean == pytest.approx(xs.mean())
    assert h.n == 200
    assert h.vmin == xs.min() and h.vmax == xs.max()


def test_histogram_empty_matches_old_pct_contract():
    h = StreamingHistogram()
    assert h.percentile(50) is None
    assert h.mean is None
    assert h.n == 0


def test_histogram_spills_once_and_stays_bounded():
    h = StreamingHistogram(exact_cap=16)
    rng = np.random.RandomState(1)
    xs = rng.exponential(0.05, size=500)
    for x in xs:
        h.record(x)
    assert not h.exact                      # spilled past the cap
    assert h.n == 500
    assert int(h.counts.sum()) == 500       # every sample landed in a bucket
    # bucketed percentile: within one bucket ratio of the true value
    # (10^(1/5) ~ 1.58x), the documented bound
    for q in (50, 95):
        true = np.percentile(xs, q)
        got = h.percentile(q)
        assert true / 1.6 <= got <= true * 1.6
    # min/max/mean stay exact regardless of regime
    assert h.vmin == xs.min() and h.vmax == xs.max()
    assert h.mean == pytest.approx(xs.mean())


def test_histogram_bucket_layout_roundtrip():
    h = StreamingHistogram()
    # underflow, overflow, and a mid value land where bucket_le says
    assert h.bucket_index(0.0) == 0
    assert h.bucket_le(0) == h.lo
    assert math.isinf(h.bucket_le(h.n_buckets + 1))
    for x in (1e-5, 3e-3, 0.7, 42.0):
        i = h.bucket_index(x)
        assert h.bucket_le(i - 1) <= x <= h.bucket_le(i) * (1 + 1e-12)
    assert h.bucket_index(1e12) == h.n_buckets + 1    # overflow


def test_histogram_cumulative_is_prometheus_shaped():
    h = StreamingHistogram(exact_cap=4)
    for x in (0.001, 0.002, 0.004, 0.3, 0.3, 9.0):
        h.record(x)
    cum = h.cumulative()
    les = [le for le, _ in cum]
    counts = [c for _, c in cum]
    assert les == sorted(les)                         # le bounds ascend
    assert counts == sorted(counts)                   # cumulative ascends
    assert math.isinf(les[-1]) and counts[-1] == h.n  # +Inf terminal = count


def test_histogram_merge_exact_and_bucketed():
    a, b = StreamingHistogram(), StreamingHistogram()
    for x in (0.01, 0.02, 0.03):
        a.record(x)
    for x in (0.04, 0.05):
        b.record(x)
    m = a.merge(b)
    assert m.n == 5 and m.exact
    assert m.percentile(50) == pytest.approx(
        np.percentile([0.01, 0.02, 0.03, 0.04, 0.05], 50))
    # exact + bucketed pools into buckets, counts conserved
    c = StreamingHistogram(exact_cap=2)
    for x in (0.1, 0.2, 0.4):
        c.record(x)
    assert not c.exact
    m2 = a.merge(c)
    assert m2.n == 6 and not m2.exact
    assert int(m2.bucket_counts().sum()) == 6
    with pytest.raises(ValueError):
        a.merge(StreamingHistogram(per_decade=3))
    # merged() of nothing is a valid empty histogram
    assert StreamingHistogram.merged([]).percentile(50) is None


def test_histogram_to_dict_is_json_able():
    h = StreamingHistogram()
    h.record(0.5)
    d = h.to_dict()
    json.dumps(d)
    assert d["count"] == 1 and d["p50"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# SLO counters
# ---------------------------------------------------------------------------
def _ev(kind, t=0.0, **attrs):
    return ServeEvent(kind, t, attrs)


def test_slo_counters():
    slo = SLOCounters(ttft_target_s=0.1, itl_target_s=0.01)
    # TTFT violation for t0, within target for t1
    slo.consume(_ev("first_token", tenant="t0", ttft=0.5))
    slo.consume(_ev("first_token", tenant="t1", ttft=0.05))
    # deadline miss (negative slack), ITL violation: (1.0-0.5)/(6-1)=0.1
    slo.consume(_ev("done", tenant="t0", latency=1.0, ttft=0.5,
                    n_tokens=6, deadline_slack=-0.2))
    # no deadline -> never a miss; single token -> no ITL
    slo.consume(_ev("done", tenant="t1", latency=0.06, ttft=0.05,
                    n_tokens=1, deadline_slack=None))
    rep = slo.report()
    assert rep["requests_done"] == 2
    assert rep["ttft_violations"] == {"t0": 1}
    assert rep["deadline_misses"] == {"t0": 1}
    assert rep["itl_violations"] == {"t0": 1}


def test_slo_counters_disabled_targets_count_nothing():
    slo = SLOCounters()                     # no targets configured
    slo.consume(_ev("first_token", tenant="t0", ttft=99.0))
    slo.consume(_ev("done", tenant=None, latency=99.0, ttft=1.0,
                    n_tokens=50, deadline_slack=0.5))
    rep = slo.report()
    assert rep["ttft_violations"] == {} and rep["itl_violations"] == {}
    assert rep["deadline_misses"] == {}     # positive slack


# ---------------------------------------------------------------------------
# Snapshot writer
# ---------------------------------------------------------------------------
def test_snapshot_writer_interval_and_atomicity(tmp_path):
    path = str(tmp_path / "telemetry.json")
    w = TelemetrySnapshotWriter(path, interval_s=1.0)
    calls = []

    def payload():
        calls.append(1)
        return {"metrics": {"x": 1, "hist": _hist_with(0.5)}}

    assert w.maybe_write(0.0, payload)          # first call always writes
    assert not w.maybe_write(0.5, payload)      # inside interval: skipped
    assert len(calls) == 1                      # payload built lazily
    assert w.maybe_write(1.0, payload)
    with open(path) as f:
        snap = json.load(f)
    assert snap["t"] == 1.0 and snap["seq"] == 1
    assert snap["metrics"]["hist"]["count"] == 1   # histogram serialized
    assert not os.path.exists(path + ".tmp")       # rename completed
    with pytest.raises(ValueError):
        TelemetrySnapshotWriter(path, interval_s=0.0)


def _hist_with(*xs):
    h = StreamingHistogram()
    for x in xs:
        h.record(x)
    return h


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------
def test_prometheus_text_shape():
    m = Metrics(n_slots=4)
    bus = EventBus([m])
    bus.emit("start", 0.0)
    bus.emit("admit", 0.1, tenant="t0", wait=0.1)
    bus.emit("first_token", 0.2, tenant="t0", ttft=0.2)
    bus.emit("token", 0.2, tenant="t0")
    bus.emit("step", 0.3, n_active=1, path="segments-xla+packed")
    bus.emit("done", 0.4, tenant="t0", latency=0.4)
    bus.emit("stop", 0.5)
    slo = SLOCounters(ttft_target_s=0.1)
    slo.consume(_ev("first_token", tenant="t0", ttft=0.2))
    text = prometheus_text(m, slo)
    assert 'repro_serve_requests_total{tenant="t0"} 1' in text
    assert 'repro_serve_tokens_total{tenant="t0"} 1' in text
    assert ('repro_serve_decode_path_steps_total'
            '{path="segments-xla+packed"} 1') in text
    assert 'le="+Inf"}' in text                       # histogram terminal
    assert 'repro_serve_ttft_seconds_count{tenant="t0"} 1' in text
    assert 'repro_serve_ttft_violations_total{tenant="t0"} 1' in text
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# Path attribution
# ---------------------------------------------------------------------------
def test_note_path_noop_without_context():
    note_path("anywhere", formulation="x")            # must not raise


def test_attribution_collects_dedups_and_nests():
    with attribution() as outer:
        note_path("a", formulation="gather")
        note_path("a", formulation="gather")          # duplicate dropped
        with attribution() as inner:
            note_path("b", formulation="dense")
        assert inner == [{"site": "b", "formulation": "dense"}]
        note_path("c")
    assert outer == [{"site": "a", "formulation": "gather"}, {"site": "c"}]
    note_path("after")                                # context restored to None


def test_path_label():
    assert path_label([]) == "unknown"
    assert path_label([{"site": "s", "formulation": "segments-pallas"},
                       {"site": "r", "residency": "values"}]) \
        == "segments-pallas+values"
    assert path_label([{"site": "s", "formulation": "a"},
                       {"site": "t", "formulation": "a"},
                       {"site": "u", "formulation": "b"}]) == "a+b"
    assert path_label([{"site": "s", "dispatch": "segments"}]) == "unknown"


# ---------------------------------------------------------------------------
# Tracer on a synthetic event stream
# ---------------------------------------------------------------------------
def _lifecycle(bus, rid, tenant, t0, *, n_tokens=3):
    """One full request lifecycle offset to t0; returns finish time."""
    bus.emit("submit", t0, rid=rid, tenant=tenant, prompt_len=5)
    bus.emit("admit", t0 + 0.01, rid=rid, tenant=tenant, slot=0,
             wait=0.01, deadline_slack=1.0, prompt_len=5, bucket=8)
    bus.emit("prefill", t0 + 0.02, t_start=t0 + 0.01, rid=rid,
             tenant=tenant, prompt_len=5, bucket=8, slot=0)
    bus.emit("first_token", t0 + 0.02, rid=rid, tenant=tenant, ttft=0.02)
    t = t0 + 0.02
    for _ in range(n_tokens - 1):
        t += 0.01
        bus.emit("step", t, t_start=t - 0.01, n_active=1,
                 path="segments-xla+packed", recompiled=False)
        bus.emit("token", t, rid=rid, tenant=tenant)
    bus.emit("done", t, rid=rid, tenant=tenant, latency=t - t0,
             ttft=0.02, n_tokens=n_tokens, deadline_slack=0.5)
    return t


def test_tracer_builds_valid_chrome_trace(tmp_path):
    tr = Tracer()
    bus = EventBus([tr])
    bus.emit("start", 0.0)
    _lifecycle(bus, rid=1, tenant="t0", t0=0.0)
    _lifecycle(bus, rid=2, tenant=None, t0=0.05)
    bus.emit("jit_trace", 0.01, signature=("decode", True, False),
             site="decode", first=True, notes=[{"site": "x"}])
    bus.emit("jit_trace", 0.06, signature=("decode", True, True),
             site="decode", first=False, notes=[])
    bus.emit("stop", 1.0)

    trace = tr.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    assert tr.n_request_spans == 2
    names = [e["name"] for e in trace["traceEvents"]]
    assert names.count("request") == 2
    assert names.count("queue_wait") == 2
    assert names.count("prefill") == 2
    assert names.count("decode") == 2
    assert "jit_compile" in names and "jit_recompile" in names
    # request span args carry the SLO-relevant fields
    req = next(e for e in trace["traceEvents"] if e["name"] == "request")
    assert req["args"]["deadline_slack_s"] == 0.5
    assert req["args"]["tokens"] == 3
    # export + CLI validator agree
    out = str(tmp_path / "trace.json")
    tr.export(out)
    from repro.serve.trace import _main
    assert _main(["--validate", out]) == 0


def test_tracer_step_sampling_and_event_cap():
    tr = Tracer(step_sample=2)
    bus = EventBus([tr])
    for i in range(6):
        bus.emit("step", 0.01 * (i + 1), t_start=0.01 * i, n_active=1)
    steps = [e for e in tr.events if e["name"] == "decode_step"]
    assert len(steps) == 3                      # every 2nd kept
    with pytest.raises(ValueError):
        Tracer(step_sample=0)

    capped = Tracer(max_events=2)
    bus = EventBus([capped])
    for i in range(5):
        bus.emit("step", 0.01 * (i + 1), t_start=0.01 * i, n_active=1)
    _lifecycle(bus, rid=1, tenant="t0", t0=1.0)     # past the cap
    assert capped.dropped_events >= 3
    # request lifecycle spans still record past the cap
    assert capped.n_request_spans == 1
    assert capped.to_chrome_trace()["otherData"]["dropped_events"] >= 3


def test_validator_catches_structural_problems():
    assert validate_chrome_trace({}) == ["traceEvents missing or empty"]
    # spans but no request span
    bad = {"traceEvents": [
        {"name": "decode_step", "ph": "X", "pid": 2, "tid": 0,
         "ts": 0.0, "dur": 1.0, "args": {}}]}
    assert any("no request spans" in p for p in validate_chrome_trace(bad))
    # request span without child prefill+decode
    lonely = {"traceEvents": [
        {"name": "request", "ph": "X", "pid": 1, "tid": 1,
         "ts": 0.0, "dur": 5.0, "args": {}}]}
    assert any("child prefill+decode" in p
               for p in validate_chrome_trace(lonely))
    # non-monotonic timestamps
    shuffled = {"traceEvents": [
        {"name": "a", "ph": "i", "pid": 1, "tid": 1, "ts": 5.0, "s": "t"},
        {"name": "b", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0, "s": "t"}]}
    assert any("monotonic" in p for p in validate_chrome_trace(shuffled))
    # negative ts
    neg = {"traceEvents": [
        {"name": "a", "ph": "i", "pid": 1, "tid": 1, "ts": -1.0, "s": "t"}]}
    assert any("bad ts" in p for p in validate_chrome_trace(neg))


def test_cli_validator_rejects_garbage(tmp_path):
    from repro.serve.trace import _main
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert _main(["--validate", str(bad)]) == 1
    empty = tmp_path / "empty.json"
    empty.write_text("{}")
    assert _main(["--validate", str(empty)]) == 1


# ---------------------------------------------------------------------------
# Metrics edge cases
# ---------------------------------------------------------------------------
def test_metrics_empty_run_report():
    m = Metrics(n_slots=4)
    rep = m.report()
    assert rep["wall_time_s"] == 0.0
    assert rep["tokens_per_sec"] is None
    assert rep["ttft_p50"] is None
    assert rep["batch_occupancy"] is None
    assert rep["decode_paths"] is None
    assert rep["tenants"] == {}


def test_metrics_wall_clamp_never_negative():
    m = Metrics(n_slots=1)
    m.start(10.0)
    m.stop(3.0)                                # stale t_end from a reset
    assert m.report()["wall_time_s"] == 0.0


def test_metrics_shard_token_range_guard():
    m = Metrics(n_slots=4, data_shards=2)
    m.record_shard_token(1)
    with pytest.raises(ValueError, match=r"shard 2 out of range for 2"):
        m.record_shard_token(2)
    with pytest.raises(ValueError, match="out of range"):
        m.record_shard_token(-1)
    assert m.shard_tokens == [0, 1]


def test_metrics_ragged_shard_rows_raise():
    m = Metrics(n_slots=4, data_shards=2)
    with pytest.raises(ValueError, match="shard_active has 3 entries"):
        m.record_step(2, shard_active=[1, 1, 1])
    with pytest.raises(ValueError, match="shard_unique has 1 entries"):
        m.record_step(2, shard_active=[1, 1], shard_unique=[1])
    # nothing partial leaked into the step matrices
    assert m.step_shard_unique == []


def test_metrics_consume_maps_event_stream():
    m = Metrics(n_slots=2, data_shards=2)
    bus = EventBus([m])
    bus.emit("start", 0.0)
    bus.emit("admit", 0.1, tenant="t0", wait=0.1)
    bus.emit("first_token", 0.2, tenant="t0", ttft=0.2)
    bus.emit("token", 0.2, tenant="t0")
    bus.emit("step", 0.3, n_active=2, shard_active=[1, 1],
             shard_unique=[1, 0], residency_used=True, path="p")
    bus.emit("shard_token", 0.3, shard=1)
    bus.emit("jit_trace", 0.3, signature="s", site="decode", first=True)
    bus.emit("done", 0.4, tenant="t0", latency=0.4)
    bus.emit("stop", 1.0)
    rep = m.report()
    assert rep["wall_time_s"] == 1.0
    assert rep["prefills"] == 1 and rep["decode_steps"] == 1
    assert rep["decode_paths"] == {"p": 1}
    assert rep["residency"]["value_steps"] == 1
    assert rep["unique_tenants_per_shard_mean"] == [1.0, 0.0]
    assert m.shard_tokens == [0, 1]
    assert m.jit_traces == 1
    assert rep["tenants"]["t0"]["ttft_p50"] == pytest.approx(0.2)


def test_tenant_stats_report_keys_backward_compatible():
    t = TenantStats()
    t.n_requests, t.n_tokens = 1, 4
    t.ttfts.record(0.2)
    t.queue_waits.record(0.1)
    t.latencies.record(0.4)
    rep = t.report(wall=2.0)
    assert set(rep) == {"requests", "tokens", "tokens_per_sec", "ttft_p50",
                        "ttft_p95", "queue_wait_p50", "latency_p50",
                        "latency_p95"}
    assert rep["tokens_per_sec"] == 2.0


# ---------------------------------------------------------------------------
# Engine integration: trace <-> metrics consistency under VirtualClock
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp  # noqa: F401
    from repro.configs import get_smoke_config
    from repro.core import DeltaDQSpec, compress
    from repro.models import lm
    from repro.serve import ContinuousEngine, VirtualClock

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.fold_in(rng, 7), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32))

    out_dir = tmp_path_factory.mktemp("traced")
    tracer = Tracer()
    slo = SLOCounters(ttft_target_s=1e-9)     # everything violates: countable
    telem = TelemetrySnapshotWriter(str(out_dir / "telemetry.json"),
                                    interval_s=1e-4)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3),
                           trace=tracer, slo=slo, telemetry=telem)
    eng.register_tenant("t0", deltas)
    reqs = [eng.submit(t, np.arange(5 + i) % cfg.vocab, max_new_tokens=4,
                       arrival=0.001 * i, deadline=0.002 * i)
            for i, t in enumerate(("t0", None, "t0"))]
    metrics = eng.run()
    return eng, tracer, slo, telem, metrics.report(), reqs, out_dir


def test_traced_engine_trace_validates_and_matches_metrics(traced_run):
    eng, tracer, slo, telem, rep, reqs, out_dir = traced_run
    trace = tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []

    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)

    # one source of truth: span counts == metrics counts
    assert tracer.n_request_spans == len(reqs) == rep["prefills"]
    assert len(by_name["request"]) == len(reqs)
    assert len(by_name["prefill"]) == len(reqs)
    assert len(by_name["decode"]) == len(reqs)
    assert len(by_name["decode_step"]) == rep["decode_steps"]
    # every generated token is attributed: request spans' token args sum
    # to the metrics total
    assert sum(e["args"]["tokens"] for e in by_name["request"]) \
        == rep["total_tokens"]
    # decode-path attribution resolved to a real label on every step
    assert rep["decode_paths"] is not None
    assert "unknown" not in rep["decode_paths"]
    assert sum(rep["decode_paths"].values()) == rep["decode_steps"]
    # step spans carry the same label(s) the metrics counted
    step_paths = {e["args"]["path"] for e in by_name["decode_step"]}
    assert step_paths <= set(rep["decode_paths"]) | {"base"}


def test_traced_engine_is_deterministic_on_virtual_clock(traced_run):
    """Same workload, fresh engine, same VirtualClock -> byte-identical
    trace JSON (the CI determinism contract for traces)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp  # noqa: F401
    from repro.configs import get_smoke_config
    from repro.core import DeltaDQSpec, compress
    from repro.models import lm
    from repro.serve import ContinuousEngine, VirtualClock

    eng0, tracer0 = traced_run[0], traced_run[1]
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.fold_in(rng, 7), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32))
    tracer = Tracer()
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3), trace=tracer)
    eng.register_tenant("t0", deltas)
    for i, t in enumerate(("t0", None, "t0")):
        eng.submit(t, np.arange(5 + i) % cfg.vocab, max_new_tokens=4,
                   arrival=0.001 * i, deadline=0.002 * i)
    eng.run()
    assert json.dumps(tracer.to_chrome_trace(), sort_keys=True) \
        == json.dumps(tracer0.to_chrome_trace(), sort_keys=True)


def test_traced_engine_slo_and_snapshots(traced_run):
    eng, tracer, slo, telem, rep, reqs, out_dir = traced_run
    # ttft target of 1ns: every request must have violated
    srep = slo.report()
    assert srep["requests_done"] == len(reqs)
    assert sum(srep["ttft_violations"].values()) == len(reqs)
    # deadlines were in the past relative to finish -> misses counted
    assert sum(srep["deadline_misses"].values()) >= 1
    # snapshots were written during run() on engine time
    assert telem.n_written >= 1
    with open(os.path.join(str(out_dir), "telemetry.json")) as f:
        snap = json.load(f)
    assert set(snap) >= {"t", "seq", "metrics", "slo"}
    assert snap["metrics"]["decode_steps"] <= rep["decode_steps"]


def test_reset_metrics_preserves_shards_and_rewires_bus(traced_run):
    eng = traced_run[0]
    old_metrics, shards = eng.metrics, eng.metrics.data_shards
    eng.reset_metrics()
    assert eng.metrics is not old_metrics
    assert eng.metrics.data_shards == shards
    assert eng.metrics.n_decode_steps == 0
    # the bus now feeds the NEW collector (and still the tracer/slo)
    assert eng.metrics in eng.bus.consumers
    assert old_metrics not in eng.bus.consumers
    assert eng.trace in eng.bus.consumers
    assert eng.slo in eng.bus.consumers
