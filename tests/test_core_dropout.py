import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    bernoulli_dropout_dense,
    groupwise_dropout_mask,
    groupwise_dropout_pack,
    reconstruct_dense,
)


def test_exact_keep_count_per_group():
    rng = jax.random.PRNGKey(0)
    d = jax.random.normal(rng, (256, 32))
    p = groupwise_dropout_pack(rng, d, h_g=64, alpha=8)
    dense = np.asarray(reconstruct_dense(p))
    nz = (dense.reshape(4, 64, 32) != 0).sum(axis=1)
    assert nz.min() == nz.max() == 8  # exactly h_g/alpha survivors per group


def test_mask_exact_count():
    m = groupwise_dropout_mask(jax.random.PRNGKey(1), 128, 16, 32, 4.0)
    counts = np.asarray(m).reshape(4, 32, 16).sum(axis=1)
    assert (counts == 8).all()


def test_rescale_unbiased():
    """E[compressed] == delta elementwise (alpha rescale).

    Per-element std of one draw is 3*sqrt(alpha-1); after n draws it is
    3*sqrt(3)/sqrt(n). Check the grand mean tightly and elements at 5 sigma.
    """
    rng = jax.random.PRNGKey(2)
    d = jnp.ones((64, 8)) * 3.0
    acc = jnp.zeros_like(d)
    n = 200
    for i in range(n):
        p = groupwise_dropout_pack(jax.random.fold_in(rng, i), d, h_g=16, alpha=4)
        acc = acc + reconstruct_dense(p)
    mean = np.asarray(acc / n)
    sigma = 3.0 * np.sqrt(3.0) / np.sqrt(n)
    assert abs(mean.mean() - 3.0) < 4 * sigma / np.sqrt(mean.size)
    assert np.abs(mean - 3.0).max() < 5 * sigma


def test_matches_bernoulli_variant_layer_error():
    """Exact-count structured dropout == paper's Bernoulli mask statistically:
    layer-wise output error within 10% across seeds."""
    rng = jax.random.PRNGKey(3)
    h_in, h_out, t = 512, 64, 32
    d = jax.random.normal(rng, (h_in, h_out)) * 0.01
    x = jax.random.normal(jax.random.fold_in(rng, 9), (t, h_in))
    y = x @ d

    def err_exact(seed):
        p = groupwise_dropout_pack(jax.random.PRNGKey(seed), d, h_g=h_in, alpha=8)
        return float(jnp.linalg.norm(x @ reconstruct_dense(p) - y))

    def err_bern(seed):
        dd = bernoulli_dropout_dense(jax.random.PRNGKey(seed + 1000), d, alpha=8)
        return float(jnp.linalg.norm(x @ dd - y))

    e1 = np.mean([err_exact(s) for s in range(20)])
    e2 = np.mean([err_bern(s) for s in range(20)])
    assert abs(e1 - e2) / e2 < 0.1


def test_full_output_error_small():
    """The paper's losslessness argument: the delta contribution is small
    next to the base output, and the dropout error is zero-mean — so the
    error of the FULL layer output x(W_b + d_hat) vs x(W_b + d) is tiny even
    at alpha=8, while the delta-only relative error is ~sqrt(alpha-1)."""
    rng = jax.random.PRNGKey(4)
    h_in = 1024
    w_b = jax.random.normal(jax.random.fold_in(rng, 2), (h_in, 16)) * 0.05
    d = jax.random.normal(rng, (h_in, 16)) * 0.002   # SFT-scale delta
    x = jax.random.normal(jax.random.fold_in(rng, 1), (8, h_in))
    p = groupwise_dropout_pack(rng, d, h_g=64, alpha=8)
    y_full = x @ (w_b + d)
    y_hat = x @ (w_b + reconstruct_dense(p))
    rel_full = float(jnp.linalg.norm(y_full - y_hat) / jnp.linalg.norm(y_full))
    rel_delta = float(jnp.linalg.norm(x @ d - x @ reconstruct_dense(p)) /
                      jnp.linalg.norm(x @ d))
    assert rel_full < 0.25, rel_full
    assert rel_delta > 1.0  # delta-only error is large; full output is not


def test_bad_args():
    d = jnp.zeros((64, 8))
    with pytest.raises(ValueError):
        groupwise_dropout_pack(jax.random.PRNGKey(0), d, h_g=48, alpha=8)
    with pytest.raises(ValueError):
        groupwise_dropout_pack(jax.random.PRNGKey(0), d, h_g=4, alpha=8.0)
