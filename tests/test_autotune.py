"""Autotune table: lookup semantics, persistence round-trip, ops consult."""
import json
import os

import numpy as np
import pytest

import jax

from repro.core import groupwise_dropout_pack
from repro.kernels import autotune, ops, ref


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune.invalidate_cache()
    yield
    autotune.invalidate_cache()


def test_lookup_defaults_without_table(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(tmp_path / "missing.json"))
    got = autotune.lookup(64, 8, 4, 128, 256)
    assert got == autotune.DEFAULTS


def test_lookup_merges_partial_entry(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    key = autotune.envelope_key(64, 8, 4, 128, 256)
    path.write_text(json.dumps(
        {"version": 2, "entries": {key: {"gather_max_t": 32}}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    got = autotune.lookup(64, 8, 4, 128, 256)
    assert got["gather_max_t"] == 32
    assert got["tb"] == autotune.DEFAULTS["tb"]       # filled from defaults
    # unknown envelope point -> pure defaults
    assert autotune.lookup(16, 2, 1, 32, 64) == autotune.DEFAULTS


def test_envelope_key_none_bits():
    assert autotune.envelope_key(16, 2, None, 64, 128) == "16/2/None/64/128"


def test_snap_t_grid():
    assert autotune.snap_t(1) == 1
    assert autotune.snap_t(5) == 8
    assert autotune.snap_t(16) == 16
    assert autotune.snap_t(17) == 32
    assert autotune.snap_t(10_000) == autotune.T_GRID[-1]


def test_envelope_key_with_t():
    got = autotune.envelope_key(64, 8, 4, 128, 256, t=13)
    assert got == "64/8/4/128/256@T16"


def test_lookup_t_overlay_tiles_only(tmp_path, monkeypatch):
    """A v3 ``@T`` entry overlays kernel tiles only; ``gather_max_t``
    always comes from the base entry so the formulation threshold stays
    one monotone function of T (the identity contract)."""
    path = tmp_path / "table.json"
    base = autotune.envelope_key(64, 8, 4, 128, 256)
    ov = autotune.envelope_key(64, 8, 4, 128, 256, t=16)
    path.write_text(json.dumps({"version": 3, "entries": {
        base: {"tb": 128, "ob": 128, "kc": 8, "gather_max_t": 64},
        ov: {"tb": 32, "formulation": "gather", "gather_us": 1.0,
             "dense_us": 9.0, "gather_max_t": 7}}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    got = autotune.lookup(64, 8, 4, 128, 256, t=13)   # snaps to @T16
    assert got["tb"] == 32                            # per-T tile wins
    assert got["ob"] == 128                           # base fills the rest
    assert got["gather_max_t"] == 64   # overlay must NOT move the crossover
    # no overlay swept at this T -> pure base entry
    assert autotune.lookup(64, 8, 4, 128, 256, t=256)["tb"] == 128


def test_lookup_floors_gather_max_t(tmp_path, monkeypatch):
    """Identity floor: decode-sized batches keep the gather formulation
    (the segment dispatch always gathers) even if a stale or hand-edited
    table stores a lower crossover."""
    path = tmp_path / "table.json"
    key = autotune.envelope_key(64, 8, 4, 128, 256)
    path.write_text(json.dumps(
        {"version": 3, "entries": {key: {"gather_max_t": 4}}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    got = autotune.lookup(64, 8, 4, 128, 256)
    assert got["gather_max_t"] == autotune.MIN_GATHER_T


def test_corrupt_table_falls_back(tmp_path, monkeypatch):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    assert autotune.lookup(64, 8, 4, 128, 256) == autotune.DEFAULTS


def test_committed_table_loads():
    """The checked-in table must parse and yield complete entries."""
    assert os.path.exists(autotune.DEFAULT_TABLE_PATH), \
        "results/autotune_kernels.json missing (regenerate with " \
        "python -m repro.kernels.autotune)"
    entries = autotune.load_table(autotune.DEFAULT_TABLE_PATH)
    assert entries, "committed autotune table has no entries"
    for key, entry in entries.items():
        got = {**autotune.DEFAULTS, **entry}
        assert set(got) >= set(autotune.DEFAULTS), key


def test_ops_respects_tuned_tiles(tmp_path, monkeypatch):
    """A tuned (tb, ob) must flow into the kernel launch and still be
    numerically correct (padding handles non-divisible tiles)."""
    rng = jax.random.PRNGKey(0)
    delta = jax.random.normal(rng, (128, 192)) * 0.01
    p = groupwise_dropout_pack(rng, delta, h_g=64, alpha=8, k_bits=4)
    path = tmp_path / "table.json"
    key = autotune.envelope_key(p.h_g, p.keep, p.k_bits, p.h_in, p.h_out)
    path.write_text(json.dumps(
        {"version": 2,
         "entries": {key: {"tb": 32, "ob": 64, "kc": 4,
                           "gather_max_t": 4}}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    autotune.invalidate_cache()
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 128))
    got = ops.delta_spmm(x, p, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.delta_spmm_ref(x, p)),
                               atol=1e-4, rtol=1e-4)
    # explicit arguments override the table
    got2 = ops.delta_spmm(x, p, tb=16, ob=192, interpret=True)
    np.testing.assert_allclose(np.asarray(got2),
                               np.asarray(ref.delta_spmm_ref(x, p)),
                               atol=1e-4, rtol=1e-4)


def test_col_tile_prefers_divisors():
    """Benign non-divisible h_out runs unpadded on a divisor tile (the
    fused kernel would otherwise copy-pad the whole base matrix); only
    prime-ish h_out falls back to pad-to-pow2."""
    from repro.kernels.ops import _col_tile
    assert _col_tile(256, 128) == 128     # divides: use the tuned tile
    assert _col_tile(96, 128) == 96       # divisor tile, no padding
    assert _col_tile(40, 64) == 40
    assert _col_tile(192, 128) == 96      # largest divisor <= cap
    assert 251 % _col_tile(251, 128) != 0  # prime: pad-and-slice path
    assert _col_tile(251, 128) >= 32


def test_decode_tile_accounting():
    """Unique-tenant dedup in numbers: dup batches decode fewer tiles."""
    from repro.serve.scheduler import tenant_segments
    dup = tenant_segments(np.array([1, 1, 1, 2, 1, 1, 2, 1], np.int32))
    distinct = tenant_segments(np.arange(1, 9).astype(np.int32))
    kw = dict(n_groups=2, h_out=256, tb=8, ob=128)
    per_row = ops.per_row_decode_tiles(8, n_groups=2, h_out=256, ob=128)
    assert ops.segment_decode_tiles(dup.seg_offsets, **kw) == per_row // 4
    assert ops.segment_decode_tiles(distinct.seg_offsets, **kw) == per_row
