import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly on bare CPU containers
from hypothesis import given, settings, strategies as st

from repro.core import quant


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_quant_dequant_bounds(k):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.02
    q, qp = quant.quantize(x, k)
    assert int(q.min()) >= 0 and int(q.max()) <= 2**k - 1
    err = jnp.max(jnp.abs(quant.dequantize(q, qp) - x))
    # uniform quant error bounded by one step
    assert float(err) <= float(qp.scale) * 1.01


def test_quant_lead_dims_independent_scales():
    noise = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    x = jnp.stack([noise[0] * 0.001, noise[1] * 10.0])  # spans differ 1e4x
    q, qp = quant.quantize(x, 4, lead_dims=1)
    assert qp.scale.shape == (2,)
    assert float(qp.scale[0]) * 100 < float(qp.scale[1])


@pytest.mark.parametrize("k,m", [(4, 1), (4, 4), (4, 16), (8, 8), (2, 2), (1, 1)])
def test_separate_quantization_invertible(k, m):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(0, 2**k, (33, 17)), jnp.int32)
    pid, low = quant.decompose(q, k, m)
    assert int(low.max()) <= 2**k // m - 1
    back = quant.recompose(pid, low, k, m)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_compression_ratio_paper_settings():
    # paper: 8x dropout + k=4, m=8 -> 1-bit storage -> 128x
    assert quant.compression_ratio(8, 4, 8) == pytest.approx(128.0)
    # 32x dropout + k=4, m=8 -> 512x (WizardMath-70B row)
    assert quant.compression_ratio(32, 4, 8) == pytest.approx(512.0)
    # dropout only
    assert quant.compression_ratio(16, None) == 16.0
    # degenerate "-" row: m == 2^k
    assert quant.compression_ratio(8, 4, 16) == float("inf")


@settings(max_examples=30, deadline=None)
@given(k=st.sampled_from([1, 2, 4, 8]),
       n=st.integers(1, 40), cols=st.integers(1, 7))
def test_pack_unpack_roundtrip(k, n, cols):
    rng = np.random.default_rng(n * 8 + k)
    q = jnp.asarray(rng.integers(0, 2**k, (n, cols)), jnp.int32)
    packed = quant.pack_bits(q, k, axis=0)
    assert packed.dtype == jnp.uint8
    assert packed.shape[0] == quant.packed_len(n, k)
    back = quant.unpack_bits(packed, k, n, axis=0)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_pack_axis1():
    q = jnp.asarray(np.random.default_rng(0).integers(0, 4, (3, 9, 5)), jnp.int32)
    packed = quant.pack_bits(q, 2, axis=1)
    back = quant.unpack_bits(packed, 2, 9, axis=1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))
