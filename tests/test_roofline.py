import pytest

from repro.roofline import analysis as rl

HLO = """
ENTRY %main {
  %p0 = bf16[128,1024]{1,0} parameter(0)
  %ag = bf16[128,16384]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={1}
  %ar = f32[256]{0} all-reduce(%x), to_apply=%add
  %ars = f32[1024,8]{1,0} all-reduce-start(%y), to_apply=%add
  %ard = f32[1024,8]{1,0} all-reduce-done(%ars)
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[32,32]{1,0} all-to-all(%w), dimensions={0}
  %cp = u8[1000]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_parser():
    out = rl.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-gather"] == 128 * 16384 * 2
    # all-reduce + all-reduce-start counted once each; -done skipped
    assert b["all-reduce"] == 256 * 4 + 1024 * 8 * 4
    assert b["reduce-scatter"] == 64 * 4
    assert b["all-to-all"] == 32 * 32 * 2
    assert b["collective-permute"] == 1000
    assert out["counts"]["all-reduce"] == 2


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(flops=197e12, bytes_accessed=819e9 / 2, coll_bytes=0,
                    model_flops=98.5e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.useful_flops_frac == pytest.approx(0.5)
    assert r.roofline_frac == pytest.approx(0.5)

    r2 = rl.Roofline(flops=1e12, bytes_accessed=819e9, coll_bytes=100e9,
                     model_flops=1e12)
    assert r2.bottleneck == "collective"
    assert r2.t_collective == pytest.approx(2.0)


def test_model_flops_convention():
    # train: 6ND, inference: 2ND (active params for MoE)
    assert rl.model_flops_for("train", 10, 10, 100, 1) == 6000
    assert rl.model_flops_for("decode", 10, 4, 100, 2) == 400
