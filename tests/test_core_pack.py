import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PackedDelta,
    from_storage_parts,
    groupwise_dropout_pack,
    reconstruct_dense,
    to_storage_parts,
)


def _pack(h_in=256, h_out=32, h_g=64, alpha=8, k=4, m=4, seed=0):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_in, h_out)) * 0.01
    return groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=k, m=m)


@pytest.mark.parametrize("k,m", [(4, 1), (4, 4), (4, 8), (8, 8), (2, 2), (1, 1)])
def test_storage_parts_roundtrip(k, m):
    p = _pack(k=k, m=m)
    parts = to_storage_parts(p)
    assert len(parts) == m
    # supports are disjoint and complete
    total = sum(len(q.low_codes) for q in parts)
    assert total == p.nnz
    p2 = from_storage_parts(parts, h_in=p.h_in, h_out=p.h_out, h_g=p.h_g,
                            keep=p.keep, alpha=p.alpha, k_bits=k,
                            scale=p.scale, zero=p.zero)
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(p)),
                                  np.asarray(reconstruct_dense(p2)))


def test_low_code_bit_width():
    p = _pack(k=4, m=8)
    for part in to_storage_parts(p):
        if len(part.low_codes):
            assert part.low_codes.max() <= 2**4 // 8 - 1  # 1-bit storage


def test_bits_accounting():
    p = _pack(h_in=512, h_out=64, h_g=64, alpha=8, k=4, m=8)
    # value bits: nnz * (k - log2 m) = nnz * 1
    assert p.value_bits() == pytest.approx(p.nnz * 1.0)
    # index bits: log2(h_g) per nnz
    assert p.index_bits() == pytest.approx(p.nnz * 6.0)
    assert p.total_bits() == pytest.approx(p.nnz * 7.0)


def test_stacked_pack_and_index():
    rng = jax.random.PRNGKey(1)
    d = jax.random.normal(rng, (3, 128, 16)) * 0.01   # stacked (layers)
    p = groupwise_dropout_pack(rng, d, h_g=32, alpha=4, k_bits=4)
    assert p.stack_shape() == (3,)
    assert p.scale.shape == (3,)
    dense = reconstruct_dense(p)
    assert dense.shape == (3, 128, 16)
    one = p.index(1)
    np.testing.assert_allclose(np.asarray(reconstruct_dense(one)),
                               np.asarray(dense[1]), rtol=1e-6)


def test_pytree_registration():
    p = _pack()
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 4
    p2 = jax.tree.map(lambda x: x, p)
    assert isinstance(p2, PackedDelta)
    assert p2.h_g == p.h_g
