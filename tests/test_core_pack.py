import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PackedDelta,
    from_storage_parts,
    groupwise_dropout_pack,
    reconstruct_dense,
    to_storage_parts,
)


def _pack(h_in=256, h_out=32, h_g=64, alpha=8, k=4, m=4, seed=0):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_in, h_out)) * 0.01
    return groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=k, m=m)


def _canonical(p: PackedDelta):
    """(idx, q) with each (group, column)'s K entries sorted by idx.

    The m-part CSR reassembly preserves the (idx, code) *pairs* exactly
    but interleaves part order within a (g, o) row, so elementwise array
    equality is only meaningful after sorting by the (unique) local
    indices — the canonical form of the structured-sparse layout.
    """
    from repro.core import quant
    q = np.asarray(quant.unpack_bits(p.codes, quant.pack_width(p.k_bits),
                                     p.keep, axis=p.codes.ndim - 2))
    idx = np.asarray(p.idx, np.int64)
    order = np.argsort(idx, axis=1, kind="stable")
    return (np.take_along_axis(idx, order, axis=1),
            np.take_along_axis(q, order, axis=1))


@pytest.mark.parametrize("k,m", [(4, 1), (4, 4), (4, 8), (8, 8), (2, 2),
                                 (1, 1)])
def test_storage_parts_roundtrip(k, m):
    p = _pack(k=k, m=m)
    parts = to_storage_parts(p)
    assert len(parts) == m
    # supports are disjoint and complete
    total = sum(len(q.low_codes) for q in parts)
    assert total == p.nnz
    p2 = from_storage_parts(parts, h_in=p.h_in, h_out=p.h_out, h_g=p.h_g,
                            keep=p.keep, alpha=p.alpha, k_bits=k,
                            scale=p.scale, zero=p.zero)
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(p)),
                                  np.asarray(reconstruct_dense(p2)))


@pytest.mark.parametrize("k", [2, 4, 8])
@pytest.mark.parametrize("m", [1, 2, 4])
def test_storage_parts_roundtrip_full_equality(k, m):
    """Paper-faithful storage round-trip: to_storage_parts ->
    from_storage_parts reproduces the original PackedDelta — codes and
    idx (canonically ordered), static meta, scale/zero, and the dense
    reconstruction — over the k x m sweep."""
    if 2 ** k < m:
        pytest.skip("more parts than code levels")
    p = _pack(h_in=128, h_out=24, h_g=32, alpha=4, k=k, m=m, seed=k * 10 + m)
    p2 = from_storage_parts(to_storage_parts(p), h_in=p.h_in, h_out=p.h_out,
                            h_g=p.h_g, keep=p.keep, alpha=p.alpha, k_bits=k,
                            scale=p.scale, zero=p.zero)
    assert (p2.h_in, p2.h_out, p2.h_g, p2.keep, p2.alpha, p2.k_bits, p2.m) \
        == (p.h_in, p.h_out, p.h_g, p.keep, p.alpha, p.k_bits, p.m)
    assert p2.idx.dtype == p.idx.dtype and p2.codes.dtype == p.codes.dtype
    np.testing.assert_array_equal(np.asarray(p2.scale, np.float32),
                                  np.asarray(p.scale, np.float32))
    np.testing.assert_array_equal(np.asarray(p2.zero, np.int32),
                                  np.asarray(p.zero, np.int32))
    idx_a, q_a = _canonical(p)
    idx_b, q_b = _canonical(p2)
    np.testing.assert_array_equal(idx_a, idx_b)
    np.testing.assert_array_equal(q_a, q_b)
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(p)),
                                  np.asarray(reconstruct_dense(p2)))


def test_low_code_bit_width():
    p = _pack(k=4, m=8)
    for part in to_storage_parts(p):
        if len(part.low_codes):
            assert part.low_codes.max() <= 2**4 // 8 - 1  # 1-bit storage


def test_bits_accounting():
    p = _pack(h_in=512, h_out=64, h_g=64, alpha=8, k=4, m=8)
    # value bits: nnz * (k - log2 m) = nnz * 1
    assert p.value_bits() == pytest.approx(p.nnz * 1.0)
    # index bits: log2(h_g) per nnz
    assert p.index_bits() == pytest.approx(p.nnz * 6.0)
    assert p.total_bits() == pytest.approx(p.nnz * 7.0)


def test_stacked_pack_and_index():
    rng = jax.random.PRNGKey(1)
    d = jax.random.normal(rng, (3, 128, 16)) * 0.01   # stacked (layers)
    p = groupwise_dropout_pack(rng, d, h_g=32, alpha=4, k_bits=4)
    assert p.stack_shape() == (3,)
    assert p.scale.shape == (3,)
    dense = reconstruct_dense(p)
    assert dense.shape == (3, 128, 16)
    one = p.index(1)
    np.testing.assert_allclose(np.asarray(reconstruct_dense(one)),
                               np.asarray(dense[1]), rtol=1e-6)


def test_pytree_registration():
    p = _pack()
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 4
    p2 = jax.tree.map(lambda x: x, p)
    assert isinstance(p2, PackedDelta)
    assert p2.h_g == p.h_g


# ---------------------------------------------------------------------------
# Codec-parametrized round-trips (the DeltaCodec protocol contract)
# ---------------------------------------------------------------------------
from repro.core import decode_values  # noqa: E402
from repro.core.codecs import (  # noqa: E402
    BitDeltaSpec,
    DeltaDQSpec,
    LowRankSpec,
    codec_names,
    get_codec,
)

# quantized DeltaDQ spec (the default DeltaDQSpec is dropout-only, which
# the storage layer stores as raw f32 values — fine, but the interesting
# round-trip is through packed codes)
CODEC_SPECS = {
    "deltadq": DeltaDQSpec(alpha=8.0, k_bits=4, m=2, h_g=16),
    "bitdelta": BitDeltaSpec(),
    "lowrank": LowRankSpec(rank=4, k_bits=4),
}


def _codec_leaf(name, h_in=64, h_out=24, seed=0):
    c = get_codec(name)
    rng = jax.random.PRNGKey(seed)
    base = jax.random.normal(rng, (h_in, h_out))
    ft = base + 0.01 * jax.random.normal(
        jax.random.fold_in(rng, 1), (h_in, h_out))
    leaf = c.compress_leaf(jax.random.fold_in(rng, 2), base, ft,
                           CODEC_SPECS[name])
    return c, leaf


def test_every_registered_codec_is_exercised():
    assert sorted(codec_names()) == sorted(CODEC_SPECS)


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_codec_runtime_lowering_bit_faithful(name):
    """The serving contract: every codec's runtime PackedDelta lowering
    reconstructs the exact same dense delta as the codec's own reference
    decode — bit equality, not allclose (token identity depends on it)."""
    c, leaf = _codec_leaf(name)
    rt = c.runtime_packed(leaf)
    assert isinstance(rt, PackedDelta) and rt.codec == name
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(rt)),
                                  np.asarray(c.reconstruct_dense(leaf)))
    np.testing.assert_array_equal(np.asarray(c.decode_values(leaf)),
                                  np.asarray(decode_values(rt)))


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_codec_storage_parts_roundtrip(name):
    c, leaf = _codec_leaf(name)
    parts, meta = c.to_storage_parts(leaf)
    assert meta["codec"] == name
    leaf2 = c.from_storage_parts(parts, meta)
    np.testing.assert_array_equal(np.asarray(c.reconstruct_dense(leaf)),
                                  np.asarray(c.reconstruct_dense(leaf2)))
    # full child equality where the layout is unique (the m-part DeltaDQ
    # CSR interleaves part order; its canonical-order equality is covered
    # by test_storage_parts_roundtrip_full_equality above)
    if name == "bitdelta":
        np.testing.assert_array_equal(np.asarray(leaf.sign),
                                      np.asarray(leaf2.sign))
        np.testing.assert_array_equal(np.asarray(leaf.scale, np.float32),
                                      np.asarray(leaf2.scale, np.float32))
    if name == "lowrank":
        for attr in ("codes", "scale", "zero", "u", "v"):
            np.testing.assert_array_equal(
                np.asarray(getattr(leaf, attr)),
                np.asarray(getattr(leaf2, attr)))


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_codec_leaf_spec_matches_compression(name):
    """Abstract ShapeDtypeStruct twins agree with real compression:
    same tree structure, shapes and dtypes leaf for leaf."""
    c, leaf = _codec_leaf(name)
    sds = jax.ShapeDtypeStruct((64, 24), jnp.float32)
    twin = c.leaf_spec(sds, CODEC_SPECS[name])
    real_leaves = jax.tree.leaves(leaf)
    twin_leaves = jax.tree.leaves(twin)
    assert len(real_leaves) == len(twin_leaves)
    for a, b in zip(real_leaves, twin_leaves):
        assert tuple(a.shape) == tuple(b.shape), (name, a.shape, b.shape)
        assert a.dtype == b.dtype, (name, a.dtype, b.dtype)


@pytest.mark.parametrize("name", sorted(CODEC_SPECS))
def test_codec_storage_bits_positive_and_below_dense(name):
    c, leaf = _codec_leaf(name)
    bits = c.storage_bits(leaf)
    dense = 16.0 * leaf.h_in * leaf.h_out
    assert 0 < bits["value_bits"] <= bits["total_bits"]
    assert bits["value_bits"] < dense
