import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PackedDelta,
    from_storage_parts,
    groupwise_dropout_pack,
    reconstruct_dense,
    to_storage_parts,
)


def _pack(h_in=256, h_out=32, h_g=64, alpha=8, k=4, m=4, seed=0):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_in, h_out)) * 0.01
    return groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=k, m=m)


def _canonical(p: PackedDelta):
    """(idx, q) with each (group, column)'s K entries sorted by idx.

    The m-part CSR reassembly preserves the (idx, code) *pairs* exactly
    but interleaves part order within a (g, o) row, so elementwise array
    equality is only meaningful after sorting by the (unique) local
    indices — the canonical form of the structured-sparse layout.
    """
    from repro.core import quant
    q = np.asarray(quant.unpack_bits(p.codes, quant.pack_width(p.k_bits),
                                     p.keep, axis=p.codes.ndim - 2))
    idx = np.asarray(p.idx, np.int64)
    order = np.argsort(idx, axis=1, kind="stable")
    return (np.take_along_axis(idx, order, axis=1),
            np.take_along_axis(q, order, axis=1))


@pytest.mark.parametrize("k,m", [(4, 1), (4, 4), (4, 8), (8, 8), (2, 2),
                                 (1, 1)])
def test_storage_parts_roundtrip(k, m):
    p = _pack(k=k, m=m)
    parts = to_storage_parts(p)
    assert len(parts) == m
    # supports are disjoint and complete
    total = sum(len(q.low_codes) for q in parts)
    assert total == p.nnz
    p2 = from_storage_parts(parts, h_in=p.h_in, h_out=p.h_out, h_g=p.h_g,
                            keep=p.keep, alpha=p.alpha, k_bits=k,
                            scale=p.scale, zero=p.zero)
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(p)),
                                  np.asarray(reconstruct_dense(p2)))


@pytest.mark.parametrize("k", [2, 4, 8])
@pytest.mark.parametrize("m", [1, 2, 4])
def test_storage_parts_roundtrip_full_equality(k, m):
    """Paper-faithful storage round-trip: to_storage_parts ->
    from_storage_parts reproduces the original PackedDelta — codes and
    idx (canonically ordered), static meta, scale/zero, and the dense
    reconstruction — over the k x m sweep."""
    if 2 ** k < m:
        pytest.skip("more parts than code levels")
    p = _pack(h_in=128, h_out=24, h_g=32, alpha=4, k=k, m=m, seed=k * 10 + m)
    p2 = from_storage_parts(to_storage_parts(p), h_in=p.h_in, h_out=p.h_out,
                            h_g=p.h_g, keep=p.keep, alpha=p.alpha, k_bits=k,
                            scale=p.scale, zero=p.zero)
    assert (p2.h_in, p2.h_out, p2.h_g, p2.keep, p2.alpha, p2.k_bits, p2.m) \
        == (p.h_in, p.h_out, p.h_g, p.keep, p.alpha, p.k_bits, p.m)
    assert p2.idx.dtype == p.idx.dtype and p2.codes.dtype == p.codes.dtype
    np.testing.assert_array_equal(np.asarray(p2.scale, np.float32),
                                  np.asarray(p.scale, np.float32))
    np.testing.assert_array_equal(np.asarray(p2.zero, np.int32),
                                  np.asarray(p.zero, np.int32))
    idx_a, q_a = _canonical(p)
    idx_b, q_b = _canonical(p2)
    np.testing.assert_array_equal(idx_a, idx_b)
    np.testing.assert_array_equal(q_a, q_b)
    np.testing.assert_array_equal(np.asarray(reconstruct_dense(p)),
                                  np.asarray(reconstruct_dense(p2)))


def test_low_code_bit_width():
    p = _pack(k=4, m=8)
    for part in to_storage_parts(p):
        if len(part.low_codes):
            assert part.low_codes.max() <= 2**4 // 8 - 1  # 1-bit storage


def test_bits_accounting():
    p = _pack(h_in=512, h_out=64, h_g=64, alpha=8, k=4, m=8)
    # value bits: nnz * (k - log2 m) = nnz * 1
    assert p.value_bits() == pytest.approx(p.nnz * 1.0)
    # index bits: log2(h_g) per nnz
    assert p.index_bits() == pytest.approx(p.nnz * 6.0)
    assert p.total_bits() == pytest.approx(p.nnz * 7.0)


def test_stacked_pack_and_index():
    rng = jax.random.PRNGKey(1)
    d = jax.random.normal(rng, (3, 128, 16)) * 0.01   # stacked (layers)
    p = groupwise_dropout_pack(rng, d, h_g=32, alpha=4, k_bits=4)
    assert p.stack_shape() == (3,)
    assert p.scale.shape == (3,)
    dense = reconstruct_dense(p)
    assert dense.shape == (3, 128, 16)
    one = p.index(1)
    np.testing.assert_allclose(np.asarray(reconstruct_dense(one)),
                               np.asarray(dense[1]), rtol=1e-6)


def test_pytree_registration():
    p = _pack()
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 4
    p2 = jax.tree.map(lambda x: x, p)
    assert isinstance(p2, PackedDelta)
    assert p2.h_g == p.h_g
