"""Fixture tests for the deltalint rules (DL000-DL008).

Each rule gets (at least) a violating snippet it must fire on and a
compliant twin it must stay silent on; the escape hatch and the DL004
multi-file cross-check have their own cases. The suite ends with the
self-gate: the shipped ``src/repro`` tree must lint clean, which is the
same check CI's lint job runs.

No jax import anywhere in this file — the lint layer must stay loadable
(and fast) without an accelerator stack.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis.lint import RULES, lint_paths, lint_source

REPO = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return [f.rule for f in findings]


def hits(source, rel, rule):
    return [f for f in lint_source(source, rel) if f.rule == rule]


# ---------------------------------------------------------------------------
# DL001 — dot-family reductions in identity paths
# ---------------------------------------------------------------------------
def test_dl001_fires_on_einsum_in_fallback():
    src = "import jax.numpy as jnp\ny = jnp.einsum('bi,bio->bo', x, w)\n"
    found = hits(src, "repro/kernels/fallback.py", "DL001")
    assert len(found) == 1 and found[0].line == 2


def test_dl001_fires_on_dot_general_and_jnp_dot():
    src = ("from jax import lax\nimport jax.numpy as jnp\n"
           "a = lax.dot_general(x, w, d)\nb = jnp.dot(x, w)\n"
           "c = jnp.matmul(x, w)\n")
    assert len(hits(src, "repro/core/apply.py", "DL001")) == 3


def test_dl001_silent_on_compliant_twin():
    # the sanctioned formulation: elementwise multiply + axis sum, and
    # the @ operator (base matmuls are legitimate; the rule targets the
    # *named reduce-order-sensitive* calls in correction paths)
    src = ("import jax.numpy as jnp\n"
           "y = jnp.sum(x[:, :, None] * dense, axis=1)\n"
           "z = x @ w\n")
    assert hits(src, "repro/kernels/fallback.py", "DL001") == []


def test_dl001_out_of_scope_file_not_checked():
    src = "import jax.numpy as jnp\ny = jnp.einsum('ij,jk->ik', a, b)\n"
    assert hits(src, "repro/models/lm.py", "DL001") == []


def test_dl001_escape_hatch_with_reason():
    src = ("import jax.numpy as jnp\n"
           "# deltalint: allow[DL001] audited MoE site, grouped serving\n"
           "y = jnp.einsum('e...d,edf->e...f', x, w)\n")
    assert hits(src, "repro/core/apply.py", "DL001") == []


def test_allow_comment_skips_comment_continuations():
    src = ("import jax.numpy as jnp\n"
           "# deltalint: allow[DL001] audited site whose justification\n"
           "# spans two comment lines before the code\n"
           "y = jnp.einsum('e...d,edf->e...f', x, w)\n")
    assert hits(src, "repro/core/apply.py", "DL001") == []


def test_allow_without_reason_is_dl000():
    src = ("import jax.numpy as jnp\n"
           "y = jnp.einsum('ij,jk->ik', a, b)  # deltalint: allow[DL001]\n")
    found = lint_source(src, "repro/core/apply.py")
    assert rules_of(found) == ["DL000"]   # suppressed, but flagged reasonless


# ---------------------------------------------------------------------------
# DL002 — nondeterminism in core/ + serve/
# ---------------------------------------------------------------------------
def test_dl002_fires_on_hash_time_and_global_rng():
    src = ("import time\nimport numpy as np\n"
           "s = hash(path)\n"
           "t = time.time()\n"
           "r = np.random.rand(3)\n"
           "g = np.random.default_rng()\n")
    assert len(hits(src, "repro/core/compress.py", "DL002")) == 4


def test_dl002_silent_on_sanctioned_twins():
    src = ("import time\nimport zlib\nimport numpy as np\n"
           "s = zlib.crc32(path.encode())\n"
           "t = time.monotonic()\n"                 # the injectable default
           "g = np.random.default_rng(1234)\n"      # explicit seed
           "r = g.normal(size=3)\n")                # instance RNG, not global
    assert hits(src, "repro/serve/engine.py", "DL002") == []


def test_dl002_launch_timing_loops_out_of_scope():
    src = "import time\nt0 = time.time()\n"
    assert hits(src, "repro/launch/serve.py", "DL002") == []


# ---------------------------------------------------------------------------
# DL003 — bare asserts
# ---------------------------------------------------------------------------
def test_dl003_fires_on_bare_assert_anywhere_in_repro():
    src = "def f(x):\n    assert x > 0\n    return x\n"
    assert len(hits(src, "repro/models/ssm.py", "DL003")) == 1


def test_dl003_silent_on_typed_raise():
    src = ("def f(x):\n"
           "    if x <= 0:\n"
           "        raise ValueError(f'x={x} must be positive')\n"
           "    return x\n")
    assert hits(src, "repro/models/ssm.py", "DL003") == []


def test_dl003_escape_hatch_for_traced_body_invariant():
    src = ("def step(x):\n"
           "    # deltalint: allow[DL003] traced-body shape invariant\n"
           "    assert x.shape[1] == 1\n")
    assert hits(src, "repro/models/ssm.py", "DL003") == []


# ---------------------------------------------------------------------------
# DL004 — emit names <-> EVENT_SCHEMA (multi-file cross-check)
# ---------------------------------------------------------------------------
_TRACE_SRC = ("EVENT_SCHEMA = {\n"
              "    'token': 'engine: one token',\n"
              "    'ghost': 'documented but never emitted',\n"
              "}\n")


def _write_tree(tmp_path, trace_src, engine_src):
    pkg = tmp_path / "repro" / "serve"
    pkg.mkdir(parents=True)
    (pkg / "trace.py").write_text(trace_src)
    (pkg / "engine.py").write_text(engine_src)
    return [str(pkg / "trace.py"), str(pkg / "engine.py")]


def test_dl004_typo_emit_and_dead_schema_entry(tmp_path):
    paths = _write_tree(
        tmp_path, _TRACE_SRC,
        "def go(bus, t):\n"
        "    bus.emit('token', t)\n"
        "    bus.emit('tokn', t)\n")        # typo'd name
    found = [f for f in lint_paths(paths) if f.rule == "DL004"]
    msgs = sorted(f.message for f in found)
    assert len(found) == 2
    assert "'tokn'" in msgs[1] and "not in" in msgs[1]
    assert "'ghost'" in msgs[0] and "never emitted" in msgs[0]


def test_dl004_clean_cross_check(tmp_path):
    paths = _write_tree(
        tmp_path,
        "EVENT_SCHEMA = {'token': 'engine: one token'}\n",
        "def go(self, t):\n"
        "    self.bus.emit('token', t)\n"
        "    self.engine.bus.emit('token' if t else 'token', t)\n")
    assert [f for f in lint_paths(paths) if f.rule == "DL004"] == []


def test_dl004_non_literal_event_name_flagged():
    src = "def go(bus, name, t):\n    bus.emit(name, t)\n"
    assert len(hits(src, "repro/serve/registry.py", "DL004")) == 1


def test_dl004_reverse_check_needs_engine_in_scope(tmp_path):
    # linting trace.py alone must NOT flag schema entries as unemitted —
    # the emitting layer simply isn't part of the run
    pkg = tmp_path / "repro" / "serve"
    pkg.mkdir(parents=True)
    (pkg / "trace.py").write_text(_TRACE_SRC)
    assert [f for f in lint_paths([str(pkg / "trace.py")])
            if f.rule == "DL004"] == []


def test_dl004_shipped_schema_matches_shipped_emits():
    found = lint_paths([str(REPO / "src" / "repro" / "serve")])
    assert [f for f in found if f.rule == "DL004"] == []


# ---------------------------------------------------------------------------
# DL005 — recompile-risk jit patterns
# ---------------------------------------------------------------------------
def test_dl005_fires_on_jit_in_loop_and_immediate_invoke():
    src = ("import jax\n"
           "for f in fns:\n"
           "    g = jax.jit(f)\n"           # fresh cache every iteration
           "y = jax.jit(h)(x)\n")           # compiles every call
    assert len(hits(src, "repro/kernels/autotune.py", "DL005")) == 2


def test_dl005_silent_on_bound_once_jit():
    src = ("import jax\n"
           "step = jax.jit(f)\n"
           "for x in xs:\n"
           "    y = step(x)\n")
    assert hits(src, "repro/serve/engine.py", "DL005") == []


def test_dl005_tracks_from_import_alias():
    src = ("from jax import jit\n"
           "while True:\n"
           "    g = jit(f)\n")
    assert len(hits(src, "repro/core/apply.py", "DL005")) == 1


def test_dl005_launch_excluded_and_allowable():
    src = "import jax\nfor f in fns:\n    g = jax.jit(f)\n"
    assert hits(src, "repro/launch/bench.py", "DL005") == []
    allowed = ("import jax\n"
               "for f in fns:\n"
               "    # deltalint: allow[DL005] deliberate autotune sweep\n"
               "    g = jax.jit(f)\n")
    assert hits(allowed, "repro/kernels/autotune.py", "DL005") == []


# ---------------------------------------------------------------------------
# DL006 — codec protocol completeness
# ---------------------------------------------------------------------------
_FULL_CODEC = """
class GoodCodec:
    name = 'good'
    spec_cls = object
    leaf_cls = object
    def compress_leaf(self): ...
    def reconstruct_dense(self): ...
    def runtime_packed(self): ...
    def storage_bits(self): ...
    def to_storage_parts(self): ...
    def from_storage_parts(self): ...
    def leaf_spec(self): ...
    def leaf_axes(self): ...
register_codec(GoodCodec())
"""


def test_dl006_fires_on_partial_codec():
    src = ("class HalfCodec:\n"
           "    name = 'half'\n"
           "    def compress_leaf(self): ...\n"
           "register_codec(HalfCodec())\n")
    found = hits(src, "repro/core/codecs.py", "DL006")
    assert len(found) == 1
    assert "reconstruct_dense" in found[0].message
    assert "spec_cls" in found[0].message


def test_dl006_silent_on_full_surface():
    assert hits(_FULL_CODEC, "repro/core/codecs.py", "DL006") == []


def test_dl006_walks_same_module_bases():
    src = ("class Base:\n"
           "    name = 'b'\n"
           "    spec_cls = object\n"
           "    leaf_cls = object\n"
           "    def compress_leaf(self): ...\n"
           "    def reconstruct_dense(self): ...\n"
           "    def runtime_packed(self): ...\n"
           "    def storage_bits(self): ...\n"
           "    def to_storage_parts(self): ...\n"
           "    def from_storage_parts(self): ...\n"
           "    def leaf_spec(self): ...\n"
           "class Child(Base):\n"
           "    def leaf_axes(self): ...\n"
           "register_codec(Child())\n")
    assert hits(src, "repro/core/codecs.py", "DL006") == []


# ---------------------------------------------------------------------------
# DL007 — deterministic storage paths
# ---------------------------------------------------------------------------
def test_dl007_fires_on_mutable_default_and_set_iteration():
    src = ("def pack(leaves, seen=[]):\n"
           "    for k in set(leaves):\n"
           "        seen.append(k)\n")
    found = hits(src, "repro/core/pack.py", "DL007")
    assert len(found) == 2


def test_dl007_silent_on_sorted_iteration_and_none_default():
    src = ("def pack(leaves, seen=None):\n"
           "    seen = [] if seen is None else seen\n"
           "    for k in sorted(set(leaves)):\n"
           "        seen.append(k)\n")
    # sorted(set(...)) is fine: the For iterates the sorted() call
    assert hits(src, "repro/core/codecs.py", "DL007") == []


def test_dl007_scoped_to_storage_files():
    src = "def f(xs=[]):\n    pass\n"
    assert hits(src, "repro/serve/engine.py", "DL007") == []


# ---------------------------------------------------------------------------
# DL008 — value-naming raises in public serve/ functions
# ---------------------------------------------------------------------------
def test_dl008_fires_on_static_message():
    src = ("def submit(self, tenant):\n"
           "    raise ValueError('unknown tenant')\n")
    assert len(hits(src, "repro/serve/engine.py", "DL008")) == 1


def test_dl008_fires_on_argless_and_concat_static():
    src = ("def merge(self, other):\n"
           "    raise RuntimeError()\n"
           "def check(self, x):\n"
           "    raise TypeError('bad ' + 'layout')\n")
    assert len(hits(src, "repro/serve/telemetry.py", "DL008")) == 2


def test_dl008_silent_when_value_is_named():
    src = ("def submit(self, tenant):\n"
           "    raise ValueError(f'unknown tenant {tenant!r}')\n"
           "def place(self, slot):\n"
           "    raise RuntimeError('slot %d occupied' % slot)\n")
    assert hits(src, "repro/serve/scheduler.py", "DL008") == []


def test_dl008_private_functions_and_other_dirs_exempt():
    src = "def _inner(x):\n    raise ValueError('nope')\n"
    assert hits(src, "repro/serve/engine.py", "DL008") == []
    pub = "def f(x):\n    raise ValueError('nope')\n"
    assert hits(pub, "repro/core/pack.py", "DL008") == []


# ---------------------------------------------------------------------------
# Self-gate: the shipped tree lints clean, via API and via the CLI
# ---------------------------------------------------------------------------
def test_shipped_tree_is_clean():
    findings = lint_paths([str(REPO / "src" / "repro")])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exits_zero_and_writes_json_report(tmp_path):
    report = tmp_path / "findings.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         str(REPO / "src" / "repro"), "--json", str(report)],
        capture_output=True, text=True, cwd=str(REPO),
        env={**os.environ, "PYTHONPATH": str(REPO / "src")},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["findings"] == [] and data["files"] > 50


def test_cli_exits_one_on_violation(tmp_path):
    bad = tmp_path / "repro" / "core"
    bad.mkdir(parents=True)
    (bad / "pack.py").write_text("def f(x):\n    assert x\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(tmp_path)],
        capture_output=True, text=True, cwd=str(REPO),
        env={**os.environ, "PYTHONPATH": str(REPO / "src")},
    )
    assert proc.returncode == 1
    assert "DL003" in proc.stdout


def test_rule_table_is_closed():
    # every finding a fixture produced uses a documented rule id
    assert set(RULES) == {f"DL00{i}" for i in range(9)}


def test_syntax_error_reported_not_raised(tmp_path):
    pkg = tmp_path / "repro"
    pkg.mkdir()
    (pkg / "broken.py").write_text("def f(:\n")
    found = lint_paths([str(pkg / "broken.py")])
    assert rules_of(found) == ["DL000"]
    assert "cannot lint" in found[0].message
