import numpy as np

from repro.data import MemmapTokens, PretrainMixture, SortTask, SyntheticLM
from repro.data.pipeline import EOS, PAD, SEP


def test_determinism_all_sources(tmp_path):
    for src in (SyntheticLM(100, 16, 4, seed=1), PretrainMixture(100, 16, 4, seed=1),
                SortTask(100, 32, 4, seed=1)):
        b1, b2 = src.batch_at(5), src.batch_at(5)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
        b3 = src.batch_at(6)
        assert any((b1[k] != b3[k]).any() for k in b1)


def test_memmap_tokens(tmp_path):
    data = np.arange(1000, dtype=np.int32) % 50
    p = tmp_path / "toks.bin"
    data.tofile(p)
    src = MemmapTokens(str(p), seq_len=8, batch=4, seed=0)
    b = src.batch_at(0)
    assert b["tokens"].shape == (4, 8)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_sort_task_structure():
    task = SortTask(vocab=64, seq_len=32, batch=8, n_digits=6, seed=0)
    b = task.batch_at(0)
    toks, labels, mask = b["tokens"], b["labels"], b["loss_mask"]
    for r in range(8):
        sep_pos = int(np.where(toks[r] == SEP)[0][0])
        assert sep_pos == 6
        sorted_part = toks[r, sep_pos + 1: sep_pos + 7]
        np.testing.assert_array_equal(sorted_part, np.sort(toks[r, :6]))
        assert toks[r, sep_pos + 7] == EOS
        # loss only on the completion span
        assert mask[r, :6].sum() == 0
        assert mask[r, 6:13].sum() == 7

    prompts, targets = task.prompts_at(0)
    np.testing.assert_array_equal(np.sort(prompts[:, :6], axis=1), targets)
