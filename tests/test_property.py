"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly on bare CPU containers
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeltaDQSpec,
    compression_ratio,
    groupwise_dropout_pack,
    reconstruct_dense,
)
from repro.core.pack import to_storage_parts


@settings(max_examples=20, deadline=None)
@given(alpha=st.sampled_from([2, 4, 8, 16]),
       k=st.sampled_from([2, 4, 8]),
       m_exp=st.integers(0, 3))
def test_ratio_monotonic_in_m(alpha, k, m_exp):
    m = 2 ** m_exp
    if m > 2 ** k - 1:
        return
    r0 = compression_ratio(alpha, k, 1)
    r1 = compression_ratio(alpha, k, m)
    assert r1 >= r0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), k=st.sampled_from([2, 4, 8]))
def test_quant_error_decreases_with_k(seed, k):
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (64, 16)) * 0.01
    errs = []
    for kb in (2, 4, 8):
        p = groupwise_dropout_pack(rng, d, h_g=16, alpha=2, k_bits=kb)
        p_ref = groupwise_dropout_pack(rng, d, h_g=16, alpha=2, k_bits=None)
        errs.append(float(jnp.linalg.norm(reconstruct_dense(p) - reconstruct_dense(p_ref))))
    assert errs[0] >= errs[1] >= errs[2]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50),
       alpha=st.sampled_from([2, 4, 8]),
       hg_exp=st.integers(3, 6))
def test_structured_sparsity_invariant(seed, alpha, hg_exp):
    """Every (group, column) has exactly h_g/alpha survivors; support of m
    parts partitions the nonzeros; dequantized zeros stay exactly zero."""
    h_g = 2 ** hg_exp
    if h_g < alpha:
        return
    rng = jax.random.PRNGKey(seed)
    d = jax.random.normal(rng, (h_g * 2, 8)) * 0.01
    p = groupwise_dropout_pack(rng, d, h_g=h_g, alpha=alpha, k_bits=4, m=4)
    dense = np.asarray(reconstruct_dense(p))
    keep = h_g // alpha
    # indices are unique within each (group, column)
    idx = np.asarray(p.idx)
    for g in range(idx.shape[0]):
        for o in range(idx.shape[2]):
            assert len(np.unique(idx[g, :, o])) == keep
    parts = to_storage_parts(p)
    assert sum(len(q.low_codes) for q in parts) == p.nnz


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 30))
def test_separate_computation_linearity(seed):
    """forward(base + delta) == forward(base) + delta_matmul(x) at a single
    linear layer for any packed delta (the separate-computation identity)."""
    from repro.core.apply import apply_linear
    rng = jax.random.PRNGKey(seed)
    w = jax.random.normal(rng, (64, 32)) * 0.1
    d = jax.random.normal(jax.random.fold_in(rng, 1), (64, 32)) * 0.01
    p = groupwise_dropout_pack(rng, d, h_g=16, alpha=2, k_bits=None)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (4, 64))
    y_sep = apply_linear(x, w, p)
    y_merged = x @ (w + reconstruct_dense(p))
    np.testing.assert_allclose(np.asarray(y_sep), np.asarray(y_merged), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([8, 16]), seed=st.integers(0, 20))
def test_model_logits_finite_property(b, s, seed):
    from repro.configs import get_smoke_config
    from repro.models import lm
    cfg = get_smoke_config("llama3.2-1b")
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, cfg.vocab)}
    logits = lm.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
