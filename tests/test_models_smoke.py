"""Per-assigned-architecture smoke tests (required deliverable f):
instantiate the reduced same-family config, run one forward and one train
step on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import make_train_step

ARCHS = list_archs()


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_feats"] = jax.random.normal(rng, (B, 8, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(rng, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits = lm.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), n_micro=1, remat=True))
    batch = _batch(cfg, rng)
    params2, opt2, metrics = step(params, opt, batch, rng)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_specs_exist(arch):
    """Full configs are exercised via the dry-run only; here we check their
    param tree builds (ShapeDtypeStruct, no allocation) and counts are sane."""
    cfg = get_config(arch)
    n = cfg.n_params()
    assert n > 1e8, (arch, n)
    na = cfg.n_active_params()
    assert na <= n
    if cfg.moe:
        assert na < n


def test_expected_param_counts():
    """Anchor a few archs against public parameter counts (rough)."""
    checks = {
        "llama3.2-1b": (1.0e9, 1.5e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "phi3-medium-14b": (13e9, 15e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "mamba2-370m": (3.0e8, 4.5e8),
        "wizard-llama2-7b": (6.0e9, 7.5e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    # ~3B active of ~30B total
    assert 2e9 <= cfg.n_active_params() <= 5e9
