"""CompileGuard: the single recompile-detection implementation.

Unit half: a fake engine whose jitted entries are plain counters —
exercises budgets, max_new, snapshot/new_compiles arithmetic, warmup
re-baselining, strict event-bus mode, and the context-manager protocol
without touching jax.

Regression half: a real ContinuousEngine on the smoke config. The
injected-recompile test is the reason this module exists — it drives the
engine's actual decode jit with a *different batch extent* (the exact
bug class the static-decode-shape contract forbids), and shows the
guard catching it where the old hand-rolled ``_cache_size()`` deltas
would have had to be re-derived at every call site. It also proves the
guard's arithmetic equals the raw cache-size delta, so migrating the
lifecycle/scheduler tests and the bench gate onto it changed no
semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import CompileBudgetError, CompileGuard, count_recompiles
from repro.analysis.compile_guard import ENTRY_PATHS
from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import ContinuousEngine, VirtualClock
from repro.serve.trace import EventBus, ServeEvent


# ---------------------------------------------------------------------------
# Fake engine: jitted entries are counters
# ---------------------------------------------------------------------------
class FakeJit:
    def __init__(self, n=1):
        self.n = n

    def compile(self, k=1):
        self.n += k

    def _cache_size(self):
        return self.n


class FakeEngine:
    def __init__(self):
        self._decode = FakeJit()
        self._prefill = FakeJit(3)
        self.bus = EventBus()


def retrace_ev(first=False, **extra):
    return ServeEvent("jit_trace", 0.0,
                      {"first": first, "path": "decode",
                       "sig": ("decode", 1, False), **extra})


def test_unknown_entry_rejected_at_construction():
    with pytest.raises(ValueError, match="decod"):
        CompileGuard(FakeEngine(), budgets={"decod": 1})
    with pytest.raises(ValueError, match="known entries"):
        CompileGuard(FakeEngine(), max_new={"everything": 0})


def test_entries_and_sizes_resolve_by_duck_type():
    guard = CompileGuard(FakeEngine())
    assert set(guard.entries()) == {"decode", "prefill"}
    assert guard.sizes() == {"decode": 1, "prefill": 3}
    # the full path table is a superset; unresolvable entries are skipped
    assert set(guard.entries()) <= set(ENTRY_PATHS)


def test_new_compiles_counts_from_snapshot():
    eng = FakeEngine()
    guard = CompileGuard(eng)
    assert guard.new_compiles("decode") == 0
    eng._decode.compile(2)
    assert guard.new_compiles("decode") == 2
    assert guard.report()["decode"] == {"total": 3, "new": 2}
    guard.snapshot()
    assert guard.new_compiles("decode") == 0


def test_budget_total_enforced():
    eng = FakeEngine()
    guard = CompileGuard(eng, budgets={"decode": 1})
    guard.check()                       # at budget: fine
    eng._decode.compile()
    with pytest.raises(CompileBudgetError) as e:
        guard.check()
    assert "'decode' compiled 2 time(s), budget 1" in str(e.value)


def test_max_new_enforced_and_labelled():
    eng = FakeEngine()
    guard = CompileGuard(eng, max_new={"decode": 0}, label="lifecycle")
    guard.check()
    eng._decode.compile()
    with pytest.raises(CompileBudgetError) as e:
        guard.check()
    msg = str(e.value)
    assert msg.startswith("[lifecycle] ")
    assert "recompiled 1 time(s) since baseline" in msg
    assert "full report" in msg         # the whole table rides along


def test_context_manager_checks_on_clean_exit_only():
    eng = FakeEngine()
    with pytest.raises(CompileBudgetError):
        with CompileGuard(eng, max_new={"decode": 0}):
            eng._decode.compile()
    # a body exception propagates un-masked (no budget check on top)
    eng2 = FakeEngine()
    with pytest.raises(KeyError):
        with CompileGuard(eng2, max_new={"decode": 0}):
            eng2._decode.compile()
            raise KeyError("body error wins")


def test_strict_mode_raises_at_emit_site():
    eng = FakeEngine()
    guard = CompileGuard(eng, strict=True).attach()
    eng.bus.emit("token", 0.0)                       # unrelated: ignored
    eng.bus.emit("jit_trace", 0.0, first=True)       # first trace: fine
    with pytest.raises(CompileBudgetError, match="retrace outside warmup"):
        eng.bus.emit("jit_trace", 0.0, first=False, path="decode",
                     sig=("decode", 1, False))
    guard.detach()
    assert len(guard.retraces) == 1


def test_non_strict_records_without_raising():
    eng = FakeEngine()
    guard = CompileGuard(eng).attach()
    eng.bus.emit("jit_trace", 0.0, first=False)
    assert len(guard.retraces) == 1
    guard.detach()
    eng.bus.emit("jit_trace", 0.0, first=False)      # detached: unseen
    assert len(guard.retraces) == 1


def test_attach_detach_manage_bus_consumers():
    eng = FakeEngine()
    guard = CompileGuard(eng)
    assert guard.attach() is guard
    assert guard in eng.bus.consumers
    guard.attach()                                   # idempotent
    assert eng.bus.consumers.count(guard) == 1
    guard.detach()
    assert guard not in eng.bus.consumers


def test_attach_without_bus_is_an_error():
    class NoBus:
        _decode = FakeJit()
    with pytest.raises(ValueError, match="has no .bus"):
        CompileGuard(NoBus()).attach()


def test_warmup_suspends_strict_and_rebaselines():
    eng = FakeEngine()
    guard = CompileGuard(eng, max_new={"decode": 0}, strict=True).attach()
    with guard.warmup():
        eng._decode.compile(2)                       # warmup traces
        guard.consume(retrace_ev())                  # no raise inside warmup
    assert guard.retraces == []                      # cleared on exit
    guard.check()                                    # re-baselined: 0 new
    eng._decode.compile()
    with pytest.raises(CompileBudgetError):
        guard.check()
    guard.detach()


def test_consume_direct_event_objects():
    guard = CompileGuard(FakeEngine(), strict=True)
    guard._in_warmup = True
    guard.consume(retrace_ev())
    assert len(guard.retraces) == 1


def test_count_recompiles_helper():
    eng = FakeEngine()
    assert count_recompiles(eng, lambda: None) == 0
    assert count_recompiles(eng, lambda: eng._decode.compile(3)) == 3
    assert count_recompiles(eng, lambda: eng._prefill.compile(),
                            entry="prefill") == 1


# ---------------------------------------------------------------------------
# Regression half: real engine, injected recompile
# ---------------------------------------------------------------------------
SPEC = DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32)


@pytest.fixture(scope="module")
def served():
    """A small engine that has already served traffic (decode jit warm)."""
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.fold_in(rng, 7), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, SPEC)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=64,
                           clock=VirtualClock(0.0))
    eng.register_tenant("t0", deltas)
    rs = np.random.RandomState(0)
    eng.submit("t0", rs.randint(0, cfg.vocab, size=8), max_new_tokens=4)
    eng.run()
    return cfg, eng


def _inject_decode_recompile(eng):
    """Call the engine's decode jit with batch extent 1 instead of
    n_slots — a NEW signature, so the cache grows by one. The cache is
    sliced into a fresh copy first because the jit donates argument 1."""
    cache_copy = jax.tree.map(lambda x: x[:1], eng.kv.cache)
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    eng._decode(eng.base, cache_copy, tok, pos, None)


def test_steady_state_engine_passes_the_gate(served):
    _, eng = served
    guard = CompileGuard(eng, budgets={"decode": 1}, max_new={"decode": 0})
    guard.check()
    assert guard.new_compiles("decode") == 0
    assert "decode" in guard.entries() and "prefill" in guard.entries()


def test_guard_catches_injected_recompile(served):
    _, eng = served
    guard = CompileGuard(eng, max_new={"decode": 0}, label="inject")
    raw_before = eng._decode._cache_size()
    _inject_decode_recompile(eng)
    raw_delta = eng._decode._cache_size() - raw_before
    assert raw_delta >= 1                 # the injection really retraced
    # guard arithmetic == the raw _cache_size() delta the old call sites
    # hand-rolled, so the migration changed no semantics
    assert guard.new_compiles("decode") == raw_delta
    with pytest.raises(CompileBudgetError) as e:
        guard.check()
    assert "[inject]" in str(e.value) and "'decode'" in str(e.value)


def test_count_recompiles_on_real_engine(served):
    _, eng = served
    assert count_recompiles(eng, lambda: None) == 0
    # once the batch-1 signature is cached, a repeat injection reuses the
    # executable and the helper must report zero new compiles
    _inject_decode_recompile(eng)
    assert count_recompiles(
        eng, lambda: _inject_decode_recompile(eng)) == 0
