"""Chunked prefill: token identity, chunk-scheduler properties, tracing.

The acceptance bar for the chunked-prefill path:
* a chunked engine is **token-identical** to the whole-prompt engine on
  the same mixed stream — for plain attention, windowed attention (ring
  eviction mid-prompt), and state-carrying mixers (exact-length chunks),
* the chunk scheduler is safe under any interleaving: cursors advance
  strictly and resume exactly after a denied step, the budget never
  over-grants past its share, decode rows never starve,
* VirtualClock runs are byte-identical trace-to-trace, and the exported
  trace's ``prefill_chunk`` spans tile each prompt contiguously.

Determinism: every engine runs on a VirtualClock and every random draw
is explicitly seeded (the property tests must shrink reproducibly).
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import ContinuousEngine, Engine, VirtualClock
from repro.serve.scheduler import ChunkBudget, ChunkQueue, RequestQueue
from repro.serve.trace import Tracer, validate_chrome_trace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SPEC = DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32)


def _make_tenants(cfg, base, n, rng, scale=0.05):
    out = []
    for t in range(n):
        ft = jax.tree.map(
            lambda p, t=t: p + scale * jax.random.normal(
                jax.random.fold_in(rng, 7 + t), p.shape,
                jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        deltas, _ = compress(base, ft, SPEC)
        out.append(deltas)
    return out


@pytest.fixture(scope="module")
def llama_setup():
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 2, rng)
    return cfg, base, tenants


def _mixed_stream(cfg, rng, lengths, n_tenants):
    reqs = []
    for i, L in enumerate(lengths):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
        tenant = f"t{i % n_tenants}" if i % 3 else None
        reqs.append((tenant, prompt))
    return reqs


# ---------------------------------------------------------------------------
# Token identity: chunked == whole-prompt, across arch families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_size", [3, 8])
def test_chunked_token_identical_mixed_stream(llama_setup, chunk_size):
    """Staggered multi-tenant stream, more requests than slots, prompts
    spanning chunk boundaries (L < C, L == C, L > 2C): every request's
    output must match the whole-prompt reference engine exactly."""
    cfg, base, tenants = llama_setup
    eng = ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                           clock=VirtualClock(tick=1e-3),
                           chunked_prefill=True, chunk_size=chunk_size)
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)
    assert eng._chunk_pad                     # attention arch: padded chunks

    rng = jax.random.PRNGKey(9)
    stream = _mixed_stream(cfg, rng, (5, 9, 3, 12, 8, 7), 2)
    handles = [eng.submit(t, p, max_new_tokens=5, arrival=0.002 * i)
               for i, (t, p) in enumerate(stream)]
    eng.run()
    for (tenant, prompt), r in zip(stream, handles):
        want = ref.generate(tenant, prompt[None], max_new_tokens=5)[0]
        np.testing.assert_array_equal(r.output(), want, err_msg=str(tenant))


def test_chunked_ssm_exact_tail_chunks():
    """State-carrying mixers can't see pad tokens mid-sequence: chunks
    are exact-length (tail chunk shorter), still token-identical."""
    cfg = get_smoke_config("mamba2-370m")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 2, rng)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3),
                           chunked_prefill=True, chunk_size=4)
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)
    assert not eng._chunk_pad                 # exact buckets -> exact chunks

    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(rng, 60 + i), (L,), 0, cfg.vocab))
        for i, L in enumerate((6, 9, 5))]
    rs = [eng.submit(f"t{i % 2}", p, max_new_tokens=4)
          for i, p in enumerate(prompts)]
    eng.run()
    for i, (p, r) in enumerate(zip(prompts, rs)):
        want = ref.generate(f"t{i % 2}", p[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(r.output(), want)


def test_chunked_windowed_attention_ring():
    """Windowed layers evict ring entries as the chunk is written: the
    chunk path must attend BEFORE the scatter, or mid-prompt history
    silently vanishes. gemma3's mixed {global, window-8} layers cover
    both layer kinds in one model."""
    cfg = get_smoke_config("gemma3-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 1, rng)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3),
                           chunked_prefill=True, chunk_size=4)
    ref = Engine(cfg, base, max_seq=32)
    eng.register_tenant("t0", tenants[0])
    ref.register_tenant("t0", tenants[0])

    # prompts longer than the window (8) so eviction happens mid-prefill
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(rng, 80 + i), (L,), 0, cfg.vocab))
        for i, L in enumerate((11, 6, 14))]
    rs = [eng.submit("t0" if i % 2 else None, p, max_new_tokens=3)
          for i, p in enumerate(prompts)]
    eng.run()
    for i, (p, r) in enumerate(zip(prompts, rs)):
        want = ref.generate("t0" if i % 2 else None, p[None],
                            max_new_tokens=3)[0]
        np.testing.assert_array_equal(r.output(), want)


def test_chunk_size_validation():
    cfg = get_smoke_config("llama3.2-1b")
    base = lm.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ContinuousEngine(cfg, base, n_slots=2, max_seq=16,
                         chunked_prefill=True, chunk_size=0)
    with pytest.raises(ValueError):           # chunk can't exceed the ring
        ContinuousEngine(cfg, base, n_slots=2, max_seq=16,
                         chunked_prefill=True, chunk_size=17)
    # windowed arch: the smallest ring (window 8) bounds the chunk
    wcfg = get_smoke_config("gemma3-1b")
    wbase = lm.init_params(wcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ContinuousEngine(wcfg, wbase, n_slots=2, max_seq=32,
                         chunked_prefill=True, chunk_size=16)


# ---------------------------------------------------------------------------
# Trace: prefill_chunk spans, starvation-freedom, determinism
# ---------------------------------------------------------------------------
class _Recorder:
    def __init__(self):
        self.events = []

    def consume(self, ev):
        self.events.append(ev)


def _run_traced_chunked(chunk_size=4, tick=1e-3):
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    [deltas] = _make_tenants(cfg, base, 1, rng)
    tracer = Tracer()
    rec = _Recorder()
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=tick), trace=tracer,
                           chunked_prefill=True, chunk_size=chunk_size)
    eng.bus.attach(rec)
    eng.register_tenant("t0", deltas)
    lengths = (9, 5, 7, 11)
    for i, L in enumerate(lengths):
        eng.submit("t0" if i % 2 else None, np.arange(L) % cfg.vocab,
                   max_new_tokens=4, arrival=0.001 * i)
    eng.run()
    return tracer, rec, lengths, chunk_size


def test_chunked_trace_spans_and_no_starvation():
    tracer, rec, lengths, C = _run_traced_chunked()
    trace = tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []

    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"
             and e["name"] == "prefill_chunk"]
    assert len(spans) == sum(math.ceil(L / C) for L in lengths)

    # every step advances EVERY active decode row (no starvation): the
    # token events landing at a step's timestamp must cover n_active,
    # plus one first-token when that step completed a prompt
    by_kind = {}
    for ev in rec.events:
        by_kind.setdefault(ev.kind, []).append(ev)
    tokens_at = {}
    for ev in by_kind.get("token", []):
        tokens_at[ev.t] = tokens_at.get(ev.t, 0) + 1
    for step in by_kind["step"]:
        lasts = sum(1 for e in by_kind.get("prefill_chunk", [])
                    if e.t == step.t and e.attrs["last"])
        want = step.attrs["n_active"] + lasts
        if want:
            assert tokens_at.get(step.t, 0) == want
    # chunk cursors in the event stream tile each prompt contiguously
    cursors = {}
    for ev in by_kind["prefill_chunk"]:
        rid = ev.attrs["rid"]
        assert ev.attrs["start"] == cursors.get(rid, 0)
        cursors[rid] = ev.attrs["start"] + ev.attrs["length"]


def test_chunked_virtualclock_trace_byte_identical():
    """Same workload, fresh engine, same VirtualClock -> byte-identical
    trace JSON (the CI determinism contract extends to chunked mode)."""
    t1, _, _, _ = _run_traced_chunked()
    t2, _, _, _ = _run_traced_chunked()
    assert json.dumps(t1.to_chrome_trace(), sort_keys=True) \
        == json.dumps(t2.to_chrome_trace(), sort_keys=True)


# ---------------------------------------------------------------------------
# Property-based chunk-scheduler invariants (hypothesis; skipped if absent)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=120, deadline=None)
    @given(chunk_size=st.integers(1, 8),
           reqs=st.lists(st.tuples(
               st.integers(1, 40),                       # prompt length
               st.one_of(st.none(), st.floats(0, 10, allow_nan=False)),
               st.floats(0, 5, allow_nan=False)),        # arrival
               min_size=1, max_size=8),
           denies=st.lists(st.booleans(), max_size=64))
    def test_prop_chunk_queue_cursors_edf_resume(chunk_size, reqs, denies):
        """Any admission set, any budget-denial pattern: next_task always
        returns the EDF head's next chunk, a denied step repicks the
        IDENTICAL task later, cursors advance strictly monotonically by
        exactly the processed length, every request takes ceil(L/C)
        chunks, and a stale advance raises instead of corrupting."""
        q = RequestQueue()
        cq = ChunkQueue(chunk_size)
        for slot, (L, dl, arr) in enumerate(reqs):
            r = q.submit(None, np.zeros(L), arrival=arr, deadline=dl)
            cq.add(slot, r)
        chunks_taken = {}
        seen_cursor = {}
        deny = iter(denies)
        while len(cq):
            task = cq.next_task()
            # EDF: no queued request sorts strictly before the pick
            key = (task.request.deadline if task.request.deadline
                   is not None else float("inf"),
                   task.request.arrival, task.request.rid)
            for rid, (_, r) in cq._entries.items():
                assert key <= (r.deadline if r.deadline is not None
                               else float("inf"), r.arrival, rid)
            if next(deny, False):             # budget denied: no advance
                again = cq.next_task()
                assert (again.slot, again.request.rid, again.start,
                        again.length, again.last) == \
                    (task.slot, task.request.rid, task.start,
                     task.length, task.last)
                continue
            rid = task.request.rid
            assert task.start == seen_cursor.get(rid, 0)
            assert 1 <= task.length <= chunk_size
            assert task.last == \
                (task.start + task.length >= task.request.prompt_len)
            cq.advance(task)
            seen_cursor[rid] = task.start + task.length
            chunks_taken[rid] = chunks_taken.get(rid, 0) + 1
            if not task.last:
                assert cq.cursor(rid) == seen_cursor[rid]
                with pytest.raises(ValueError):
                    cq.advance(task)          # stale cursor must raise
            else:
                assert rid not in cq._entries
        assert len(cq) == 0 and cq.pending_tokens() == 0
        # every request consumed exactly ceil(L / C) chunks
        assert sorted(chunks_taken.values()) == sorted(
            math.ceil(L / chunk_size) for (L, _, _) in reqs)

    @settings(max_examples=200, deadline=None)
    @given(share=st.floats(0.05, 1.0, allow_nan=False),
           calls=st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6)),
                          min_size=1, max_size=80))
    def test_prop_chunk_budget_share_bounds(share, calls):
        """Deterministic token bucket: never grants without pending work,
        always grants when no decode rows need protecting, and over the
        decode-active calls grants at most ceil(share*n)+1 chunks while
        never going longer than ceil(1/share)+1 such calls between
        grants (chunks are throttled, never starved)."""
        b = ChunkBudget(share)
        active_calls = 0
        grants = 0
        gap = 0
        for n_decode, n_pending in calls:
            got = b.grant(n_decode, n_pending)
            if n_pending == 0:
                assert not got
                continue
            if n_decode == 0:
                assert got                    # nothing to protect: drain
                continue
            active_calls += 1
            if got:
                grants += 1
                gap = 0
            else:
                gap += 1
            assert gap <= math.ceil(1.0 / share) + 1
        assert grants <= math.ceil(share * active_calls) + 1
        if share == 1.0:
            assert grants == active_calls     # TTFT-first default

    def test_chunk_budget_validation():
        with pytest.raises(ValueError):
            ChunkBudget(0.0)
        with pytest.raises(ValueError):
            ChunkBudget(1.5)
        with pytest.raises(ValueError):
            ChunkQueue(0)
