"""Serving-path tests: cache consistency, ring buffers, multi-tenant engine.

Determinism: every random draw uses an explicitly seeded jax.random key
and engine time is injected via VirtualClock — no wall clock or global
RNG state reaches an assertion.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import Engine, VirtualClock

FAST_ARCHS = ["llama3.2-1b", "gemma3-1b", "mamba2-370m", "recurrentgemma-9b",
              "seamless-m4t-medium", "llama-3.2-vision-11b", "wizard-llama2-7b"]


def _cfg(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:  # avoid capacity-drop nondeterminism in consistency tests
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _batch(cfg, rng, B, S):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_feats"] = jax.random.normal(rng, (B, 8, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(rng, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


# a fast representative subset runs on every PR; the full arch sweep is
# the heavy nightly part
_SWEEP_FAST = {"llama3.2-1b", "mamba2-370m"}


@pytest.mark.parametrize(
    "arch",
    [a if a in _SWEEP_FAST else pytest.param(a, marks=pytest.mark.slow)
     for a in list_archs()])
def test_prefill_decode_matches_forward(arch):
    cfg = _cfg(arch)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    B, S, extra = 2, 12, 4
    batch = _batch(cfg, rng, B, S + extra)
    ref = lm.forward(cfg, params, batch)
    enc_len = 8 if cfg.family == "encdec" else 0
    cache = lm.init_cache(cfg, B, max_seq=S + extra, enc_len=enc_len)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :S]
    lg, cache = lm.prefill(cfg, params, pb, cache)
    errs = [float(jnp.max(jnp.abs(lg - ref[:, S - 1])))]
    for t in range(S, S + extra):
        lg, cache = lm.decode_step(cfg, params, cache, batch["tokens"][:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - ref[:, t]))))
    assert max(errs) < 0.15, errs


def test_ring_buffer_window_cache():
    """Decoding past the window: ring buffer must evict oldest correctly."""
    cfg = get_smoke_config("gemma3-1b")  # has 8-token local windows
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, rng)
    B, total = 1, 24
    toks = jax.random.randint(rng, (B, total), 0, cfg.vocab)
    ref = lm.forward(cfg, params, {"tokens": toks})
    cache = lm.init_cache(cfg, B, max_seq=total)
    lg, cache = lm.prefill(cfg, params, {"tokens": toks[:, :4]}, cache)
    errs = []
    for t in range(4, total):
        lg, cache = lm.decode_step(cfg, params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - ref[:, t]))))
    assert max(errs) < 0.15, errs


def test_engine_multi_tenant():
    cfg = _cfg("wizard-llama2-7b")
    rng = jax.random.PRNGKey(2)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(jax.random.PRNGKey(3), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, report = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32))
    # VirtualClock: the serve_batch shim's continuous engine must not read
    # wall-clock time in tests (deterministic metrics, reproducible runs)
    eng = Engine(cfg, base, max_seq=32, clock=VirtualClock(tick=1e-3))
    eng.register_tenant("math", deltas, report)

    prompts = np.asarray(jax.random.randint(rng, (2, 8), 0, cfg.vocab))
    gen_base = eng.generate(None, prompts, max_new_tokens=4)
    gen_t = eng.generate("math", prompts, max_new_tokens=4)
    assert gen_base.shape == gen_t.shape == (2, 4)
    # tenant delta must actually change behaviour vs raw base
    # (weights differ by a large perturbation)
    assert (gen_base != gen_t).any()

    reqs = [("math", prompts[0]), ("math", prompts[1]), ("math", prompts[0])]
    outs = eng.serve_batch(reqs, max_new_tokens=4)
    assert len(outs) == 3
    np.testing.assert_array_equal(outs[0], outs[2])

    rep = eng.memory_report()
    assert rep["delta_bytes_total"] < rep["base_bytes"]


def test_tenant_generation_matches_merged_weights():
    """The engine's separate computation must reproduce the merged model."""
    from repro.core import decompress
    cfg = _cfg("llama3.2-1b")
    rng = jax.random.PRNGKey(4)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.03 * jax.random.normal(jax.random.PRNGKey(5), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    deltas, _ = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32))
    merged = decompress(base, deltas)

    eng_sep = Engine(cfg, base, max_seq=24)
    eng_sep.register_tenant("t", deltas)
    eng_merged = Engine(cfg, merged, max_seq=24)

    prompts = np.asarray(jax.random.randint(rng, (2, 8), 0, cfg.vocab))
    g1 = eng_sep.generate("t", prompts, max_new_tokens=6)
    g2 = eng_merged.generate(None, prompts, max_new_tokens=6)
    np.testing.assert_array_equal(g1, g2)
