import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeltaDQSpec, candidate_group_sizes, search_direct, search_proxy


def test_candidates():
    assert candidate_group_sizes(256, 8) == [8, 16, 32, 64, 128, 256]
    assert candidate_group_sizes(96, 4)[-1] == 96
    for c in candidate_group_sizes(96, 4):
        assert 96 % c == 0


def test_proxy_search_runs_and_prefers_low_error():
    rng = jax.random.PRNGKey(0)
    d_model = 128
    wq_b = jax.random.normal(rng, (d_model, 64)) * 0.1
    wk_b = jax.random.normal(jax.random.fold_in(rng, 1), (d_model, 64)) * 0.1
    wq_f = wq_b + jax.random.normal(jax.random.fold_in(rng, 2), wq_b.shape) * 0.01
    wk_f = wk_b + jax.random.normal(jax.random.fold_in(rng, 3), wk_b.shape) * 0.01
    x = jax.random.normal(jax.random.fold_in(rng, 4), (16, d_model))
    spec = DeltaDQSpec(alpha=4.0, k_bits=None)
    res = search_proxy(x, wq_b, wk_b, wq_f, wk_f, spec)
    assert res.h_g_star in candidate_group_sizes(d_model, 4)
    assert res.errors[res.h_g_star] == min(res.errors.values())
    assert res.method == "proxy"


def test_direct_search_api():
    # direct search over a known convex-ish score
    scores = {4: 3.0, 8: 1.0, 16: 2.0, 32: 5.0, 64: 6.0, 128: 7.0}
    res = search_direct(lambda hg: scores[hg], 128, DeltaDQSpec(alpha=4.0))
    assert res.h_g_star == 8
    assert res.method == "direct"


def test_proxy_agrees_with_direct_on_layer_error():
    """When the direct objective IS the attention error, both must agree."""
    rng = jax.random.PRNGKey(7)
    d_model = 64
    wq_b = jax.random.normal(rng, (d_model, 32)) * 0.1
    wk_b = jax.random.normal(jax.random.fold_in(rng, 1), (d_model, 32)) * 0.1
    wq_f = wq_b + jax.random.normal(jax.random.fold_in(rng, 2), wq_b.shape) * 0.02
    wk_f = wk_b + jax.random.normal(jax.random.fold_in(rng, 3), wk_b.shape) * 0.02
    x = jax.random.normal(jax.random.fold_in(rng, 4), (8, d_model))
    spec = DeltaDQSpec(alpha=4.0, seed=0)

    proxy = search_proxy(x, wq_b, wk_b, wq_f, wk_f, spec)

    from repro.core.groupsearch import attention_proxy_error
    direct = search_direct(
        lambda hg: float(attention_proxy_error(x, wq_b, wk_b, wq_f, wk_f, hg, spec,
                                               jax.random.fold_in(jax.random.PRNGKey(0), hg))),
        d_model, spec)
    assert proxy.h_g_star == direct.h_g_star
