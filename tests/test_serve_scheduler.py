"""Continuous-batching scheduler invariants.

The acceptance bar for the serving subsystem:
* mixed-tenant slot batches are token-identical to the per-tenant
  reference path,
* eviction never drops an unfinished sequence (everything submitted
  completes, bit-exact, even under slot pressure),
* jit compile count stays bounded by the number of length buckets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    DeltaDQSpec,
    compress,
    stack_tenant_deltas,
    wrap_slot_deltas,
    zero_delta_like,
)
from repro.models import lm
from repro.serve import (
    ContinuousEngine,
    Engine,
    LengthBuckets,
    RequestQueue,
    Scheduler,
    VirtualClock,
    mask_after_stop,
)

SPEC = DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32)


def _make_tenants(cfg, base, n, rng, scale=0.05):
    out = []
    for t in range(n):
        ft = jax.tree.map(
            lambda p, t=t: p + scale * jax.random.normal(
                jax.random.fold_in(rng, 7 + t), p.shape, jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        deltas, _ = compress(base, ft, SPEC)
        out.append(deltas)
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 3, rng)
    return cfg, base, tenants


# ---------------------------------------------------------------------------
# Unit: scheduler policy pieces (no jax)
# ---------------------------------------------------------------------------
def test_length_buckets_pow2_and_exact():
    b = LengthBuckets(min_bucket=8, max_bucket=64)
    assert [b.bucket(n) for n in (1, 8, 9, 16, 33)] == [8, 8, 16, 16, 64]
    assert b.seen == {8, 16, 64}
    with pytest.raises(ValueError):
        b.bucket(65)
    e = LengthBuckets(min_bucket=8, exact=True)
    assert e.bucket(13) == 13
    # non-power-of-two cap: clamp, don't overshoot past prompts that fit
    c = LengthBuckets(min_bucket=8, max_bucket=48)
    assert c.bucket(33) == 48


def test_queue_deadline_priority():
    q = RequestQueue()
    r_late = q.submit("a", np.zeros(4), arrival=0.0, deadline=9.0)
    r_urgent = q.submit("b", np.zeros(4), arrival=0.0, deadline=1.0)
    r_future = q.submit("c", np.zeros(4), arrival=5.0)
    assert q.pop_ready(0.0) is r_urgent
    assert q.pop_ready(0.0) is r_late
    assert q.pop_ready(0.0) is None          # not yet arrived
    assert q.pop_ready(6.0) is r_future


def test_scheduler_refuses_to_evict_unfinished():
    q = RequestQueue()
    q.submit("a", np.zeros(4))
    sched = Scheduler(2, LengthBuckets())
    [(slot, req)] = sched.admit(q, now=0.0)
    from repro.serve.scheduler import SlotState
    sched.place(slot, SlotState(request=req, next_token=0, pos=4, tenant_row=1))
    with pytest.raises(RuntimeError):
        sched.release(slot)
    req.t_done = 1.0                          # finished -> release allowed
    assert sched.release(slot) is req
    assert sched.free_slots() == [0, 1]


# ---------------------------------------------------------------------------
# Satellite fixes
# ---------------------------------------------------------------------------
def test_stop_token_mask_no_wraparound():
    stop = 7
    # stop token in the FINAL step must not corrupt column 0 (np.roll bug)
    gen = np.array([[3, 4, 5, 7],
                    [7, 1, 2, 3],
                    [1, 7, 7, 2],
                    [1, 2, 3, 4]])
    out = mask_after_stop(gen, stop)
    np.testing.assert_array_equal(out, np.array([
        [3, 4, 5, 7],          # final-step stop: earlier columns untouched
        [7, 7, 7, 7],          # everything after first stop masked
        [1, 7, 7, 7],
        [1, 2, 3, 4],          # no stop: unchanged
    ]))


def test_memory_report_baselines_pinned(dense_setup):
    cfg, base, tenants = dense_setup
    eng = Engine(cfg, base, max_seq=16)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
    rep = eng.memory_report()
    base_b, delta_b, n = rep["base_bytes"], rep["delta_bytes_total"], 3
    assert rep["n_tenants"] == n
    # ours vs n full fine-tuned models (paper Fig. 2 comparison)
    assert rep["bytes_vs_n_full_models"] == pytest.approx(
        (base_b + delta_b) / (n * base_b))
    # ours vs base + n full models (control arm kept resident)
    assert rep["bytes_vs_base_plus_n_full"] == pytest.approx(
        (base_b + delta_b) / ((n + 1) * base_b))
    assert rep["bytes_vs_n_full_models"] < 1.0


# ---------------------------------------------------------------------------
# Slot-dispatch numerics: gathered per-slot deltas == per-tenant deltas
# ---------------------------------------------------------------------------
def test_slot_decode_logits_match_per_tenant(dense_setup):
    cfg, base, tenants = dense_setup
    max_seq = 32
    rng = jax.random.PRNGKey(3)
    prompt = jnp.asarray(jax.random.randint(rng, (1, 6), 0, cfg.vocab))
    stacked = stack_tenant_deltas([zero_delta_like(tenants[0])] + tenants)

    # reference: each tenant decodes alone (scalar-pos path)
    ref_logits = []
    for d in [None] + tenants:
        cache = lm.init_cache(cfg, 1, max_seq)
        lg, cache = lm.prefill(cfg, base, {"tokens": prompt}, cache, deltas=d)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg, _ = lm.decode_step(cfg, base, cache, tok[:, None], jnp.int32(6), deltas=d)
        ref_logits.append(np.asarray(lg[0]))

    # mixed: all four rows (base + 3 tenants) in one slot batch
    B = 4
    cache = lm.init_cache(cfg, B, max_seq)
    toks = jnp.tile(prompt, (B, 1))
    lg, cache = lm.prefill(cfg, base, {"tokens": toks}, cache,
                           deltas=wrap_slot_deltas(stacked, jnp.arange(B)))
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg, _ = lm.decode_step(cfg, base, cache, tok[:, None],
                           jnp.full((B,), 6, jnp.int32),
                           deltas=wrap_slot_deltas(stacked, jnp.arange(B)))
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(lg[b]), ref_logits[b])


def test_delta_spmm_slots_matches_per_row_reference():
    from repro.core import groupwise_dropout_pack, reconstruct_dense
    from repro.core.apply import stack_tenant_deltas as stack
    from repro.kernels import ops
    rng = jax.random.PRNGKey(0)
    h_in, h_out, B = 64, 32, 5
    deltas = [groupwise_dropout_pack(jax.random.fold_in(rng, t),
                                     jax.random.normal(jax.random.fold_in(rng, 10 + t),
                                                       (h_in, h_out)) * 0.01,
                                     h_g=16, alpha=2.0, k_bits=8, m=1)
              for t in range(3)]
    stacked = stack(deltas)
    slots = jnp.asarray([0, 2, 1, 1, 0])
    x = jax.random.normal(jax.random.fold_in(rng, 99), (B, 1, h_in))
    from repro.core.apply import SlotDelta
    gathered = SlotDelta(stacked, slots).gather()
    y = ops.delta_spmm_slots(x, gathered)
    for b in range(B):
        want = x[b] @ reconstruct_dense(deltas[int(slots[b])], dtype=x.dtype)
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine invariants under a mixed randomized stream
# ---------------------------------------------------------------------------
def test_mixed_stream_token_identical_and_bounded_compiles(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)

    # >=3 tenants (incl. base), >=2 prompt lengths, staggered arrivals,
    # more requests than slots
    rng = jax.random.PRNGKey(9)
    lengths = [5, 9, 7, 12, 5, 9, 3, 7]
    reqs = []
    for i, L in enumerate(lengths):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
        tenant = f"t{i % 3}" if i % 4 else None
        reqs.append((tenant, prompt,
                     eng.submit(tenant, prompt, max_new_tokens=6,
                                arrival=0.002 * i)))
    metrics = eng.run()

    for tenant, prompt, r in reqs:
        want = ref.generate(tenant, prompt[None], max_new_tokens=6)[0]
        np.testing.assert_array_equal(r.output(), want, err_msg=str(tenant))

    # jit compiled at most once per length bucket (prefill) + once (decode)
    assert eng.prefill_shapes == {8, 16}
    assert eng._prefill._cache_size() <= len(eng.prefill_shapes)
    assert eng._decode._cache_size() == 1

    rep = metrics.report()
    assert rep["prefills"] == len(lengths)
    assert rep["total_tokens"] == 6 * len(lengths)
    assert 0.0 < rep["batch_occupancy"] <= 1.0
    for name in ("t0", "t1", "t2", "__base__"):
        t = rep["tenants"][name]
        assert t["requests"] >= 1 and t["ttft_p50"] is not None


def test_per_row_dispatch_token_identical(dense_setup):
    """The legacy per-row dispatch (behind the slot_dispatch flag) must
    produce the exact same tokens as the default segment dispatch and
    the per-tenant reference engine."""
    cfg, base, tenants = dense_setup
    ref = Engine(cfg, base, max_seq=32)
    engines = {
        mode: ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                               clock=VirtualClock(tick=1e-3),
                               slot_dispatch=mode)
        for mode in ("segments", "per_row")
    }
    for i, d in enumerate(tenants):
        ref.register_tenant(f"t{i}", d)
        for eng in engines.values():
            eng.register_tenant(f"t{i}", d)

    rng = jax.random.PRNGKey(11)
    lengths = [5, 9, 7, 5, 3]
    outs = {}
    for mode, eng in engines.items():
        reqs = []
        for i, L in enumerate(lengths):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
            tenant = f"t{i % 3}" if i % 2 else None
            reqs.append((tenant, prompt,
                         eng.submit(tenant, prompt, max_new_tokens=5,
                                    arrival=0.002 * i)))
        eng.run()
        outs[mode] = reqs

    for (t_a, p_a, r_a), (t_b, p_b, r_b) in zip(outs["segments"],
                                                outs["per_row"]):
        np.testing.assert_array_equal(r_a.output(), r_b.output(),
                                      err_msg=str(t_a))
        want = ref.generate(t_a, p_a[None], max_new_tokens=5)[0]
        np.testing.assert_array_equal(r_a.output(), want, err_msg=str(t_a))


def test_eviction_never_drops_unfinished_randomized(dense_setup):
    """Slot pressure + random lengths/budgets: every request completes
    bit-exact; slots are only recycled after their sequence finishes."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)

    rs = np.random.RandomState(42)
    reqs = []
    for i in range(10):
        L = int(rs.randint(3, 14))
        n_new = int(rs.randint(1, 8))
        prompt = rs.randint(0, cfg.vocab, size=L)
        tenant = f"t{rs.randint(3)}"
        reqs.append((tenant, prompt, n_new,
                     eng.submit(tenant, prompt, max_new_tokens=n_new,
                                arrival=float(rs.rand() * 0.01))))
    eng.run()

    for tenant, prompt, n_new, r in reqs:
        assert r.done and len(r.tokens) == n_new
        want = ref.generate(tenant, prompt[None], max_new_tokens=n_new)[0]
        np.testing.assert_array_equal(r.output(), want)
    assert eng.kv.n_free == eng.n_slots          # all slots returned
    assert eng.sched.active_slots() == []


def test_stop_token_frees_slot_early(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=32)
    eng.register_tenant("t0", tenants[0])
    ref = Engine(cfg, base, max_seq=32)
    ref.register_tenant("t0", tenants[0])

    prompt = np.arange(5) % cfg.vocab
    want = ref.generate("t0", prompt[None], max_new_tokens=8)[0]
    stop = int(want[2])                           # force an early stop
    r1 = eng.submit("t0", prompt, max_new_tokens=8, stop_token=stop)
    r2 = eng.submit("t0", prompt, max_new_tokens=4)
    eng.run()
    assert r1.done and r1.tokens[-1] == stop and len(r1.tokens) <= 3
    assert r2.done and len(r2.tokens) == 4        # queued request still served


def test_serve_batch_shim_matches_generate(dense_setup):
    cfg, base, tenants = dense_setup
    eng = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (6,), 0, cfg.vocab))
        for i in range(4)]
    reqs = [("t0", prompts[0]), ("t1", prompts[1]),
            ("t0", prompts[2]), ("t2", prompts[3])]
    outs = eng.serve_batch(reqs, max_new_tokens=4)
    assert len(outs) == 4
    for (tenant, prompt), out in zip(reqs, outs):
        want = eng.generate(tenant, prompt[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(out, want)


def test_continuous_engine_ssm_exact_buckets():
    """State-carrying mixers can't be left-padded: exact buckets, still
    token-identical through the slot path."""
    cfg = get_smoke_config("mamba2-370m")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 2, rng)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32)
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)
    assert eng.buckets.exact

    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(rng, 60 + i), (L,), 0, cfg.vocab))
        for i, L in enumerate((6, 9, 6))]
    rs = [eng.submit(f"t{i % 2}", p, max_new_tokens=4)
          for i, p in enumerate(prompts)]
    eng.run()
    for i, (p, r) in enumerate(zip(prompts, rs)):
        want = ref.generate(f"t{i % 2}", p[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(r.output(), want)


def test_incompatible_tenant_rejected_at_registration(dense_setup):
    """A tenant whose packing spec can't join the stack fails at
    register_tenant, not mid-run — and the engine stays fully usable."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32)
    eng.register_tenant("t0", tenants[0])

    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.PRNGKey(77), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    other_spec, _ = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=16))
    with pytest.raises(ValueError):
        eng.register_tenant("bad", other_spec)
    assert "bad" not in {t.name for t in eng.store.ordered()}

    # engine still serves, no slot was leaked
    r = eng.submit("t0", np.arange(5) % cfg.vocab, max_new_tokens=3)
    eng.run()
    assert r.done and len(r.tokens) == 3
    assert eng.kv.n_free == eng.n_slots


def test_clamped_bucket_pad_overwrite_token_identical(dense_setup):
    """Non-pow2 max_seq: the bucket clamps to max_seq and decode reuses
    pad ring slots; output must still match the reference exactly, and
    genuinely overlong requests must still be rejected."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=48)
    ref = Engine(cfg, base, max_seq=48)
    eng.register_tenant("t0", tenants[0])
    ref.register_tenant("t0", tenants[0])
    prompt = np.arange(33) % cfg.vocab        # bucket 64 -> clamped to 48
    r = eng.submit("t0", prompt, max_new_tokens=5)
    eng.run()
    want = ref.generate("t0", prompt[None], max_new_tokens=5)[0]
    np.testing.assert_array_equal(r.output(), want)
    with pytest.raises(ValueError):
        eng.submit("t0", np.arange(45) % cfg.vocab, max_new_tokens=5)


def test_live_unregister_refuses_to_remap_inflight_rows(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=32)
    eng.register_tenant("t0", tenants[0])
    eng.register_tenant("t1", tenants[1])
    eng.submit("t1", np.arange(5) % cfg.vocab, max_new_tokens=6)
    eng.step(0.0)                    # prefill + first decode, in flight
    eng.store.unregister("t0")       # would shift t1's stack row 2 -> 1
    with pytest.raises(RuntimeError, match="rows shifted"):
        eng.step(0.0)


def test_moe_tenants_fall_back_to_grouped():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    [deltas] = _make_tenants(cfg, base, 1, rng)
    eng = Engine(cfg, base, max_seq=32)
    eng.register_tenant("m", deltas)
    prompts = np.asarray(jax.random.randint(rng, (2, 6), 0, cfg.vocab))
    reqs = [("m", prompts[0]), ("m", prompts[1]), ("m", prompts[0])]
    outs = eng.serve_batch(reqs, max_new_tokens=3)   # falls back, no crash
    assert len(outs) == 3
    np.testing.assert_array_equal(outs[0], outs[2])
