"""Continuous-batching scheduler invariants.

The acceptance bar for the serving subsystem:
* mixed-tenant slot batches are token-identical to the per-tenant
  reference path,
* eviction never drops an unfinished sequence (everything submitted
  completes, bit-exact, even under slot pressure),
* jit compile count stays bounded by the number of length buckets,
* with data shards: admission is occupancy-balanced, deterministic,
  and data=N decode is token-identical to data=1.

Determinism: every engine in this module runs on a VirtualClock (no
wall-clock time reaches an assertion) and every random draw is an
explicitly seeded np.random.RandomState / jax.random key — the
property tests below must shrink reproducibly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import CompileGuard
from repro.configs import get_smoke_config
from repro.core import (
    DeltaDQSpec,
    compress,
    stack_tenant_deltas,
    wrap_slot_deltas,
    zero_delta_like,
)
from repro.models import lm
from repro.serve import (
    AffinityAdmission,
    BalancedAdmission,
    ContinuousEngine,
    DeltaResidency,
    Engine,
    LengthBuckets,
    RequestQueue,
    Scheduler,
    SlotKVCache,
    SlotState,
    VirtualClock,
    make_admission,
    mask_after_stop,
    tenant_segments,
    tenant_segments_sharded,
)

# hypothesis is optional: the property-based suite needs it, but the
# deterministic invariants must run everywhere (bare CPU containers)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SPEC = DeltaDQSpec(alpha=2.0, k_bits=8, h_g=32)


def _make_tenants(cfg, base, n, rng, scale=0.05):
    out = []
    for t in range(n):
        ft = jax.tree.map(
            lambda p, t=t: p + scale * jax.random.normal(
                jax.random.fold_in(rng, 7 + t), p.shape, jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        deltas, _ = compress(base, ft, SPEC)
        out.append(deltas)
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 3, rng)
    return cfg, base, tenants


# ---------------------------------------------------------------------------
# Unit: scheduler policy pieces (no jax)
# ---------------------------------------------------------------------------
def test_length_buckets_pow2_and_exact():
    b = LengthBuckets(min_bucket=8, max_bucket=64)
    assert [b.bucket(n) for n in (1, 8, 9, 16, 33)] == [8, 8, 16, 16, 64]
    assert b.seen == {8, 16, 64}
    with pytest.raises(ValueError):
        b.bucket(65)
    e = LengthBuckets(min_bucket=8, exact=True)
    assert e.bucket(13) == 13
    # non-power-of-two cap: clamp, don't overshoot past prompts that fit
    c = LengthBuckets(min_bucket=8, max_bucket=48)
    assert c.bucket(33) == 48


def test_queue_deadline_priority():
    q = RequestQueue()
    r_late = q.submit("a", np.zeros(4), arrival=0.0, deadline=9.0)
    r_urgent = q.submit("b", np.zeros(4), arrival=0.0, deadline=1.0)
    r_future = q.submit("c", np.zeros(4), arrival=5.0)
    assert q.pop_ready(0.0) is r_urgent
    assert q.pop_ready(0.0) is r_late
    assert q.pop_ready(0.0) is None          # not yet arrived
    assert q.pop_ready(6.0) is r_future


def test_scheduler_refuses_to_evict_unfinished():
    q = RequestQueue()
    q.submit("a", np.zeros(4))
    sched = Scheduler(2, LengthBuckets())
    [(slot, req)] = sched.admit(q, now=0.0)
    from repro.serve.scheduler import SlotState
    sched.place(slot, SlotState(request=req, next_token=0, pos=4, tenant_row=1))
    with pytest.raises(RuntimeError):
        sched.release(slot)
    req.t_done = 1.0                          # finished -> release allowed
    assert sched.release(slot) is req
    assert sched.free_slots() == [0, 1]


# ---------------------------------------------------------------------------
# Satellite fixes
# ---------------------------------------------------------------------------
def test_stop_token_mask_no_wraparound():
    stop = 7
    # stop token in the FINAL step must not corrupt column 0 (np.roll bug)
    gen = np.array([[3, 4, 5, 7],
                    [7, 1, 2, 3],
                    [1, 7, 7, 2],
                    [1, 2, 3, 4]])
    out = mask_after_stop(gen, stop)
    np.testing.assert_array_equal(out, np.array([
        [3, 4, 5, 7],          # final-step stop: earlier columns untouched
        [7, 7, 7, 7],          # everything after first stop masked
        [1, 7, 7, 7],
        [1, 2, 3, 4],          # no stop: unchanged
    ]))


def test_memory_report_baselines_pinned(dense_setup):
    cfg, base, tenants = dense_setup
    eng = Engine(cfg, base, max_seq=16)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
    rep = eng.memory_report()
    base_b, delta_b, n = rep["base_bytes"], rep["delta_bytes_total"], 3
    assert rep["n_tenants"] == n
    # ours vs n full fine-tuned models (paper Fig. 2 comparison)
    assert rep["bytes_vs_n_full_models"] == pytest.approx(
        (base_b + delta_b) / (n * base_b))
    # ours vs base + n full models (control arm kept resident)
    assert rep["bytes_vs_base_plus_n_full"] == pytest.approx(
        (base_b + delta_b) / ((n + 1) * base_b))
    assert rep["bytes_vs_n_full_models"] < 1.0


# ---------------------------------------------------------------------------
# Slot-dispatch numerics: gathered per-slot deltas == per-tenant deltas
# ---------------------------------------------------------------------------
@pytest.mark.slow  # ~35s logits-level sweep; the engine-level token-
# identity tests below pin the same contract end to end; nightly runs this
def test_slot_decode_logits_match_per_tenant(dense_setup):
    cfg, base, tenants = dense_setup
    max_seq = 32
    rng = jax.random.PRNGKey(3)
    prompt = jnp.asarray(jax.random.randint(rng, (1, 6), 0, cfg.vocab))
    stacked = stack_tenant_deltas([zero_delta_like(tenants[0])] + tenants)

    # reference: each tenant decodes alone (scalar-pos path)
    ref_logits = []
    for d in [None] + tenants:
        cache = lm.init_cache(cfg, 1, max_seq)
        lg, cache = lm.prefill(cfg, base, {"tokens": prompt}, cache, deltas=d)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg, _ = lm.decode_step(cfg, base, cache, tok[:, None], jnp.int32(6), deltas=d)
        ref_logits.append(np.asarray(lg[0]))

    # mixed: all four rows (base + 3 tenants) in one slot batch
    B = 4
    cache = lm.init_cache(cfg, B, max_seq)
    toks = jnp.tile(prompt, (B, 1))
    lg, cache = lm.prefill(cfg, base, {"tokens": toks}, cache,
                           deltas=wrap_slot_deltas(stacked, jnp.arange(B)))
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg, _ = lm.decode_step(cfg, base, cache, tok[:, None],
                           jnp.full((B,), 6, jnp.int32),
                           deltas=wrap_slot_deltas(stacked, jnp.arange(B)))
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(lg[b]), ref_logits[b])


def test_delta_spmm_slots_matches_per_row_reference():
    from repro.core import groupwise_dropout_pack, reconstruct_dense
    from repro.core.apply import stack_tenant_deltas as stack
    from repro.kernels import ops
    rng = jax.random.PRNGKey(0)
    h_in, h_out, B = 64, 32, 5
    deltas = [groupwise_dropout_pack(jax.random.fold_in(rng, t),
                                     jax.random.normal(jax.random.fold_in(rng, 10 + t),
                                                       (h_in, h_out)) * 0.01,
                                     h_g=16, alpha=2.0, k_bits=8, m=1)
              for t in range(3)]
    stacked = stack(deltas)
    slots = jnp.asarray([0, 2, 1, 1, 0])
    x = jax.random.normal(jax.random.fold_in(rng, 99), (B, 1, h_in))
    from repro.core.apply import SlotDelta
    gathered = SlotDelta(stacked, slots).gather()
    y = ops.delta_spmm_slots(x, gathered)
    for b in range(B):
        want = x[b] @ reconstruct_dense(deltas[int(slots[b])], dtype=x.dtype)
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine invariants under a mixed randomized stream
# ---------------------------------------------------------------------------
def test_mixed_stream_token_identical_and_bounded_compiles(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)

    # >=3 tenants (incl. base), >=2 prompt lengths, staggered arrivals,
    # more requests than slots
    rng = jax.random.PRNGKey(9)
    lengths = [5, 9, 7, 12, 5, 9, 3, 7]
    reqs = []
    for i, L in enumerate(lengths):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
        tenant = f"t{i % 3}" if i % 4 else None
        reqs.append((tenant, prompt,
                     eng.submit(tenant, prompt, max_new_tokens=6,
                                arrival=0.002 * i)))
    metrics = eng.run()

    for tenant, prompt, r in reqs:
        want = ref.generate(tenant, prompt[None], max_new_tokens=6)[0]
        np.testing.assert_array_equal(r.output(), want, err_msg=str(tenant))

    # jit compiled at most once per length bucket (prefill) + once (decode)
    assert eng.prefill_shapes == {8, 16}
    CompileGuard(eng, budgets={"prefill": len(eng.prefill_shapes),
                               "decode": 1}).check()

    rep = metrics.report()
    assert rep["prefills"] == len(lengths)
    assert rep["total_tokens"] == 6 * len(lengths)
    assert 0.0 < rep["batch_occupancy"] <= 1.0
    for name in ("t0", "t1", "t2", "__base__"):
        t = rep["tenants"][name]
        assert t["requests"] >= 1 and t["ttft_p50"] is not None


def test_per_row_dispatch_smoke_token_identical(dense_setup):
    """Cheap tier-1 guard for the legacy per_row dispatch (the full
    mixed-stream version below is slow-marked/nightly): one mixed
    2-request trace must match the segments engine token for token."""
    cfg, base, tenants = dense_setup
    outs = {}
    for mode in ("segments", "per_row"):
        eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                               clock=VirtualClock(tick=1e-3),
                               slot_dispatch=mode)
        eng.register_tenant("t0", tenants[0])
        reqs = [eng.submit(t, np.arange(5) % cfg.vocab, max_new_tokens=3)
                for t in ("t0", None)]
        eng.run()
        outs[mode] = [r.output() for r in reqs]
    for a, b in zip(outs["segments"], outs["per_row"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow  # ~24s legacy-dispatch stream; nightly runs it
def test_per_row_dispatch_token_identical(dense_setup):
    """The legacy per-row dispatch (behind the slot_dispatch flag) must
    produce the exact same tokens as the default segment dispatch and
    the per-tenant reference engine."""
    cfg, base, tenants = dense_setup
    ref = Engine(cfg, base, max_seq=32)
    engines = {
        mode: ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                               clock=VirtualClock(tick=1e-3),
                               slot_dispatch=mode)
        for mode in ("segments", "per_row")
    }
    for i, d in enumerate(tenants):
        ref.register_tenant(f"t{i}", d)
        for eng in engines.values():
            eng.register_tenant(f"t{i}", d)

    rng = jax.random.PRNGKey(11)
    lengths = [5, 9, 7, 5, 3]
    outs = {}
    for mode, eng in engines.items():
        reqs = []
        for i, L in enumerate(lengths):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
            tenant = f"t{i % 3}" if i % 2 else None
            reqs.append((tenant, prompt,
                         eng.submit(tenant, prompt, max_new_tokens=5,
                                    arrival=0.002 * i)))
        eng.run()
        outs[mode] = reqs

    for (t_a, p_a, r_a), (t_b, p_b, r_b) in zip(outs["segments"],
                                                outs["per_row"]):
        np.testing.assert_array_equal(r_a.output(), r_b.output(),
                                      err_msg=str(t_a))
        want = ref.generate(t_a, p_a[None], max_new_tokens=5)[0]
        np.testing.assert_array_equal(r_a.output(), want, err_msg=str(t_a))


def test_eviction_never_drops_unfinished_randomized(dense_setup):
    """Slot pressure + random lengths/budgets: every request completes
    bit-exact; slots are only recycled after their sequence finishes."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)

    rs = np.random.RandomState(42)
    reqs = []
    for i in range(10):
        L = int(rs.randint(3, 14))
        n_new = int(rs.randint(1, 8))
        prompt = rs.randint(0, cfg.vocab, size=L)
        tenant = f"t{rs.randint(3)}"
        reqs.append((tenant, prompt, n_new,
                     eng.submit(tenant, prompt, max_new_tokens=n_new,
                                arrival=float(rs.rand() * 0.01))))
    eng.run()

    for tenant, prompt, n_new, r in reqs:
        assert r.done and len(r.tokens) == n_new
        want = ref.generate(tenant, prompt[None], max_new_tokens=n_new)[0]
        np.testing.assert_array_equal(r.output(), want)
    assert eng.kv.n_free == eng.n_slots          # all slots returned
    assert eng.sched.active_slots() == []


def test_stop_token_frees_slot_early(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    eng.register_tenant("t0", tenants[0])
    ref = Engine(cfg, base, max_seq=32)
    ref.register_tenant("t0", tenants[0])

    prompt = np.arange(5) % cfg.vocab
    want = ref.generate("t0", prompt[None], max_new_tokens=8)[0]
    stop = int(want[2])                           # force an early stop
    r1 = eng.submit("t0", prompt, max_new_tokens=8, stop_token=stop)
    r2 = eng.submit("t0", prompt, max_new_tokens=4)
    eng.run()
    assert r1.done and r1.tokens[-1] == stop and len(r1.tokens) <= 3
    assert r2.done and len(r2.tokens) == 4        # queued request still served


def test_serve_batch_shim_matches_generate(dense_setup):
    cfg, base, tenants = dense_setup
    eng = Engine(cfg, base, max_seq=32, clock=VirtualClock(tick=1e-3))
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(1), i), (6,), 0, cfg.vocab))
        for i in range(4)]
    reqs = [("t0", prompts[0]), ("t1", prompts[1]),
            ("t0", prompts[2]), ("t2", prompts[3])]
    outs = eng.serve_batch(reqs, max_new_tokens=4)
    assert len(outs) == 4
    for (tenant, prompt), out in zip(reqs, outs):
        want = eng.generate(tenant, prompt[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(out, want)


def test_continuous_engine_ssm_exact_buckets():
    """State-carrying mixers can't be left-padded: exact buckets, still
    token-identical through the slot path."""
    cfg = get_smoke_config("mamba2-370m")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = _make_tenants(cfg, base, 2, rng)
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)
    assert eng.buckets.exact

    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(rng, 60 + i), (L,), 0, cfg.vocab))
        for i, L in enumerate((6, 9, 6))]
    rs = [eng.submit(f"t{i % 2}", p, max_new_tokens=4)
          for i, p in enumerate(prompts)]
    eng.run()
    for i, (p, r) in enumerate(zip(prompts, rs)):
        want = ref.generate(f"t{i % 2}", p[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(r.output(), want)


def test_heterogeneous_specs_register_into_codec_groups(dense_setup):
    """A tenant whose packing spec differs from the stack no longer fails
    at registration: it lands in its own codec group and serves
    token-identically to a per-tenant engine (the mixed-group contract).
    Tenants whose delta TREE STRUCTURE differs still fail up front."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    eng.register_tenant("t0", tenants[0])

    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.PRNGKey(77), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    other, _ = compress(base, ft, DeltaDQSpec(alpha=2.0, k_bits=8, h_g=16))
    eng.register_tenant("t-hetero", other)
    eng._refresh_stacked()
    assert len(eng._groups) == 2
    assert {t.name for t in eng.store.ordered()} == {"t0", "t-hetero"}

    # a tenant missing a compressed site (different None pattern) cannot
    # join any group — combining per-group corrections needs one tree shape
    flat, treedef = jax.tree.flatten(
        other, is_leaf=lambda x: x is not None and not isinstance(x, dict))
    bad = jax.tree.unflatten(
        treedef, [None if i == 0 else l for i, l in enumerate(flat)])
    with pytest.raises(ValueError, match="structure"):
        eng.register_tenant("bad", bad)
    assert "bad" not in {t.name for t in eng.store.ordered()}

    # both groups serve, token-identical to each tenant alone
    ref = Engine(cfg, base, max_seq=32)
    ref.register_tenant("t0", tenants[0])
    ref.register_tenant("t-hetero", other)
    p0 = np.arange(5) % cfg.vocab
    p1 = (np.arange(5) + 3) % cfg.vocab
    r0 = eng.submit("t0", p0, max_new_tokens=3)
    r1 = eng.submit("t-hetero", p1, max_new_tokens=3)
    eng.run()
    np.testing.assert_array_equal(
        r0.output(), ref.generate("t0", p0[None], max_new_tokens=3)[0])
    np.testing.assert_array_equal(
        r1.output(), ref.generate("t-hetero", p1[None], max_new_tokens=3)[0])
    assert eng.kv.n_free == eng.n_slots


def test_mixed_codec_engine_token_identical_to_alone(dense_setup):
    """Two tenants on two different CODECS (DeltaDQ + BitDelta) served by
    one engine: every request's tokens must match an engine serving only
    that tenant — the other codec group's zero row contributes exactly
    0.0 to the summed correction."""
    from repro.core import BitDeltaSpec
    cfg, base, tenants = dense_setup
    ft = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(
            jax.random.PRNGKey(88), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    bd, _ = compress(base, ft, BitDeltaSpec())
    fleet = {"t-dq": tenants[0], "t-bd": bd}
    prompts = {"t-dq": np.arange(6) % cfg.vocab,
               "t-bd": (np.arange(6) + 2) % cfg.vocab}

    alone = {}
    for name, d in fleet.items():
        e1 = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                              clock=VirtualClock(tick=1e-3))
        e1.register_tenant(name, d)
        alone[name] = e1.serve([(name, prompts[name])], max_new_tokens=5)[0]

    eng = ContinuousEngine(cfg, base, n_slots=2, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    for name, d in fleet.items():
        eng.register_tenant(name, d)
    outs = eng.serve([(n, prompts[n]) for n in fleet], max_new_tokens=5)
    assert len(eng._groups) == 2
    assert sorted(c for g in eng._groups for c in g.codecs) \
        == ["bitdelta", "deltadq"]
    for (name, _), out in zip(fleet.items(), outs):
        np.testing.assert_array_equal(out, alone[name])


def test_clamped_bucket_pad_overwrite_token_identical(dense_setup):
    """Non-pow2 max_seq: the bucket clamps to max_seq and decode reuses
    pad ring slots; output must still match the reference exactly, and
    genuinely overlong requests must still be rejected."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=48,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=48)
    eng.register_tenant("t0", tenants[0])
    ref.register_tenant("t0", tenants[0])
    prompt = np.arange(33) % cfg.vocab        # bucket 64 -> clamped to 48
    r = eng.submit("t0", prompt, max_new_tokens=5)
    eng.run()
    want = ref.generate("t0", prompt[None], max_new_tokens=5)[0]
    np.testing.assert_array_equal(r.output(), want)
    with pytest.raises(ValueError):
        eng.submit("t0", np.arange(45) % cfg.vocab, max_new_tokens=5)


def test_live_unregister_refuses_to_remap_inflight_rows(dense_setup):
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=1, max_seq=32,
                           clock=VirtualClock(tick=1e-3))
    eng.register_tenant("t0", tenants[0])
    eng.register_tenant("t1", tenants[1])
    eng.submit("t1", np.arange(5) % cfg.vocab, max_new_tokens=6)
    eng.step(0.0)                    # prefill + first decode, in flight
    eng.store.unregister("t0")       # would shift t1's stack row 2 -> 1
    with pytest.raises(RuntimeError, match="rows shifted"):
        eng.step(0.0)


def test_moe_tenants_fall_back_to_grouped():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    [deltas] = _make_tenants(cfg, base, 1, rng)
    eng = Engine(cfg, base, max_seq=32, clock=VirtualClock(tick=1e-3))
    eng.register_tenant("m", deltas)
    prompts = np.asarray(jax.random.randint(rng, (2, 6), 0, cfg.vocab))
    reqs = [("m", prompts[0]), ("m", prompts[1]), ("m", prompts[0])]
    outs = eng.serve_batch(reqs, max_new_tokens=3)   # falls back, no crash
    assert len(outs) == 3
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# Data-shard admission + sharded segment layout (host-side, no jax)
# ---------------------------------------------------------------------------
def _fill(sched, q, now=0.0):
    admitted = sched.admit(q, now)
    for slot, req in admitted:
        sched.place(slot, SlotState(request=req, next_token=0, pos=0,
                                    tenant_row=0))
    return admitted


def test_balanced_admission_deterministic_placement():
    """Least-occupied shard first, ties by lowest slot id — and the same
    trace replayed lands every request on the same slot."""
    def run():
        q = RequestQueue()
        for i in range(5):
            q.submit("t", np.zeros(2), arrival=0.0)
        sched = Scheduler(8, LengthBuckets(), data_shards=4)
        return [slot for slot, _ in _fill(sched, q)]

    assert run() == [0, 2, 4, 6, 1]          # round-robin-ish, deterministic
    assert run() == run()


def test_balanced_admission_prefers_drained_shard():
    q = RequestQueue()
    for i in range(4):
        q.submit("t", np.zeros(2), arrival=0.0)
    sched = Scheduler(4, LengthBuckets(), data_shards=2)
    _fill(sched, q)                           # both shards full
    for slot in (0, 1):                       # drain shard 0 entirely
        sched.slots[slot].request.t_done = 1.0
        sched.release(slot)
    q.submit("t", np.zeros(2), arrival=1.0)
    [(slot, _)] = _fill(sched, q, now=1.0)
    assert sched.shard_of(slot) == 0          # least-occupied shard wins
    assert sched.shard_occupancy() == [1, 2]


def test_affinity_admission_prefers_hosting_shard():
    """Same trace, two policies: occupancy breaks the tie onto shard 0's
    lowest slot; affinity routes the repeat tenant back to the shard
    already hosting it (fewer unique tenants per shard)."""
    def run(admission):
        q = RequestQueue()
        q.submit("a", np.zeros(2), arrival=0.0)
        q.submit("b", np.zeros(2), arrival=0.0)
        sched = Scheduler(8, LengthBuckets(), data_shards=2,
                          admission=admission)
        _fill(sched, q)                       # a -> shard 0, b -> shard 1
        q.submit("b", np.zeros(2), arrival=1.0)
        [(slot, _)] = _fill(sched, q, now=1.0)
        return sched.shard_of(slot)

    assert run("occupancy") == 0              # tie -> lowest slot id
    assert run("affinity") == 1               # tie -> shard hosting "b"


def test_affinity_admission_bounded_imbalance_falls_back():
    """A hosting shard past the imbalance bound is ineligible: affinity
    must fall back to the balanced rule rather than pile on."""
    q = RequestQueue()
    for t in ("a", "b", "c"):                 # all land in shard 0's pool?
        q.submit(t, np.zeros(2), arrival=0.0)
    sched = Scheduler(8, LengthBuckets(), data_shards=2,
                      admission=AffinityAdmission(max_imbalance=2))
    _fill(sched, q)                           # balanced: a->0, b->1, c->0
    assert sched.shard_occupancy() == [2, 1]
    q.submit("a", np.zeros(2), arrival=1.0)   # shard 0 hosts "a", occ 2 vs 1
    [(s1, _)] = _fill(sched, q, now=1.0)
    assert sched.shard_of(s1) == 0            # within bound: affinity wins
    assert sched.shard_occupancy() == [3, 1]
    q.submit("a", np.zeros(2), arrival=2.0)   # occ 3 - min 1 >= bound 2
    [(s2, _)] = _fill(sched, q, now=2.0)
    assert sched.shard_of(s2) == 1            # bound hit: balanced fallback
    assert sched.shard_occupancy() == [3, 2]


def test_affinity_base_requests_use_balanced_rule():
    q = RequestQueue()
    q.submit("a", np.zeros(2), arrival=0.0)
    sched = Scheduler(4, LengthBuckets(), data_shards=2,
                      admission="affinity")
    _fill(sched, q)                           # a -> shard 0
    q.submit(None, np.zeros(2), arrival=1.0)  # base request: no affinity
    [(slot, _)] = _fill(sched, q, now=1.0)
    assert sched.shard_of(slot) == 1          # least-occupied shard


def test_make_admission_resolution():
    assert isinstance(make_admission(None), BalancedAdmission)
    assert isinstance(make_admission("occupancy"), BalancedAdmission)
    aff = AffinityAdmission(max_imbalance=3)
    assert make_admission(aff) is aff
    with pytest.raises(ValueError):
        make_admission("round_robin")
    with pytest.raises(ValueError):
        AffinityAdmission(max_imbalance=0)


def test_scheduler_rejects_indivisible_shards():
    with pytest.raises(ValueError):
        Scheduler(5, LengthBuckets(), data_shards=2)
    with pytest.raises(ValueError):
        ContinuousEngine(get_smoke_config("llama3.2-1b"),
                         lm.init_params(get_smoke_config("llama3.2-1b"),
                                        jax.random.PRNGKey(0)),
                         n_slots=3, max_seq=16, data=2)


def test_tenant_segments_zero_active_and_single_tenant():
    """Edge cases with no direct coverage before: all slots parked on the
    zero-delta row (0 active tenants) and a single tenant filling every
    slot — one full-batch segment each, identity permutation."""
    seg = tenant_segments(np.zeros(4, np.int32))
    np.testing.assert_array_equal(seg.order, np.arange(4))
    np.testing.assert_array_equal(seg.inv_order, np.arange(4))
    np.testing.assert_array_equal(seg.seg_rows, [0, 0, 0, 0])
    np.testing.assert_array_equal(seg.seg_offsets, [0, 4, 4, 4, 4])

    seg = tenant_segments(np.full(4, 7, np.int32))
    np.testing.assert_array_equal(seg.order, np.arange(4))
    assert seg.seg_rows[0] == 7
    np.testing.assert_array_equal(seg.seg_offsets[:2], [0, 4])

    # sharded: each pool contributes its own (tenant-7) segment — one
    # per pool, pool-local [0, 2) ranges; the flattened envelope keeps
    # the global [B]/[B+1] static shape (padding interleaves per pool)
    sh = tenant_segments_sharded(np.full(4, 7, np.int32), 2)
    assert sh.seg_rows.shape == (2, 2) and sh.seg_offsets.shape == (2, 3)
    np.testing.assert_array_equal(sh.seg_rows[:, 0], [7, 7])
    np.testing.assert_array_equal(sh.seg_offsets,
                                  [[0, 2, 2], [0, 2, 2]])
    go, gi = (np.asarray(a) for a in sh.global_order())
    gsr, gso = (np.asarray(a) for a in sh.global_segments())
    assert gsr.shape == (4,) and gso.shape == (5,)
    # non-empty flattened segments: tenant 7 over [0,2) and [2,4)
    live = [(int(gsr[i]), int(gso[i]), int(gso[i + 1]))
            for i in range(4) if gso[i + 1] > gso[i]]
    assert live == [(7, 0, 2), (7, 2, 4)]
    np.testing.assert_array_equal(go, np.arange(4))
    np.testing.assert_array_equal(gi, np.arange(4))


def test_tenant_segments_sharded_never_crosses_pool():
    rows = np.asarray([3, 1, 3, 0, 2, 2, 1, 1], np.int32)
    sh = tenant_segments_sharded(rows, 2)
    order = np.asarray(sh.global_order()[0])
    # pool-local stable sort, no cross-pool movement
    np.testing.assert_array_equal(
        order[:4], np.argsort(rows[:4], kind="stable"))
    np.testing.assert_array_equal(
        order[4:], 4 + np.argsort(rows[4:], kind="stable"))
    sr, so = (np.asarray(a) for a in sh.global_segments())
    rec = np.zeros(8, np.int32)
    for i in range(8):
        rec[so[i]:so[i + 1]] = sr[i]
    np.testing.assert_array_equal(rec, rows[order])
    with pytest.raises(ValueError):      # not an assert: survives python -O
        tenant_segments_sharded(np.zeros(5, np.int32), 2)


# ---------------------------------------------------------------------------
# Property-based scheduler invariants (hypothesis; skipped when absent)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _shapes = st.tuples(st.integers(1, 3), st.sampled_from([1, 2, 4]))
    # both policies run the SAME property suite: capacity / EDF /
    # no-starvation are policy-independent (a policy picks *where*,
    # never *whether*), only the occupancy bound widens to the policy's
    # declared max_imbalance
    _policies = ["occupancy", "affinity"]

    @pytest.mark.parametrize("policy", _policies)
    @settings(max_examples=60, deadline=None)
    @given(
        shape=_shapes,
        rounds=st.lists(
            st.tuples(
                # (deadline, tenant id) of this round's arrivals
                # (None deadline = best-effort; tenant repeats make the
                # affinity path actually fire)
                st.lists(st.tuples(
                    st.one_of(st.none(),
                              st.floats(0, 10, allow_nan=False)),
                    st.integers(0, 3)),
                    max_size=6),
                # picks of active slots to finish before admitting
                st.lists(st.integers(0, 10 ** 6), max_size=6),
            ),
            min_size=1, max_size=8),
    )
    def test_prop_admission_capacity_starvation_balance(policy, shape,
                                                        rounds):
        """Random arrival/deadline/finish traces: admission never exceeds
        free slots, pops earliest-deadline-first, never leaves a ready
        request waiting while a slot is free, and every shard it touches
        ends within the policy's max_imbalance of the least-occupied
        shard (1 for balanced, the configured bound for affinity)."""
        shard_size, n_shards = shape
        sched = Scheduler(shard_size * n_shards, LengthBuckets(),
                          data_shards=n_shards, admission=policy)
        bound = sched.admission.max_imbalance
        q = RequestQueue()
        now = 0.0
        for deadlines, finishes in rounds:
            now += 1.0
            for pick in finishes:             # finished sequences release
                active = sched.active_slots()
                if not active:
                    break
                slot = active[pick % len(active)]
                sched.slots[slot].request.t_done = now
                sched.release(slot)
            for dl, tid in deadlines:
                q.submit(f"t{tid}", np.zeros(2), arrival=now,
                         deadline=None if dl is None else now + dl)
            free_before = len(sched.free_slots())
            ready_before = len(q.ready(now))
            admitted = sched.admit(q, now)
            assert len(admitted) == min(free_before, ready_before)
            # earliest-deadline-first pop order within the round
            keys = [(r.deadline if r.deadline is not None else float("inf"),
                     r.arrival, r.rid) for _, r in admitted]
            assert keys == sorted(keys)
            seen_slots = set()
            for slot, req in admitted:
                assert slot not in seen_slots          # no double placement
                seen_slots.add(slot)
                sched.place(slot, SlotState(request=req, next_token=0,
                                            pos=0, tenant_row=0))
            # no starvation: a free slot and a ready request never coexist
            assert not (sched.free_slots() and q.ready(now))
            occ = sched.shard_occupancy()
            for s in {sched.shard_of(slot) for slot, _ in admitted}:
                assert occ[s] <= min(occ) + bound

    @pytest.mark.parametrize("policy", _policies)
    @settings(max_examples=60, deadline=None)
    @given(shape=_shapes,
           batches=st.lists(st.lists(st.integers(0, 3), max_size=6),
                            min_size=1, max_size=6))
    def test_prop_admission_imbalance_bounded_under_arrivals(policy, shape,
                                                             batches):
        """Arrival-only traces (the regime admission fully controls):
        per-shard occupancy imbalance <= the policy's max_imbalance
        immediately after EVERY admission round (1 for balanced)."""
        shard_size, n_shards = shape
        sched = Scheduler(shard_size * n_shards, LengthBuckets(),
                          data_shards=n_shards, admission=policy)
        bound = sched.admission.max_imbalance
        q = RequestQueue()
        for rnd, tids in enumerate(batches):
            for tid in tids:
                q.submit(f"t{tid}", np.zeros(2), arrival=float(rnd))
            _fill(sched, q, now=float(rnd))
            occ = sched.shard_occupancy()
            assert max(occ) - min(occ) <= bound, occ

    @settings(max_examples=120, deadline=None)
    @given(rows=st.lists(st.integers(0, 5), min_size=1, max_size=12))
    def test_prop_tenant_segments_stable_sort_consistent(rows):
        """The segment layout is always stable-sort-consistent: order is
        numpy's stable argsort, inv_order inverts it, and the (padded)
        segments reconstruct exactly the sorted tenant rows."""
        rows = np.asarray(rows, np.int32)
        B = len(rows)
        seg = tenant_segments(rows)
        order = np.asarray(seg.order)
        np.testing.assert_array_equal(order, np.argsort(rows, kind="stable"))
        np.testing.assert_array_equal(
            order[np.asarray(seg.inv_order)], np.arange(B))
        so = np.asarray(seg.seg_offsets)
        sr = np.asarray(seg.seg_rows)
        assert so.shape == (B + 1,) and sr.shape == (B,)
        assert so[0] == 0 and so[-1] == B and (np.diff(so) >= 0).all()
        rec = np.zeros(B, np.int32)
        for i in range(B):
            rec[so[i]:so[i + 1]] = sr[i]
        np.testing.assert_array_equal(rec, rows[order])
        # non-empty segments carry strictly increasing (unique) tenants
        live = [int(sr[i]) for i in range(B) if so[i + 1] > so[i]]
        assert all(a < b for a, b in zip(live, live[1:]))

    @settings(max_examples=120, deadline=None)
    @given(shard_size=st.integers(1, 4), n_shards=st.integers(1, 4),
           data=st.data())
    def test_prop_tenant_segments_sharded_per_pool(shard_size, n_shards,
                                                   data):
        """The sharded layout is the per-pool stable sort: the permutation
        never crosses a pool boundary, every segment lies inside one
        pool, and the segments reconstruct the pool-sorted rows."""
        B = shard_size * n_shards
        rows = np.asarray(
            data.draw(st.lists(st.integers(0, 4), min_size=B, max_size=B)),
            np.int32)
        sh = tenant_segments_sharded(rows, n_shards)
        assert sh.order.shape == (n_shards, shard_size)
        assert sh.seg_offsets.shape == (n_shards, shard_size + 1)
        order, inv_order = (np.asarray(a) for a in sh.global_order())
        sr, so = (np.asarray(a) for a in sh.global_segments())
        np.testing.assert_array_equal(order[inv_order], np.arange(B))
        for s in range(n_shards):
            lo, hi = s * shard_size, (s + 1) * shard_size
            np.testing.assert_array_equal(
                order[lo:hi], lo + np.argsort(rows[lo:hi], kind="stable"))
        assert so[0] == 0 and so[-1] == B and (np.diff(so) >= 0).all()
        for i in range(B):                    # segments stay inside a pool
            if so[i + 1] > so[i]:
                assert so[i] // shard_size == (so[i + 1] - 1) // shard_size
        rec = np.zeros(B, np.int32)
        for i in range(B):
            rec[so[i]:so[i + 1]] = sr[i]
        np.testing.assert_array_equal(rec, rows[order])
        # single-pool special case degrades to the plain layout exactly
        if n_shards == 1:
            ref = tenant_segments(rows)
            np.testing.assert_array_equal(order, ref.order)
            np.testing.assert_array_equal(sr, ref.seg_rows)
            np.testing.assert_array_equal(so, ref.seg_offsets)


# ---------------------------------------------------------------------------
# Data-sharded engine: token identity, per-shard metrics, stale-KV hygiene
# ---------------------------------------------------------------------------
def test_data_sharded_engine_token_identical_to_data1(dense_setup):
    """data=2 (host-side shard pools; no mesh needed) must be
    token-identical to data=1 on the same trace, with balanced per-shard
    occupancy reported."""
    cfg, base, tenants = dense_setup

    def run(data):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=32, data=data,
                               clock=VirtualClock(tick=1e-3))
        for i, d in enumerate(tenants):
            eng.register_tenant(f"t{i}", d)
        rng = jax.random.PRNGKey(21)
        reqs = []
        for i, L in enumerate([5, 9, 7, 5, 12, 3, 9]):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
            tenant = f"t{i % 3}" if i % 4 else None
            reqs.append(eng.submit(tenant, prompt, max_new_tokens=5,
                                   arrival=0.002 * i))
        metrics = eng.run()
        return eng, reqs, metrics

    eng1, reqs1, _ = run(1)
    eng2, reqs2, m2 = run(2)
    for a, b in zip(reqs1, reqs2):
        np.testing.assert_array_equal(a.output(), b.output())
    # decode still compiles exactly once: data=2 shares the jit signature
    CompileGuard(eng2, budgets={"decode": 1}).check()

    # a post-warmup metrics reset must keep the shard bookkeeping
    # (regression: reset_metrics dropped data_shards)
    eng2.reset_metrics()
    assert eng2.metrics.data_shards == 2

    rep = m2.report()
    assert rep["data_shards"] == 2 and len(rep["shards"]) == 2
    assert sum(s["tokens"] for s in rep["shards"]) == rep["total_tokens"]
    for s in rep["shards"]:
        assert s["tokens"] > 0                 # both shards actually decoded
    # admission kept the pools balanced on this trace
    assert rep["shard_imbalance_max"] <= 1


def test_data_sharded_freed_slot_parks_row_and_never_leaks(dense_setup):
    """PR 3's parked-slot convention under shard pools: a finished slot's
    tenant row parks at 0 (so stale rows never inflate another shard's
    segment count) and its stale KV never reaches a later request's
    decode — a full drain/refill cycle stays reference-exact."""
    cfg, base, tenants = dense_setup
    eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=32, data=2,
                           clock=VirtualClock(tick=1e-3))
    ref = Engine(cfg, base, max_seq=32)
    for i, d in enumerate(tenants):
        eng.register_tenant(f"t{i}", d)
        ref.register_tenant(f"t{i}", d)

    rng = jax.random.PRNGKey(5)
    def trace(seed, n):
        out = []
        for i in range(n):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, seed + i), (5 + (i % 2) * 4,), 0,
                cfg.vocab))
            out.append((f"t{i % 3}", prompt))
        return out

    # wave 1 fills both pools and drains completely
    w1 = [eng.submit(t, p, max_new_tokens=4) for t, p in trace(100, 4)]
    eng.run()
    assert all(r.done for r in w1)
    assert (eng._row == 0).all()               # every freed slot parked
    assert eng.kv.n_free_shard(0) == eng.kv.n_free_shard(1) == 2

    # wave 2 reuses the same slots; stale wave-1 KV/rows must not leak in
    w2 = [eng.submit(t, p, max_new_tokens=4) for t, p in trace(200, 4)]
    eng.run()
    for (tenant, prompt), r in zip(trace(200, 4), w2):
        want = ref.generate(tenant, prompt[None], max_new_tokens=4)[0]
        np.testing.assert_array_equal(r.output(), want, err_msg=tenant)


# ---------------------------------------------------------------------------
# Pre-decoded delta residency (value cache) — unit + engine level
# ---------------------------------------------------------------------------
def _toy_stack(n_tenants, h_in=64, h_out=16, h_g=16, alpha=4.0, k=4):
    from repro.core import groupwise_dropout_pack
    from repro.core.apply import stack_tenant_deltas, zero_delta_like
    rng = jax.random.PRNGKey(0)
    trees = [{"w": groupwise_dropout_pack(
        jax.random.fold_in(rng, t),
        jax.random.normal(jax.random.fold_in(rng, 100 + t),
                          (h_in, h_out)) * 0.01,
        h_g=h_g, alpha=alpha, k_bits=k)} for t in range(n_tenants)]
    return stack_tenant_deltas([zero_delta_like(trees[0])] + trees)


def test_delta_residency_budget_capacity_and_values():
    from repro.core.pack import decode_values
    stacked = _toy_stack(3)
    row_bytes = 4 * int(np.prod(stacked["w"].idx.shape[1:]))
    r = DeltaResidency(stacked, 3 * row_bytes)
    assert r.enabled and r.capacity == 3 and r.row_bytes == row_bytes
    rm = r.ensure(np.asarray([0, 1, 2, 1]))
    assert rm is not None and rm.shape == (4,)        # 3 tenants + zero row
    assert r.misses == 2 and r.hits == 0
    # resident buffer rows are bit-identical to in-step decode
    want = np.asarray(decode_values(stacked["w"]))
    vals = np.asarray(r.values["w"])
    for row in (0, 1, 2):
        np.testing.assert_array_equal(vals[rm[row]], want[row])
    # warm step: all hits, no promotion work
    rm2 = r.ensure(np.asarray([1, 2]))
    assert r.misses == 2 and r.hits == 2
    np.testing.assert_array_equal(rm, rm2)


def test_delta_residency_lru_demotion_and_fallback():
    stacked = _toy_stack(3)
    row_bytes = 4 * int(np.prod(stacked["w"].idx.shape[1:]))
    r = DeltaResidency(stacked, 2 * row_bytes)        # zero row + ONE tenant
    assert r.capacity == 2
    assert r.ensure(np.asarray([0, 1])) is not None
    # over capacity: 2 unique tenants don't fit -> packed fallback
    assert r.ensure(np.asarray([1, 2])) is None
    assert r.fallback_steps == 1
    # LRU demotion: tenant 2 reuses tenant 1's residency row
    rm = r.ensure(np.asarray([0, 2]))
    assert rm is not None and 1 not in r._slot_of and rm[2] == 1
    stats = r.stats()
    assert stats["resident_rows"] == 2 \
        and stats["resident_bytes"] == 2 * row_bytes
    # recency: touching 2 again then demanding 3 must keep 2 resident
    r.ensure(np.asarray([2]))
    rm = r.ensure(np.asarray([3]))                    # evicts nothing in use
    assert rm is not None and 2 not in r._slot_of     # 2 was LRU after 3? no:
    # [2] refreshed 2's recency, then [3] needed a row -> evicted 2 (the
    # only evictable tenant). Re-promote 2 and check 3 gets evicted next.
    rm = r.ensure(np.asarray([2]))
    assert rm is not None and 3 not in r._slot_of


def test_delta_residency_disabled_below_two_rows():
    stacked = _toy_stack(2)
    row_bytes = 4 * int(np.prod(stacked["w"].idx.shape[1:]))
    r = DeltaResidency(stacked, row_bytes)            # one row: useless
    assert not r.enabled and r.ensure(np.asarray([0, 1])) is None


def test_affinity_residency_engine_token_identical(dense_setup):
    """The acceptance contract: affinity admission + pre-decoded
    residency (data=1 and data=2) serve the exact tokens of the default
    path, while actually using the value path (hit rate > 0) and
    reporting per-shard unique-tenant counts."""
    cfg, base, tenants = dense_setup

    def run(**kw):
        eng = ContinuousEngine(cfg, base, n_slots=4, max_seq=32,
                               clock=VirtualClock(tick=1e-3), **kw)
        for i, d in enumerate(tenants):
            eng.register_tenant(f"t{i}", d)
        rng = jax.random.PRNGKey(21)
        reqs = []
        for i, L in enumerate([5, 9, 7, 5, 12, 3, 9]):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(rng, i), (L,), 0, cfg.vocab))
            tenant = f"t{i % 3}" if i % 4 else None
            reqs.append(eng.submit(tenant, prompt, max_new_tokens=5,
                                   arrival=0.002 * i))
        metrics = eng.run()
        return eng, reqs, metrics

    _, ref, _ = run()
    e1, r1, m1 = run(admission="affinity",
                     residency_budget_bytes=64 << 20)
    e2, r2, m2 = run(admission="affinity", residency_budget_bytes=64 << 20,
                     data=2)
    for a, b in zip(ref, r1):
        np.testing.assert_array_equal(a.output(), b.output())
    for a, b in zip(ref, r2):
        np.testing.assert_array_equal(a.output(), b.output())

    rep = m1.report()
    assert rep["residency"]["value_steps"] > 0
    assert rep["residency"]["hit_rate"] is not None \
        and rep["residency"]["hit_rate"] > 0
    assert rep["residency"]["fallback_steps"] == 0    # budget fits everyone
    assert rep["unique_tenants_mean"] > 0
    # values + packed are two pytree structures at most: the decode jit
    # stays bounded even when residency toggles per step
    CompileGuard(e1, budgets={"decode": 2}).check()
    rep2 = m2.report()
    assert len(rep2["unique_tenants_per_shard_mean"]) == 2
    for s in rep2["shards"]:
        assert s["unique_tenants_mean"] is not None


def test_residency_tight_budget_falls_back_packed(dense_setup):
    """A budget too small for the mixed batch must serve packed steps
    (still bit-exact vs the default path) and count them."""
    cfg, base, tenants = dense_setup

    def run(budget=None):
        eng = ContinuousEngine(cfg, base, n_slots=3, max_seq=32,
                               clock=VirtualClock(tick=1e-3),
                               residency_budget_bytes=budget)
        for i, d in enumerate(tenants):
            eng.register_tenant(f"t{i}", d)
        reqs = [eng.submit(f"t{i % 3}", np.arange(4 + i) % cfg.vocab,
                           max_new_tokens=4) for i in range(5)]
        m = eng.run()
        return eng, reqs, m

    _, ref, _ = run()
    # budget = exactly 2 rows: zero row + one tenant; 3-tenant batches
    # must fall back
    eng, _, _ = run(budget=1)                  # < 2 rows -> tier disabled
    assert eng.residency is not None and not eng.residency.enabled
    row_bytes = eng.residency.row_bytes
    eng2, r2, m2 = run(budget=2 * row_bytes)
    for a, b in zip(ref, r2):
        np.testing.assert_array_equal(a.output(), b.output())
    rep = m2.report()
    assert rep["residency"]["packed_steps"] > 0


def test_slot_kv_cache_shard_accounting(dense_setup):
    """Host-side shard bookkeeping of the KV free list mirrors the
    scheduler's contiguous pools (device-layout round-trips live in
    test_mesh_sharding.py)."""
    cfg, _, _ = dense_setup
    kv = SlotKVCache(cfg, 4, 16, data_shards=2)
    assert kv.shard_of(0) == kv.shard_of(1) == 0
    assert kv.shard_of(2) == kv.shard_of(3) == 1
    assert kv.shard_occupancy() == [0.0, 0.0]
    kv.claim(2)
    kv.claim(0)
    assert kv.n_free_shard(0) == 1 and kv.n_free_shard(1) == 1
    assert kv.shard_occupancy() == [0.5, 0.5]
    kv.release(2)
    assert kv.n_free_shard(1) == 2
    with pytest.raises(ValueError, match="double-freed"):
        kv.release(2)                          # double free still refused
    with pytest.raises(ValueError):
        SlotKVCache(cfg, 5, 16, data_shards=2)
