"""Quickstart: compress a fine-tuned model's delta with DeltaDQ in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm

# 1. a base model and a "fine-tuned" variant (here: perturbed weights)
cfg = get_smoke_config("wizard-llama2-7b")
base = lm.init_params(cfg, jax.random.PRNGKey(0))
ft = jax.tree.map(
    lambda p: p + 0.01 * jax.random.normal(jax.random.PRNGKey(1), p.shape,
                                           jnp.float32).astype(p.dtype)
    if p.ndim >= 2 else p, base)

# 2. DeltaDQ: group-wise dropout (alpha=8) + separate quantization
#    (k=4 codes stored as m=8 one-bit parts) => 128x compression
spec = DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=32)
deltas, report = compress(base, ft, spec)
print(report.summary())

# 3. serve with the paper's separate computation: y = x W_b + x dW.
#    The identity to check here: serving (base + packed delta) equals
#    serving the merged weights — the deployment never materializes them.
from repro.core import decompress

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)}
logits_sep = lm.forward(cfg, base, batch, deltas=deltas)        # separate comp
logits_merged = lm.forward(cfg, decompress(base, deltas), batch)  # merged weights

err = float(jnp.max(jnp.abs(logits_sep - logits_merged)))
print(f"separate computation == merged weights: max |logit diff| = {err:.2e}")
print("NOTE: accuracy retention needs a *real* SFT delta (random perturbations")
print("have no structure to exploit) — run examples/train_sft_delta.py for the")
print("full pretrain -> SFT -> 128x compress -> serve -> accuracy pipeline.")
