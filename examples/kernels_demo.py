"""Pallas kernel demo: the TPU scatter-to-dense + MXU delta matmul.

Shows the three kernels against their oracles (interpret mode on CPU;
compiled on a real TPU) and the HBM-bytes arithmetic that makes the
compressed layout a win for memory-bound decode.

    PYTHONPATH=src python examples/kernels_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import groupwise_dropout_pack
from repro.kernels import ops, ref
from repro.roofline.analysis import HBM_BW

T, H_IN, H_OUT, H_G, ALPHA, K = 128, 2048, 512, 128, 8, 4

rng = jax.random.PRNGKey(0)
delta = jax.random.normal(rng, (H_IN, H_OUT)) * 0.01
packed = groupwise_dropout_pack(rng, delta, h_g=H_G, alpha=ALPHA, k_bits=K, m=8)
x = jax.random.normal(jax.random.fold_in(rng, 1), (T, H_IN))
w = jax.random.normal(jax.random.fold_in(rng, 2), (H_IN, H_OUT)) * 0.05

for name, got, want in [
    ("delta_spmm", ops.delta_spmm(x, packed, interpret=True), ref.delta_spmm_ref(x, packed)),
    ("fused_base_delta", ops.fused_base_delta(x, w, packed, interpret=True),
     ref.fused_base_delta_ref(x, w, packed)),
    ("dequant", ops.dequant(packed, interpret=True), ref.dequant_tile_ref(packed)),
]:
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"{name:18s} max|err| vs oracle = {err:.2e}")

dense_bytes = H_IN * H_OUT * 2                       # bf16 delta
packed_bytes = packed.idx.size + packed.codes.size   # uint8 arrays
print(f"\nHBM bytes per layer: dense delta {dense_bytes / 1e3:.0f}KB -> "
      f"packed {packed_bytes / 1e3:.0f}KB ({dense_bytes / packed_bytes:.1f}x less wire traffic)")
print(f"at v5e HBM bw ({HBM_BW / 1e9:.0f}GB/s) that is "
      f"{dense_bytes / HBM_BW * 1e6:.1f}us -> {packed_bytes / HBM_BW * 1e6:.2f}us per layer per step")
print("the dense tile is reconstructed inside VMEM and fed to the MXU — it never touches HBM")
