"""End-to-end driver: pretrain -> SFT -> DeltaDQ -> serve -> evaluate.

The paper's whole lifecycle on one machine:
 1. pretrain a base LM (noise mixture + task format),
 2. fine-tune it on the Sort task (the "WizardMath" stand-in),
 3. compress the delta at several ratios incl. the paper's 128x flagship,
 4. serve base + tenants through the multi-tenant engine,
 5. report exact-match task accuracy per tenant and the memory ledger.

    PYTHONPATH=src python examples/train_sft_delta.py            # ~5 min CPU
    PYTHONPATH=src python examples/train_sft_delta.py --preset 100m --steps 300
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ArchConfig
from repro.core import DeltaDQSpec, compress
from repro.data import FormatOnlyTask, PretrainMixture, SortTask
from repro.models import lm
from repro.optim import adamw, schedule
from repro.optim.adamw import AdamWConfig
from repro.serve import Engine
from repro.train import make_train_step
from repro.utils import tree_params

PRESETS = {
    "3m": ArchConfig(name="sft-3m", family="dense", n_layers=4, d_model=128,
                     n_heads=4, n_kv=2, head_dim=32, d_ff=256, vocab=64,
                     tie_embeddings=True),
    "25m": ArchConfig(name="sft-25m", family="dense", n_layers=8, d_model=384,
                      n_heads=8, n_kv=4, head_dim=48, d_ff=1024, vocab=512,
                      tie_embeddings=True),
    "100m": ArchConfig(name="sft-100m", family="dense", n_layers=12, d_model=768,
                       n_heads=12, n_kv=4, head_dim=64, d_ff=2048, vocab=4096,
                       tie_embeddings=True),
}


def train(cfg, params, data, steps, lr, label):
    opt = adamw.init(params)
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0,
                          schedule=schedule.cosine_with_warmup(steps // 10 + 1, steps))
    step = jax.jit(make_train_step(cfg, opt_cfg))
    t0 = time.time()
    m = {}
    for i in range(steps):
        params, opt, m = step(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
        if i % max(steps // 5, 1) == 0:
            print(f"  [{label}] step {i:4d} loss {float(m['loss']):.4f}")
    print(f"  [{label}] done in {time.time() - t0:.0f}s, final loss {float(m['loss']):.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="3m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    n_digits, seq = 6, 32
    steps = args.steps or {"3m": 250, "25m": 300, "100m": 300}[args.preset]
    print(f"arch={cfg.name}: {tree_params(lm.param_specs(cfg)) / 1e6:.1f}M params")

    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    print("1) pretraining base ...")
    base = train(cfg, base, PretrainMixture(cfg.vocab, seq, 32), steps // 3, 5e-3, "pretrain")
    base = train(cfg, base, FormatOnlyTask(cfg.vocab, seq, 32, n_digits=n_digits),
                 steps, 3e-3, "format")
    task = SortTask(cfg.vocab, seq, 32, n_digits=n_digits, seed=1)
    print("2) supervised fine-tuning ...")
    ft = train(cfg, base, task, steps, 1e-3, "sft")

    print("3) DeltaDQ compression ...")
    eng = Engine(cfg, base, max_seq=seq + n_digits + 2)
    tenants = {
        "16x": DeltaDQSpec(alpha=8.0, k_bits=8, m=1, h_g=16),
        "64x": DeltaDQSpec(alpha=8.0, k_bits=4, m=4, h_g=16),
        "128x": DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16),
    }
    for name, spec in tenants.items():
        deltas, report = compress(base, ft, spec)
        eng.register_tenant(name, deltas, report)
        print("  ", report.summary())

    print("4) serving + evaluation ...")

    def acc(tenant, engine=eng):
        c = t = 0
        for s in range(3):
            prompts, targets = task.prompts_at(9000 + s)
            gen = engine.generate(tenant, prompts, max_new_tokens=n_digits)
            c += (gen[:, :n_digits] == targets).sum()
            t += targets.size
        return c / t

    eng_ft = Engine(cfg, ft, max_seq=seq + n_digits + 2)
    print(f"  fine-tuned (uncompressed): {acc(None, eng_ft):.3f}")
    print(f"  raw base                 : {acc(None):.3f}")
    for name in tenants:
        print(f"  tenant {name:5s}            : {acc(name):.3f}")

    rep = eng.memory_report()
    print(f"5) memory: base={rep['base_bytes'] / 1e6:.1f}MB, "
          f"{rep['n_tenants']} tenants={rep['delta_bytes_total'] / 1e6:.2f}MB total "
          f"(vs {rep['n_tenants']} full copies "
          f"{rep['base_bytes'] * rep['n_tenants'] / 1e6:.1f}MB)")


if __name__ == "__main__":
    main()
