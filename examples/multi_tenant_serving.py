"""Multi-tenant serving demo: one base, many fine-tunes, mixed request batch.

Simulates the paper's deployment (Fig. 2): N tenants fine-tuned for
different "skills" register 128x-compressed deltas with one engine; a mixed
request stream is served with per-tenant grouping (separate computation).

    PYTHONPATH=src python examples/multi_tenant_serving.py --tenants 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import Engine
from repro.utils import tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    eng = Engine(cfg, base, max_seq=48)

    print(f"registering {args.tenants} tenants at 128x delta compression ...")
    spec = DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16)
    for t in range(args.tenants):
        ft = jax.tree.map(
            lambda p, t=t: p + 0.02 * jax.random.normal(
                jax.random.fold_in(rng, 100 + t), p.shape, jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        deltas, report = compress(base, ft, spec)
        eng.register_tenant(f"tenant{t}", deltas, report)
        print(f"  tenant{t}: {report.summary()}")

    # mixed request stream
    reqs = []
    for i in range(args.requests):
        tenant = f"tenant{i % args.tenants}"
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(rng, i), (8,), 0, cfg.vocab))
        reqs.append((tenant, prompt))

    t0 = time.time()
    outs = eng.serve_batch(reqs, max_new_tokens=8)
    dt = time.time() - t0
    print(f"served {len(reqs)} requests across {args.tenants} tenants "
          f"in {dt:.1f}s (CPU, incl. jit)")

    # different tenants produce different generations for the same prompt
    same_prompt = reqs[0][1]
    gens = {t: eng.generate(f"tenant{t}", same_prompt[None], max_new_tokens=8)[0]
            for t in range(min(args.tenants, 3))}
    uniq = {tuple(g.tolist()) for g in gens.values()}
    print(f"distinct generations for one prompt across tenants: {len(uniq)}/{len(gens)}")

    rep = eng.memory_report()
    n = rep["n_tenants"]
    print(f"memory ledger: base {rep['base_bytes'] / 1e6:.1f}MB + "
          f"{n} deltas {rep['delta_bytes_total'] / 1e6:.2f}MB  "
          f"vs naive {n + 1} full models "
          f"{rep['base_bytes'] * (n + 1) / 1e6:.1f}MB  "
          f"=> {(rep['base_bytes'] * (n + 1)) / (rep['base_bytes'] + rep['delta_bytes_total']):.1f}x saving")


if __name__ == "__main__":
    main()
