"""Multi-tenant serving demo: one base, many fine-tunes, mixed live stream.

Simulates the paper's deployment (Fig. 2): N tenants fine-tuned for
different "skills" register 128x-compressed deltas with one
continuous-batching engine; a staggered mixed request stream is served
with slot-level scheduling — one decode step advances sequences belonging
to *different* tenants, each corrected by its own packed delta.

    PYTHONPATH=src python examples/multi_tenant_serving.py --tenants 4
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec
from repro.launch.serve import synth_tenants
from repro.models import lm
from repro.serve import ContinuousEngine, Engine
from repro.utils import tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    eng = ContinuousEngine(cfg, base, n_slots=args.slots, max_seq=48)

    print(f"registering {args.tenants} tenants at 128x delta compression ...")
    spec = DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16)
    for name, deltas, report in synth_tenants(cfg, base, args.tenants, spec, rng):
        eng.register_tenant(name, deltas, report)
        print(f"  {name}: {report.summary()}")

    # staggered mixed request stream with token streaming on request 0
    def stream(req, tok, done):
        print(f"  [stream r{req.rid}] token {tok}{' <done>' if done else ''}")

    reqs = []
    for i in range(args.requests):
        tenant = f"tenant{i % args.tenants}"
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, i), (8,), 0, cfg.vocab))
        reqs.append(eng.submit(tenant, prompt, max_new_tokens=8,
                               arrival=0.01 * i,
                               on_token=stream if i == 0 else None))

    metrics = eng.run()
    rep = metrics.report()
    print(f"served {len(reqs)} requests across {args.tenants} tenants in "
          f"{rep['wall_time_s']:.1f}s (CPU, incl. jit): "
          f"{rep['tokens_per_sec']:.0f} tok/s, "
          f"occupancy {rep['batch_occupancy']:.2f}, "
          f"{rep['decode_steps']} decode steps for {rep['prefills']} prefills")
    for name, t in rep["tenants"].items():
        print(f"  {name}: {t['requests']} reqs, ttft p50 "
              f"{1e3 * t['ttft_p50']:.0f}ms, latency p95 "
              f"{1e3 * t['latency_p95']:.0f}ms")

    # different tenants produce different generations for the same prompt
    ref = Engine(cfg, base, max_seq=48)
    ref.store = eng.store
    same_prompt = reqs[0].prompt
    gens = {t: ref.generate(f"tenant{t}", same_prompt[None], max_new_tokens=8)[0]
            for t in range(min(args.tenants, 3))}
    uniq = {tuple(g.tolist()) for g in gens.values()}
    print(f"distinct generations for one prompt across tenants: {len(uniq)}/{len(gens)}")

    base_bytes = tree_bytes(base)
    delta_bytes = eng.store.total_bytes()
    n = args.tenants
    print(f"memory ledger: base {base_bytes / 1e6:.1f}MB + "
          f"{n} deltas {delta_bytes / 1e6:.2f}MB  "
          f"vs naive {n} full models {base_bytes * n / 1e6:.1f}MB  "
          f"=> {(base_bytes * n) / (base_bytes + delta_bytes):.1f}x saving")


if __name__ == "__main__":
    main()
