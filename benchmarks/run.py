"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus each module's own
detail rows prefixed by their table).
    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
import argparse
import sys
import traceback

MODULES = [
    "fig4_balanced",
    "table1_basic",
    "table23_ultra",
    "table4_groupsearch",
    "fig5_groupsize",
    "memory_fig7",
    "serve_bench",
    "roofline_report",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n===== {name} =====", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == '__main__':
    main()
