"""Serving micro-benchmarks (CPU wall-clock; TPU numbers come from the
dry-run roofline, not from this container).

Measures: decode step latency base vs base+delta (separate computation
overhead), multi-tenant memory footprint vs N full fine-tuned models.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_models
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import Engine
from repro.utils import tree_bytes


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main():
    cfg, base, ft = get_models()
    deltas, report = compress(base, ft, DeltaDQSpec(alpha=8, k_bits=4, m=8, h_g=64))
    print("#", report.summary())

    B, S = 8, 32
    cache = lm.init_cache(cfg, B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    dec_base = jax.jit(lambda c, t: lm.decode_step(cfg, base, c, t, jnp.int32(4)))
    dec_delta = jax.jit(lambda c, t: lm.decode_step(cfg, base, c, t, jnp.int32(4), deltas=deltas))

    us_base = _time(dec_base, cache, tok)
    us_delta = _time(dec_delta, cache, tok)
    print(f"decode_base_us,{us_base:.1f}")
    print(f"decode_with_delta_us,{us_delta:.1f}")

    base_bytes = tree_bytes(base)
    delta_bytes = report.packed_total_bits / 8
    n_tenants = 16
    full_bytes = base_bytes * (1 + n_tenants)
    ours_bytes = base_bytes + delta_bytes * n_tenants
    print(f"memory_16_tenants: full={full_bytes / 1e6:.1f}MB "
          f"deltadq={ours_bytes / 1e6:.1f}MB saving={full_bytes / ours_bytes:.1f}x")

    csv_row("serve_bench", us_delta,
            f"delta_overhead={us_delta / us_base:.2f}x;mem_saving_16t={full_bytes / ours_bytes:.1f}x")


if __name__ == "__main__":
    main()
