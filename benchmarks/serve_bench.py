"""Serving benchmarks (CPU wall-clock; TPU numbers come from the dry-run
roofline, not from this container).

Measures, on the smoke config:

* decode step latency, base vs base+delta (separate-computation overhead),
* continuous-batching throughput / TTFT / occupancy for 1, 4 and 16
  tenants under a staggered mixed request stream,
* with ``--devices N``: the tensor-parallel row (``continuous_sharded``)
  and the data-parallel row (``continuous_data2``: a (2, N/2) mesh with
  slot rows in two occupancy-balanced shard pools, which also reports
  per-shard occupancy/throughput/imbalance and gates that every shard
  pool actually decoded tokens),
* multi-tenant memory footprint vs N full fine-tuned models,

and writes ``BENCH_serve.json`` at the repo root so later PRs have a perf
trajectory to beat.

CI regression gate::

    python -m benchmarks.serve_bench --quick --out BENCH_serve.fresh.json \
        --check BENCH_serve.json --tolerance 2.0

``--check`` compares the fresh run against a committed baseline with a
generous tolerance (CI runners are noisy; 2x catches real regressions,
not scheduler jitter) and exits non-zero on regression. ``--quick``
skips the slow 16-tenant run but keeps each remaining row's workload
identical to the baseline's, so throughput stays comparable.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_models
from repro.analysis import CompileGuard
from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.launch.serve import synth_tenants
from repro.models import lm
from repro.serve import ContinuousEngine
from repro.utils import tree_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE_SPEC = DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16)   # 128x class


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def decode_overhead():
    """Static decode-step microbenchmark on the trained bench models."""
    cfg, base, ft = get_models()
    deltas, report = compress(base, ft, DeltaDQSpec(alpha=8, k_bits=4, m=8, h_g=64))
    print("#", report.summary())

    B, S = 8, 32
    cache = lm.init_cache(cfg, B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    dec_base = jax.jit(lambda c, t: lm.decode_step(cfg, base, c, t, jnp.int32(4)))
    dec_delta = jax.jit(lambda c, t: lm.decode_step(cfg, base, c, t, jnp.int32(4), deltas=deltas))

    us_base = _time(dec_base, cache, tok)
    us_delta = _time(dec_delta, cache, tok)
    print(f"decode_base_us,{us_base:.1f}")
    print(f"decode_with_delta_us,{us_delta:.1f}")
    return {"decode_base_us": us_base, "decode_with_delta_us": us_delta,
            "delta_overhead_x": us_delta / us_base}


def continuous_bench(n_tenants: int, n_requests: int = 16, max_new: int = 8,
                     n_slots: int = 4, arrival_gap: float = 0.02,
                     devices: int = 1, data: int = 1,
                     admission: str = "occupancy",
                     residency_mb: float = 0.0) -> dict:
    """Mixed staggered stream through the continuous engine (smoke config).

    ``devices > 1`` serves the same stream on a ``(data, devices/data)``
    mesh (tensor-parallel base, output-sharded packed deltas; with
    ``data > 1`` the slot rows additionally shard over ``data`` in
    contiguous pools) — on CPU the devices are faked via
    ``--xla_force_host_platform_device_count``, which is how the CI
    multi-device bench rows run. ``data > 1`` with ``devices == 1``
    runs host-side shard pools (admission-policy semantics without
    device sharding). ``admission`` picks the shard placement policy;
    ``residency_mb > 0`` enables the pre-decoded delta value cache.
    """
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    mesh = None
    if devices > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(devices, data=data)
    from repro.serve import residency_bytes_from_mb
    eng = ContinuousEngine(cfg, base, n_slots=n_slots, max_seq=64, mesh=mesh,
                           data=data, admission=admission,
                           residency_budget_bytes=residency_bytes_from_mb(
                               residency_mb))
    for name, deltas, _ in synth_tenants(cfg, base, n_tenants, SERVE_SPEC, rng):
        eng.register_tenant(name, deltas)

    # warm every jit shape (both buckets + decode) so the measurement is
    # steady-state serving, not compilation
    warm = [eng.submit("tenant0", np.zeros(L, np.int32), max_new_tokens=2)
            for L in (4, 12)]
    eng.run()
    assert all(w.done for w in warm)
    eng.reset_metrics()             # drop warmup counters, keep compiled fns

    reqs = []
    for i in range(n_requests):
        L = 4 + (i % 3) * 4
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
        reqs.append(eng.submit(f"tenant{i % n_tenants}", prompt,
                               max_new_tokens=max_new,
                               arrival=i * arrival_gap))
    metrics = eng.run()
    assert all(r.done for r in reqs)
    rep = metrics.report()
    out = {
        "n_tenants": n_tenants,
        "n_requests": n_requests,
        "n_slots": n_slots,
        "devices": devices,
        "data": data,
        "admission": admission,
        "residency_mb": residency_mb,
        "residency": rep["residency"],
        "unique_tenants_per_shard_mean": rep["unique_tenants_per_shard_mean"],
        "shards": rep["shards"],
        "shard_imbalance_max": rep["shard_imbalance_max"],
        "arrival_gap_s": arrival_gap,
        "tokens_per_sec": rep["tokens_per_sec"],
        "ttft_p50_ms": 1e3 * rep["ttft_p50"] if rep["ttft_p50"] is not None else None,
        "batch_occupancy": rep["batch_occupancy"],
        "prefill_shapes": sorted(eng.prefill_shapes),
        # which codec(s) the decode path actually dispatched (from the
        # per-jit-signature attribution notes) — one entry per codec seen
        "decode_codecs": sorted({n["codec"]
                                 for notes in eng._path_notes.values()
                                 for n in notes if "codec" in n}),
        "delta_bytes_per_tenant": eng.store.total_bytes() / n_tenants,
        "base_bytes": tree_bytes(base),
        "tenants": rep["tenants"],     # per-tenant throughput/TTFT/latency
    }
    print(f"serve_{n_tenants}t: {out['tokens_per_sec']:.0f} tok/s, "
          f"ttft p50 {out['ttft_p50_ms']:.1f}ms, "
          f"occupancy {out['batch_occupancy']:.2f}")
    return out


def tracing_overhead(n_tenants: int = 4, n_requests: int = 16,
                     max_new: int = 8, n_slots: int = 4,
                     arrival_gap: float = 0.02, trials: int = 2) -> dict:
    """Throughput cost of full tracing: a traced and an untraced twin of
    the 4-tenant continuous row, interleaved trials, best-of per mode.

    Interleaving means machine noise (frequency scaling, co-tenant
    load) hits both modes; best-of-trials strips the slow-outlier tail.
    The gate is ``tracing_overhead_x <= 1.05`` — the observability
    subsystem's <3% contract with headroom for CI wall-clock jitter.
    """
    from repro.serve.trace import Tracer

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, n_tenants, SERVE_SPEC, rng)

    def build(traced: bool) -> ContinuousEngine:
        eng = ContinuousEngine(cfg, base, n_slots=n_slots, max_seq=64,
                               trace=Tracer() if traced else None)
        for name, deltas, _ in tenants:
            eng.register_tenant(name, deltas)
        warm = [eng.submit("tenant0", np.zeros(L, np.int32),
                           max_new_tokens=2) for L in (4, 12)]
        eng.run()
        assert all(w.done for w in warm)
        return eng

    engines = {"untraced": build(False), "traced": build(True)}
    best = {k: 0.0 for k in engines}
    for _ in range(trials):
        for mode, eng in engines.items():
            eng.reset_metrics()
            reqs = []
            for i in range(n_requests):
                L = 4 + (i % 3) * 4
                prompt = np.asarray(jax.random.randint(
                    jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
                reqs.append(eng.submit(f"tenant{i % n_tenants}", prompt,
                                       max_new_tokens=max_new,
                                       arrival=i * arrival_gap))
            rep = eng.run().report()
            assert all(r.done for r in reqs)
            best[mode] = max(best[mode], rep["tokens_per_sec"] or 0.0)
    ratio = best["untraced"] / best["traced"] if best["traced"] else None
    out = {"n_tenants": n_tenants, "n_requests": n_requests,
           "trials": trials,
           "untraced_tokens_per_sec": best["untraced"],
           "traced_tokens_per_sec": best["traced"],
           "tracing_overhead_x": ratio}
    print(f"tracing_overhead: untraced {best['untraced']:.0f} tok/s, "
          f"traced {best['traced']:.0f} tok/s -> "
          f"{ratio:.3f}x" if ratio is not None else
          "tracing_overhead: traced run produced no throughput")
    return out


def affinity_unique_check(n_tenants: int = 16, n_requests: int = 32,
                          n_slots: int = 8, data: int = 2) -> dict:
    """Deterministic replay: per-shard unique-tenant load, occupancy vs
    affinity admission, on the SAME 16-tenant skewed trace.

    Runs on a VirtualClock with host-side shard pools, so placement —
    and therefore the per-step per-shard unique-tenant counts — is a
    pure function of the trace: this is a hard gate, not a wall-clock
    measurement. The trace is zipf-ish (a few hot tenants dominate,
    like real multi-tenant traffic) so tenant repeats overlap in
    flight, which is the regime affinity exists for.
    """
    from repro.serve import VirtualClock

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, n_tenants, SERVE_SPEC, rng)
    rs = np.random.RandomState(7)
    trace = []
    for i in range(n_requests):
        # 60% of traffic from 4 hot tenants, the rest uniform
        t = rs.randint(4) if rs.rand() < 0.6 else rs.randint(n_tenants)
        L = 4 + (i % 3) * 4
        prompt = rs.randint(0, cfg.vocab, size=L).astype(np.int32)
        trace.append((f"tenant{t}", prompt, 0.004 * i))

    def run(admission: str) -> float:
        eng = ContinuousEngine(cfg, base, n_slots=n_slots, max_seq=64,
                               data=data, admission=admission,
                               clock=VirtualClock(tick=1e-3))
        for name, deltas, _ in tenants:
            eng.register_tenant(name, deltas)
        reqs = [eng.submit(t, p, max_new_tokens=6, arrival=a)
                for t, p, a in trace]
        metrics = eng.run()
        assert all(r.done for r in reqs)
        per_shard = metrics.report()["unique_tenants_per_shard_mean"]
        return float(np.mean(per_shard))

    occ, aff = run("occupancy"), run("affinity")
    out = {"n_tenants": n_tenants, "n_requests": n_requests,
           "n_slots": n_slots, "data": data,
           "unique_per_shard_occupancy": occ,
           "unique_per_shard_affinity": aff,
           "affinity_strictly_lower": aff < occ}
    print(f"affinity_unique_check: occupancy {occ:.3f} vs affinity "
          f"{aff:.3f} unique tenants/shard/step "
          f"({'OK' if aff < occ else 'NOT LOWER'})")
    return out


def continuous_zipf(n_tenants: int = 8, n_requests: int = 48,
                    n_slots: int = 4, max_new: int = 8,
                    arrival_gap: float = 0.004, devices: int = 1,
                    data: int = 1, chunk_size: int = 16) -> dict:
    """Sustained zipf-arrival load: chunked vs unchunked prefill twins.

    The TTFT-cliff workload: arrivals outnumber slots many times over
    at a gap far below per-request service time, so the queue stays
    deep for the whole run and every wasted dispatch (a batch-1
    whole-prompt prefill advances zero decode tokens) compounds into
    queue wait. Tenant picks are zipf-ish (hot-tenant skew like real
    multi-tenant traffic); prompt lengths span the whole bucket ladder
    (8..max_seq), because that is where the cliff lives: the
    whole-prompt engine compiles one prefill program per length bucket,
    and warmup covers only ONE typical bucket — as in production, where
    the shape ladder is too wide to pre-warm — so the first request to
    hit each remaining bucket stalls the entire engine behind a mid-run
    compile while the queue is deep. The chunked engine serves every
    length through its two fixed shapes (combined decode+chunk, masked
    decode), so after the same one-bucket warmup it never compiles
    again. Both twins serve the SAME trace with the SAME warmup; the
    chunked engine must deliver strictly better ``ttft_p95`` at
    equal-or-better throughput (the --check gate).
    """
    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    tenants = synth_tenants(cfg, base, n_tenants, SERVE_SPEC, rng)
    mesh = None
    if devices > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(devices, data=data)

    rs = np.random.RandomState(11)
    trace = []
    # one length per bucket rung (buckets 8/16/32/64 at max_seq=64),
    # cycled so every rung recurs throughout the run
    lengths = (6, 12, 20, 28, 40, 48)
    for i in range(n_requests):
        t = rs.randint(4) if rs.rand() < 0.6 else rs.randint(n_tenants)
        L = lengths[i % len(lengths)]
        prompt = rs.randint(0, cfg.vocab, size=L).astype(np.int32)
        trace.append((f"tenant{t}", prompt, i * arrival_gap))

    def run(chunked: bool) -> dict:
        eng = ContinuousEngine(
            cfg, base, n_slots=n_slots, max_seq=64, mesh=mesh, data=data,
            chunked_prefill=chunked, chunk_size=chunk_size)
        for name, deltas, _ in tenants:
            eng.register_tenant(name, deltas)
        # warm ONE typical bucket (both twins, identically) — the rest
        # of the shape ladder is deliberately left cold; mid-run bucket
        # compiles ARE the cliff this row measures
        warm = eng.submit("tenant0", np.zeros(12, np.int32),
                          max_new_tokens=2)
        eng.run()
        assert warm.done
        eng.reset_metrics()
        reqs = [eng.submit(t, p, max_new_tokens=max_new, arrival=a)
                for t, p, a in trace]
        rep = eng.run().report()
        assert all(r.done for r in reqs)
        return {
            "tokens_per_sec": rep["tokens_per_sec"],
            "ttft_p50_ms": 1e3 * rep["ttft_p50"],
            "ttft_p95_ms": 1e3 * rep["ttft_p95"],
            "itl_p50_ms": None if rep["itl_p50"] is None
            else 1e3 * rep["itl_p50"],
            "itl_p95_ms": None if rep["itl_p95"] is None
            else 1e3 * rep["itl_p95"],
            "batch_occupancy": rep["batch_occupancy"],
            "decode_steps": rep["decode_steps"],
        }

    unchunked = run(False)
    chunked = run(True)
    tps_ratio = chunked["tokens_per_sec"] / unchunked["tokens_per_sec"]
    out = {
        "n_tenants": n_tenants, "n_requests": n_requests,
        "n_slots": n_slots, "devices": devices, "data": data,
        "chunk_size": chunk_size, "arrival_gap_s": arrival_gap,
        "unchunked": unchunked, "chunked": chunked,
        "tps_chunked_vs_unchunked_x": tps_ratio,
        # the gate: strictly better tail TTFT at equal-or-better
        # throughput (5% wall-clock headroom on "equal")
        "chunked_better_ttft": chunked["ttft_p95_ms"]
        < unchunked["ttft_p95_ms"],
        "throughput_held": tps_ratio >= 1 / 1.05,
    }
    print(f"continuous_zipf: ttft p95 {unchunked['ttft_p95_ms']:.0f}ms -> "
          f"{chunked['ttft_p95_ms']:.0f}ms chunked, throughput "
          f"{unchunked['tokens_per_sec']:.0f} -> "
          f"{chunked['tokens_per_sec']:.0f} tok/s ({tps_ratio:.2f}x)")
    return out


def residency_memory_trade(n_tenants: int = 24, n_requests: int = 24,
                           n_slots: int = 8, residency_mb: float = 64.0
                           ) -> dict:
    """Residency's memory trade at a >16-tenant config (deferred half of
    the PR 5 residency row): what the value cache actually commits in
    bytes, against the packed deltas it fronts, at a fleet size where
    capacity pressure and LRU churn are real."""
    row = continuous_bench(n_tenants, n_requests=n_requests,
                           n_slots=n_slots, residency_mb=residency_mb)
    res = row.get("residency") or {}
    packed_total = row["delta_bytes_per_tenant"] * n_tenants
    out = {
        "n_tenants": n_tenants,
        "n_requests": n_requests,
        "residency_mb": residency_mb,
        "tokens_per_sec": row["tokens_per_sec"],
        "packed_delta_bytes_total": packed_total,
        "value_cache_allocated_bytes": res.get("allocated_bytes"),
        "value_cache_row_bytes": res.get("row_bytes"),
        "capacity_rows": res.get("capacity_rows"),
        "resident_rows": res.get("resident_rows"),
        "hit_rate": res.get("hit_rate"),
        "fallback_steps": res.get("fallback_steps"),
        # the trade: decoded-f32 bytes committed per packed delta byte
        "allocated_vs_packed_x": None if not res.get("allocated_bytes")
        else res["allocated_bytes"] / packed_total,
    }
    alloc = out["value_cache_allocated_bytes"] or 0
    print(f"residency_memory_24t: {alloc / 1e6:.2f}MB value cache vs "
          f"{packed_total / 1e6:.2f}MB packed deltas "
          f"({out['allocated_vs_packed_x'] or 0:.1f}x), hit rate "
          f"{out['hit_rate'] if out['hit_rate'] is not None else 'n/a'}")
    return out


def tenant_lifecycle(n_tenants: int = 3, max_new: int = 8,
                     n_slots: int = 4) -> dict:
    """Online tenant lifecycle row: raw checkpoint -> compress ->
    hot-register into a RUNNING engine -> first token.

    tenant0 is registered up front (it builds the tenant table and pays
    the delta-decode jit trace); tenants 1..N then arrive while
    tenant0's sequences are decoding, and each row measures
    ``compress_s`` (core.compress wall), ``register_s`` (the table row
    write) and ``register_to_first_token_s`` (checkpoint arrival to that
    tenant's first served token, engine live throughout). The gated
    invariant is ``decode_recompiles == 0``: hot registration, rollout
    and retirement must never retrace the decode step. Deterministic
    scheduling via VirtualClock; the wall times are real compute.
    """
    from repro.serve import DeltaRegistry, VirtualClock

    cfg = get_smoke_config("llama3.2-1b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    # +2 rows: every tenant resident plus one spare for the rollout
    eng = ContinuousEngine(cfg, base, n_slots=n_slots, max_seq=64,
                           tenant_capacity=n_tenants + 2,
                           clock=VirtualClock(tick=1e-3))
    reg = DeltaRegistry(eng, base, spec=SERVE_SPEC, codec=None)

    def ft_of(seed):
        return jax.tree.map(
            lambda p: p + 0.02 * jax.random.normal(
                jax.random.fold_in(rng, seed), p.shape,
                jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)

    # tenant0 + warmup: the table exists and every jit shape (both
    # prompt buckets + the grouped decode) is compiled before the
    # measured registrations — their cost is lifecycle, not XLA
    reg.ingest("tenant0", ft_of(7)); reg.pump()
    warm = [eng.submit("tenant0", np.zeros(L, np.int32), max_new_tokens=2)
            for L in (4, 12)]
    eng.run()
    assert all(w.done for w in warm)
    # post-warmup recompile count via CompileGuard — the same (single)
    # implementation the lifecycle tests and launcher drill gate on
    guard = CompileGuard(eng, max_new={"decode": 0})

    rs = np.random.RandomState(0)
    inflight = [eng.submit("tenant0",
                           rs.randint(0, cfg.vocab, size=8).astype(np.int32),
                           max_new_tokens=max_new)]
    eng.step(eng._now())                # tenant0 genuinely in flight
    rows = []
    for t in range(1, n_tenants + 1):
        name = f"tenant{t}"
        t0 = time.perf_counter()
        reg.ingest(name, ft_of(7 + t))
        reg.pump()                      # hot-register into the live engine
        rec = reg._records[name]
        req = reg.submit(name, rs.randint(0, cfg.vocab, size=8).astype(
            np.int32), max_new_tokens=max_new)
        while not req.tokens:
            eng.step(eng._now())
        rows.append({"tenant": name, "compress_s": rec.compress_s,
                     "register_s": rec.register_s,
                     "register_to_first_token_s": time.perf_counter() - t0})
        inflight.append(req)
    eng.run()
    assert all(r.done for r in inflight)

    t0 = time.perf_counter()
    reg.ingest("tenant0", ft_of(777)); reg.pump()    # version rollout
    rollout_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.unregister_tenant("tenant1")                 # drained: retire
    retire_s = time.perf_counter() - t0

    recompiles = guard.new_compiles("decode")
    out = {
        "n_tenants": n_tenants,
        "tenants": rows,
        "compress_s_mean": float(np.mean([r["compress_s"] for r in rows])),
        "register_s_mean": float(np.mean([r["register_s"] for r in rows])),
        "register_to_first_token_s_mean": float(np.mean(
            [r["register_to_first_token_s"] for r in rows])),
        "rollout_s": rollout_s,
        "retire_s": retire_s,
        "decode_recompiles": recompiles,
        "lifecycle_events": eng.metrics.report()["tenant_lifecycle"],
    }
    print(f"tenant_lifecycle: compress {out['compress_s_mean']:.2f}s, "
          f"register {1e3 * out['register_s_mean']:.0f}ms, "
          f"register->first token {out['register_to_first_token_s_mean']:.2f}s"
          f" mean of {n_tenants}; rollout {1e3 * rollout_s:.0f}ms, retire "
          f"{1e3 * retire_s:.0f}ms, decode recompiles {recompiles}")
    return out


def compare_against(fresh: dict, baseline_path: str, tolerance: float) -> list:
    """Regressions of the fresh run vs a committed baseline (throughput
    may not drop below baseline/tolerance; decode latency may not grow
    past baseline*tolerance). Returns a list of human-readable failures."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    fails = []
    # deterministic (VirtualClock) affinity invariant: per-shard unique-
    # tenant load must be strictly lower than occupancy admission on the
    # 16-tenant skewed trace — replay-exact, so no tolerance
    auc = fresh.get("affinity_unique_check")
    if auc and not auc.get("affinity_strictly_lower"):
        fails.append(
            f"affinity admission unique-tenants/shard "
            f"{auc['unique_per_shard_affinity']:.3f} not strictly lower "
            f"than occupancy {auc['unique_per_shard_occupancy']:.3f}")
    # residency vs its packed twin: same process, back-to-back, same
    # workload — the RATIO is less noisy than absolute tok/s, but CI
    # wall-clock still shows real same-machine spread (see the data2
    # tolerance note), so the floor only catches structural regressions
    # (values path ~2x slower than the unpack it removes), not jitter;
    # the >= 1.0 expectation is reported (vs_packed_x) and pinned by the
    # committed full-run baseline
    res = fresh.get("continuous_residency")
    if res and res.get("vs_packed_x") is not None \
            and res["vs_packed_x"] < 0.5:
        fails.append(
            f"residency throughput {res['vs_packed_x']:.2f}x of its packed "
            "twin (< 0.5 floor): the values path is structurally slower "
            "than the per-step unpack it removes")
    # tracing-overhead gate: absolute (same-process twin ratio, not a
    # baseline diff) — the observability subsystem promises <3% cost at
    # default sampling; 1.05x is that contract plus CI jitter headroom
    tro = fresh.get("tracing_overhead")
    if tro and tro.get("tracing_overhead_x") is not None \
            and tro["tracing_overhead_x"] > 1.05:
        fails.append(
            f"tracing overhead {tro['tracing_overhead_x']:.3f}x > 1.05x "
            f"(traced {tro['traced_tokens_per_sec']:.0f} vs untraced "
            f"{tro['untraced_tokens_per_sec']:.0f} tok/s)")
    # chunked-prefill zipf gate: same-process twin over the SAME trace,
    # so no baseline row or tolerance — chunked must deliver strictly
    # better tail TTFT without giving up throughput (5% headroom on
    # "equal"); anything else means interleaving stopped paying its way
    zp = fresh.get("continuous_zipf")
    if zp:
        if not zp.get("chunked_better_ttft"):
            fails.append(
                f"chunked prefill ttft_p95 "
                f"{zp['chunked']['ttft_p95_ms']:.0f}ms not strictly "
                f"better than unchunked "
                f"{zp['unchunked']['ttft_p95_ms']:.0f}ms on the zipf row")
        if not zp.get("throughput_held"):
            fails.append(
                f"chunked prefill throughput "
                f"{zp['tps_chunked_vs_unchunked_x']:.2f}x of its "
                f"unchunked twin (< 1/1.05) on the zipf row")
    # lifecycle gate: hot registration / rollout / retirement must not
    # retrace the decode step — a recompile count is exact (jit cache
    # size, not wall clock), so it gates at 0 with no tolerance
    tl = fresh.get("tenant_lifecycle")
    if tl and tl.get("decode_recompiles", 0) != 0:
        fails.append(
            f"tenant_lifecycle: {tl['decode_recompiles']} decode-step "
            "recompile(s) across hot registration/rollout/retire "
            "(must be exactly 0)")
    base_us = baseline.get("micro", {}).get("decode_with_delta_us")
    fresh_us = fresh.get("micro", {}).get("decode_with_delta_us")
    if base_us and fresh_us and fresh_us > base_us * tolerance:
        fails.append(f"decode_with_delta_us {fresh_us:.0f} > "
                     f"{tolerance}x baseline {base_us:.0f}")
    base_by_n = {c["n_tenants"]: c for c in baseline.get("continuous", [])}
    for c in fresh.get("continuous", []):
        b = base_by_n.get(c["n_tenants"])
        # only compare identical workloads: a row with a different request
        # count measures a different queueing regime, not a regression
        if not b or b.get("n_requests") != c.get("n_requests"):
            continue
        floor = b["tokens_per_sec"] / tolerance
        if c["tokens_per_sec"] < floor:
            fails.append(
                f"{c['n_tenants']}-tenant throughput {c['tokens_per_sec']:.0f} "
                f"tok/s < baseline {b['tokens_per_sec']:.0f}/{tolerance}")
    for row in ("continuous_sharded", "continuous_data2",
                "continuous_affinity", "continuous_residency"):
        b_sh = baseline.get(row)
        f_sh = fresh.get(row)
        # The data-parallel row emulates shard_map collectives over BOTH
        # mesh axes on fake CPU devices; its wall-clock is noisier than
        # the single-mesh rows, so it gates at 1.5x the base tolerance
        # (tightened from the original 2x once the row's spread settled).
        # continuous_sharded keeps its original (base) sensitivity — its
        # gate predates this row and loosening it here would silently
        # blind CI to model-sharded decode regressions.
        mesh_tol = tolerance * (1.5 if row == "continuous_data2"
                                else 1.0)
        if b_sh and f_sh and b_sh.get("n_requests") == f_sh.get("n_requests") \
                and b_sh.get("devices") == f_sh.get("devices") \
                and b_sh.get("data", 1) == f_sh.get("data", 1):
            if f_sh["tokens_per_sec"] < b_sh["tokens_per_sec"] / mesh_tol:
                fails.append(
                    f"{row} ({f_sh['devices']}-device, "
                    f"data={f_sh.get('data', 1)}) throughput "
                    f"{f_sh['tokens_per_sec']:.0f} tok/s < baseline "
                    f"{b_sh['tokens_per_sec']:.0f}/{mesh_tol}")
        # Shard participation gate: with this row's workload (requests
        # outnumber slots, arrival gap << per-request service time) every
        # shard pool must decode tokens — a broken admission policy that
        # funnels the stream onto one shard zeroes the other pool's
        # count. Step-level imbalance is reported but NOT gated: it
        # depends on when finishes land relative to admission rounds
        # (timing), and for small pools its reachable range can't
        # separate broken from correct admission; the deterministic
        # admission invariants live in the hypothesis suite
        # (tests/test_serve_scheduler.py), not here.
        for s in (f_sh or {}).get("shards") or []:
            if not s["tokens"]:
                fails.append(
                    f"{row} data shard {s['shard']} decoded 0 tokens "
                    "(occupancy-balanced admission broken?)")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="trimmed tenant sweep (1/4, skipping the slow "
                         "16-tenant throughput rows incl. the affinity "
                         "trajectory row; the deterministic "
                         "affinity_unique_check still runs and gates) for "
                         "CI; request count stays the same so rows remain "
                         "comparable to the baseline")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: repo-root BENCH_serve.json; "
                         "quick runs default to BENCH_serve.quick.json so a "
                         "trimmed sweep never overwrites the committed "
                         "baseline)")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail (exit 1) on regression vs this baseline")
    ap.add_argument("--tolerance", type=float, default=2.0)
    ap.add_argument("--devices", type=int, default=0,
                    help="also run a sharded 2-tenant row over N fake "
                         "devices (requires XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N); recorded under "
                         "'continuous_sharded'")
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            REPO, "BENCH_serve.quick.json" if args.quick else "BENCH_serve.json")

    tenant_sweep = (1, 4) if args.quick else (1, 4, 16)
    report = {"micro": decode_overhead(), "continuous": []}
    for n_tenants in tenant_sweep:
        report["continuous"].append(continuous_bench(n_tenants))
    # residency row: the exact 4-tenant workload of the continuous sweep
    # (so it exists in quick AND full runs and compares 1:1) with the
    # pre-decoded delta value cache enabled — its throughput should be
    # >= the packed twin's, since decode steps skip the per-step unpack
    report["continuous_residency"] = continuous_bench(4, residency_mb=64.0)
    packed_twin = next(c for c in report["continuous"]
                       if c["n_tenants"] == 4)
    res_tps = report["continuous_residency"]["tokens_per_sec"]
    ratio = res_tps / packed_twin["tokens_per_sec"]
    report["continuous_residency"]["vs_packed_x"] = ratio
    print(f"residency vs packed (4-tenant twin): {ratio:.2f}x "
          f"({'OK' if ratio >= 1.0 else 'below packed — wall-clock noise?'})")
    # tracing-overhead row: traced/untraced twin of the 4-tenant row;
    # runs in quick mode too (it IS the CI gate for the <3% contract)
    report["tracing_overhead"] = tracing_overhead()
    # affinity: the deterministic unique-tenant comparison is the gated
    # invariant and runs in BOTH modes (it is what --check enforces);
    # the wall-clock 16-tenant affinity trajectory row is full-mode only
    # (--quick's contract is to skip the slow 16-tenant throughput runs)
    report["affinity_unique_check"] = affinity_unique_check()
    if not args.quick:
        report["continuous_affinity"] = continuous_bench(
            16, n_requests=16, n_slots=8, data=2, admission="affinity")
    if args.devices > 1:
        report["continuous_sharded"] = continuous_bench(
            2, n_requests=8, devices=args.devices)
        if args.devices % 2 == 0:
            # data-parallel row: (2, devices/2) mesh, slot rows split into
            # two shard pools with occupancy-balanced admission
            report["continuous_data2"] = continuous_bench(
                2, n_requests=8, devices=args.devices, data=2)

    # tenant-lifecycle row: hot compress-and-register into a running
    # engine; its decode_recompiles==0 gate is deterministic (jit cache
    # size), so it runs — and gates — in quick mode too
    report["tenant_lifecycle"] = tenant_lifecycle()
    # chunked-prefill zipf row: same-trace twin (chunked vs whole-prompt)
    # under sustained hot-tenant load across the full bucket ladder; its
    # gate is within-process (twin ratio), so it runs in quick mode too
    report["continuous_zipf"] = continuous_zipf(
        n_requests=24 if args.quick else 48,
        devices=args.devices if args.devices > 1 else 1)
    if not args.quick:
        # residency memory trade at fleet scale (>16 tenants): bytes the
        # value cache commits against the packed deltas it fronts
        report["residency_memory_24t"] = residency_memory_trade()

    base_bytes = report["continuous"][0]["base_bytes"]
    delta_bytes = report["continuous"][0]["delta_bytes_per_tenant"]
    n = 16
    full = base_bytes * n
    ours = base_bytes + delta_bytes * n
    report["memory_16_tenants"] = {
        "full_models_mb": full / 1e6, "deltadq_mb": ours / 1e6,
        "saving_x": full / ours,
    }
    print(f"memory_16_tenants: full={full / 1e6:.1f}MB "
          f"deltadq={ours / 1e6:.1f}MB saving={full / ours:.1f}x")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {args.out}")

    us = report["micro"]["decode_with_delta_us"]
    csv_row("serve_bench", us,
            f"delta_overhead={report['micro']['delta_overhead_x']:.2f}x;"
            f"mem_saving_16t={full / ours:.1f}x;"
            f"tok_s={report['continuous'][-1]['tokens_per_sec']:.0f}")

    if args.check:
        fails = compare_against(report, args.check, args.tolerance)
        if fails:
            for f_ in fails:
                print(f"REGRESSION: {f_}", file=sys.stderr)
            sys.exit(1)
        print(f"# bench regression check vs {args.check}: OK "
              f"(tolerance {args.tolerance}x)")


if __name__ == "__main__":
    main()
