"""Per-codec compression benchmark on wizard-llama2-7b (smoke) shapes.

For every registered delta codec, compresses the same synthetic
(base, ft) pair and records:

* ``ratio_paper`` / ``ratio_honest`` — storage accounting (deterministic,
  compared EXACTLY by ``--check``),
* ``rel_error`` — mean relative Frobenius reconstruction error over the
  compressed leaves (deterministic given the pinned seeds),
* ``decode_us`` — wall-clock of the XLA fallback correction at a
  decode-sized token count on the largest compressed leaf's RUNTIME form
  (every codec serves through the same PackedDelta machinery, so this is
  the per-codec serving cost, not a format-specific path),

plus an ``auto`` row (``codec="auto"``, the default 2.0 bits/element
budget) that must report ``budget_met`` — the auto-picker provably fits
the budget on this config.

Writes ``BENCH_compress.json`` at the repo root. CI regression gate::

    python -m benchmarks.compress_bench --out BENCH_compress.fresh.json \
        --check BENCH_compress.json --tolerance 3.0

Ratios gate exactly; ``rel_error`` may not grow past 1.05x the baseline
(it is deterministic — the headroom only covers BLAS/libm drift across
runner images); ``decode_us`` gates at the wall-clock tolerance.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.core.codecs import (
    BitDeltaSpec,
    LowRankSpec,
    codec_of_leaf,
    is_codec_leaf,
    reconstruct_dense_any,
    runtime_packed_leaf,
)
from repro.kernels import fallback
from repro.models import lm
from repro.utils import flatten_with_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# one spec per codec: DeltaDQ at the launcher's 128x deployment point
CODEC_SPECS = {
    "deltadq": DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16),
    "bitdelta": BitDeltaSpec(),
    "lowrank": LowRankSpec(rank=8, k_bits=4),
}
AUTO_BUDGET_BITS = 2.0
DECODE_T = 4                       # decode-sized token count


def _models():
    cfg = get_smoke_config("wizard-llama2-7b")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    ft = jax.tree.map(
        lambda p: p + 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape, jnp.float32).astype(p.dtype)
        if p.ndim >= 2 else p, base)
    return cfg, base, ft


def _time_decode(leaf) -> float:
    """us per fallback correction call on the leaf's runtime form."""
    d = runtime_packed_leaf(leaf)
    if d.stack_shape():
        d = d.index(0)
    x = jax.random.normal(jax.random.PRNGKey(2), (DECODE_T, d.h_in))
    fn = jax.jit(lambda x: fallback.correction_nd(x, d))
    jax.block_until_ready(fn(x))   # compile
    t0 = time.perf_counter()
    n = 50
    for _ in range(n):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _rel_error(base, ft, deltas) -> float:
    fb = flatten_with_paths(base)
    ff = flatten_with_paths(ft)
    fd = flatten_with_paths(deltas, is_leaf=is_codec_leaf)
    errs = []
    for k, d in fd.items():
        if d is None:
            continue
        delta = np.asarray(ff[k], np.float32) - np.asarray(fb[k], np.float32)
        recon = np.asarray(reconstruct_dense_any(d), np.float32)
        errs.append(float(np.linalg.norm(recon - delta))
                    / max(float(np.linalg.norm(delta)), 1e-12))
    return float(np.mean(errs))


def _largest_leaf(deltas):
    leaves = [l for l in jax.tree.leaves(deltas, is_leaf=is_codec_leaf)
              if is_codec_leaf(l)]
    return max(leaves, key=lambda l: l.h_in * l.h_out)


def codec_row(name: str, base, ft) -> dict:
    deltas, report = compress(base, ft, CODEC_SPECS[name])
    row = {
        "codec": name,
        "spec": repr(CODEC_SPECS[name]),
        "n_compressed": report.n_compressed,
        "ratio_paper": report.ratio_paper,
        "ratio_honest": report.ratio_honest,
        "rel_error": _rel_error(base, ft, deltas),
        "decode_us": _time_decode(_largest_leaf(deltas)),
    }
    print(f"{name}: paper {row['ratio_paper']:.1f}x honest "
          f"{row['ratio_honest']:.1f}x rel_err {row['rel_error']:.3f} "
          f"decode {row['decode_us']:.0f}us")
    return row


def auto_row(base, ft) -> dict:
    deltas, report = compress(base, ft, codec="auto",
                              budget_bits=AUTO_BUDGET_BITS)
    picks: dict[str, int] = {}
    for ch in report.auto_choices.values():
        picks[ch["codec"]] = picks.get(ch["codec"], 0) + 1
    row = {
        "budget_bits": AUTO_BUDGET_BITS,
        "budget_met": report.budget_met,
        "ratio_honest": report.ratio_honest,
        "rel_error": _rel_error(base, ft, deltas),
        "picks": picks,
        "max_bits_per_element": max(
            ch["bits_per_element"] for ch in report.auto_choices.values()),
    }
    print(f"auto(budget={AUTO_BUDGET_BITS}): met={row['budget_met']} "
          f"honest {row['ratio_honest']:.1f}x picks={picks}")
    return row


def compare_against(fresh: dict, baseline_path: str, tolerance: float) -> list:
    with open(baseline_path) as f:
        baseline = json.load(f)
    fails = []
    base_rows = {r["codec"]: r for r in baseline.get("codecs", [])}
    for r in fresh.get("codecs", []):
        b = base_rows.get(r["codec"])
        if not b or b.get("spec") != r.get("spec"):
            continue
        for key in ("ratio_paper", "ratio_honest"):
            if abs(r[key] - b[key]) > 1e-6:
                fails.append(f"{r['codec']} {key} {r[key]:.4f} != "
                             f"baseline {b[key]:.4f} (exact gate)")
        if r["rel_error"] > b["rel_error"] * 1.05:
            fails.append(f"{r['codec']} rel_error {r['rel_error']:.4f} > "
                         f"1.05x baseline {b['rel_error']:.4f}")
        if r["decode_us"] > b["decode_us"] * tolerance:
            fails.append(f"{r['codec']} decode_us {r['decode_us']:.0f} > "
                         f"{tolerance}x baseline {b['decode_us']:.0f}")
    auto = fresh.get("auto")
    if auto and not auto.get("budget_met"):
        fails.append(f"auto-picker failed its {auto.get('budget_bits')} "
                     f"bits/element budget (max "
                     f"{auto.get('max_bits_per_element'):.2f})")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_compress.json"))
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail (exit 1) on regression vs this baseline")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="wall-clock tolerance for decode_us")
    args = ap.parse_args()

    cfg, base, ft = _models()
    report = {"arch": cfg.name,
              "codecs": [codec_row(n, base, ft) for n in sorted(CODEC_SPECS)],
              "auto": auto_row(base, ft)}

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {args.out}")

    dq = next(r for r in report["codecs"] if r["codec"] == "deltadq")
    csv_row("compress_bench", dq["decode_us"],
            f"deltadq_honest={dq['ratio_honest']:.1f}x;"
            f"auto_met={report['auto']['budget_met']}")

    if args.check:
        fails = compare_against(report, args.check, args.tolerance)
        if fails:
            for f_ in fails:
                print(f"REGRESSION: {f_}", file=sys.stderr)
            sys.exit(1)
        print(f"# compress bench regression check vs {args.check}: OK")


if __name__ == "__main__":
    main()
