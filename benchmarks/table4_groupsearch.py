"""Table 4 reproduction: group-size selection — Direct vs Proxy.

Direct: compress the whole model at each candidate h_g and score full task
accuracy. Proxy: layer-1 attention error on ~1% calibration data (Eq. 5).
The paper's claim: proxy finds the same h_g* ~3x faster.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, get_models, task, task_accuracy
from repro.core import DeltaDQSpec, candidate_group_sizes, compress, search_direct, search_proxy
from repro.models import lm


def main():
    cfg, base, ft = get_models()
    batch = task().batch_at(0)
    x = lm.embed_tokens(cfg, base, jnp.asarray(batch["tokens"][:1])).reshape(-1, cfg.d_model)

    print("alpha,method,seconds,h_g_star")
    results = {}
    for alpha in (2, 4, 8):
        spec = DeltaDQSpec(alpha=float(alpha), k_bits=None)

        t0 = time.time()

        def direct_score(hg):
            s = DeltaDQSpec(alpha=float(alpha), k_bits=None, h_g=hg)
            deltas, _ = compress(base, ft, s)
            return -task_accuracy(cfg, base, deltas=deltas, n_batches=1)

        direct = search_direct(direct_score, cfg.d_model, spec)
        t_direct = time.time() - t0

        proxy = search_proxy(x.astype(jnp.float32),
                             base["attn"]["wq"][0].astype(jnp.float32),
                             base["attn"]["wk"][0].astype(jnp.float32),
                             ft["attn"]["wq"][0].astype(jnp.float32),
                             ft["attn"]["wk"][0].astype(jnp.float32), spec)
        print(f"{alpha},direct,{t_direct:.2f},{direct.h_g_star}")
        print(f"{alpha},proxy,{proxy.seconds:.2f},{proxy.h_g_star}")
        results[alpha] = (t_direct, proxy.seconds, direct.h_g_star, proxy.h_g_star)

    speedups = [d / max(p, 1e-9) for d, p, *_ in results.values()]
    us = sum(d + p for d, p, *_ in results.values()) * 1e6
    csv_row("table4_groupsearch", us,
            f"median_speedup={sorted(speedups)[1]:.1f}x;"
            f"agree={sum(int(a == b) for *_, a, b in results.values())}/3")


if __name__ == "__main__":
    main()
