"""Aggregate results/dryrun/*.json into the §Roofline table (markdown+CSV)."""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import RESULTS, csv_row

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str = "16x16"):
    """Post-perf-pass cells (results/dryrun2) preferred; cells whose v2
    recompile did not finish fall back to the v1 baseline (marked)."""
    cells = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, "dryrun", f"*__{mesh}.json"))):
        with open(p) as f:
            d = json.load(f)
        d["_version"] = "v1-baseline"
        cells[(d["arch"], d["shape"])] = d
    for p in sorted(glob.glob(os.path.join(RESULTS, "dryrun2", f"*__{mesh}.json"))):
        with open(p) as f:
            d = json.load(f)
        d["_version"] = "v2"
        cells[(d["arch"], d["shape"])] = d
    return cells


def fmt_row(d: dict) -> str:
    if d.get("skip_reason"):
        return f"| {d['arch']} | {d['shape']} | skip | — | — | — | — | — | {d['skip_reason']} |"
    if not d.get("ok"):
        return f"| {d['arch']} | {d['shape']} | FAIL | — | — | — | — | — | {str(d.get('error'))[:60]} |"
    r = d["roofline"]
    note = f"mem_frac={r.get('memory_frac'):.2f}" if r.get("memory_frac") is not None else "—"
    if d.get("_version") == "v1-baseline":
        note += " (v1 baseline)"
    return ("| {arch} | {shape} | {bn} | {tc:.2e} | {tm:.2e} | {tl:.2e} | "
            "{uf:.2f} | {rf:.3f} | {note} |").format(
        arch=d["arch"], shape=d["shape"], bn=r["bottleneck"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        uf=r["useful_flops_frac"], rf=r["roofline_frac"], note=note)


def main():
    t0 = time.time()
    cells = load_cells()
    print("| arch | shape | bottleneck | t_compute | t_memory | t_collective "
          "| useful_flops | roofline_frac | notes |")
    print("|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for (arch, shape) in sorted(cells, key=lambda k: (k[0], SHAPE_ORDER.index(k[1]))):
        d = cells[(arch, shape)]
        print(fmt_row(d))
        n_ok += bool(d.get("ok") and not d.get("skip_reason"))
        n_skip += bool(d.get("skip_reason"))
        n_fail += bool(not d.get("ok"))
    us = (time.time() - t0) * 1e6
    csv_row("roofline_report", us, f"cells_ok={n_ok};skips={n_skip};fails={n_fail}")


if __name__ == "__main__":
    main()
