"""Table 1 reproduction: accuracy vs baselines at 2/4/8/16x compression.

Protocol mirrors the paper at tiny scale: the REAL SFT delta of the bench
model is compressed by each method at each ratio; exact-match task accuracy
is measured through the serving engine. DeltaDQ uses Group-wise Dropout
(h_g from the proxy search) for 2-8x and adds quantization at 16x, exactly
like the paper.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row, get_models, layer_l2, task_accuracy
from repro.core import DeltaDQSpec, baselines, compress
from repro.core.pack import PackedDelta
from repro.utils import flatten_with_paths, map_with_paths


def compress_with_baseline(base, ft, method: str, alpha: float, rng):
    """Dense-compressed delta trees for baseline methods (uniform API)."""
    from repro.core.compress import is_compressible
    import jax.numpy as jnp

    def fn(path, b, f):
        if not is_compressible(path, b):
            return None
        d = f.astype(jnp.float32) - b.astype(jnp.float32)
        lead = d.shape[:-2]
        flatd = d.reshape((-1, *d.shape[-2:]))
        outs = [baselines.METHODS[method](jax.random.fold_in(rng, i), flatd[i], alpha=alpha)
                for i in range(flatd.shape[0])] if lead else \
               [baselines.METHODS[method](rng, d, alpha=alpha)]
        out = jnp.stack(outs).reshape(d.shape) if lead else outs[0]
        return out

    return map_with_paths(fn, base, ft)


def apply_dense_delta(base, dense_deltas):
    import jax.numpy as jnp
    return map_with_paths(
        lambda p, b, d: b if d is None else (b.astype(jnp.float32) + d).astype(b.dtype),
        base, dense_deltas)


DELTADQ_BY_ALPHA = {
    2: DeltaDQSpec(alpha=2.0, k_bits=None),
    4: DeltaDQSpec(alpha=4.0, k_bits=None),
    8: DeltaDQSpec(alpha=8.0, k_bits=None),
    16: DeltaDQSpec(alpha=8.0, k_bits=8, m=1),   # paper: quantization at 16x
}


def pick_hg(cfg, base, ft, spec):
    """Proxy search on layer-1 Q/K (paper §3.3)."""
    import jax.numpy as jnp
    from repro.core import search_proxy
    from repro.models import lm as lmod
    from benchmarks.common import task
    batch = task().batch_at(0)
    x = lmod.embed_tokens(cfg, base, jnp.asarray(batch["tokens"][:2])).reshape(-1, cfg.d_model)
    res = search_proxy(x.astype(jnp.float32),
                       base["attn"]["wq"][0].astype(jnp.float32),
                       base["attn"]["wk"][0].astype(jnp.float32),
                       ft["attn"]["wq"][0].astype(jnp.float32),
                       ft["attn"]["wk"][0].astype(jnp.float32), spec)
    return res.h_g_star


def main():
    t0 = time.time()
    cfg, base, ft = get_models()
    rng = jax.random.PRNGKey(0)
    acc_orig = task_accuracy(cfg, ft)
    acc_base = task_accuracy(cfg, base)
    print(f"# original(ft) acc={acc_orig:.3f}  raw base acc={acc_base:.3f}")
    print("method,ratio,accuracy,layer_l2")

    rows = {}
    for alpha in (2, 4, 8, 16):
        spec = DELTADQ_BY_ALPHA[alpha]
        hg = pick_hg(cfg, base, ft, spec)
        spec = DeltaDQSpec(alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m, h_g=hg)
        deltas, rep = compress(base, ft, spec)
        acc = task_accuracy(cfg, base, deltas=deltas)
        l2 = layer_l2(cfg, base, ft, deltas)
        rows[("deltadq", alpha)] = acc
        print(f"DeltaDQ(h_g={hg}),{alpha},{acc:.3f},{l2:.3e}")

        for method in ("magnitude", "dare", "deltazip"):
            dd = compress_with_baseline(base, ft, method, float(alpha), rng)
            merged = apply_dense_delta(base, dd)
            acc_m = task_accuracy(cfg, merged)
            rows[(method, alpha)] = acc_m
            print(f"{method},{alpha},{acc_m:.3f},-")

    us = (time.time() - t0) * 1e6
    win16 = rows[("deltadq", 16)] - max(rows[(m, 16)] for m in ("magnitude", "dare", "deltazip"))
    csv_row("table1_basic", us,
            f"acc_orig={acc_orig:.3f};deltadq16x={rows[('deltadq', 16)]:.3f};margin16x={win16:+.3f}")


if __name__ == "__main__":
    main()
