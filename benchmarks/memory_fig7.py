"""Fig. 7 reproduction: Separate Quantization's memory/accuracy vs m.

Two claims: (1) growing m adds only negligible memory (group offsets +
offset coefficients) at fixed FINAL storage bit-width; (2) at ultra-low
final bits (2-bit, 1-bit storage), accuracy improves dramatically with m
because code resolution is k = final_bits + log2(m). Recomputed for TPU
v5e HBM (16 GiB/chip) instead of the paper's V100/A100.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import csv_row, get_models, task_accuracy
from repro.core import DeltaDQSpec, compress
from repro.core.pack import PackedDelta, to_storage_parts
from repro.utils import flatten_with_paths

V5E_HBM = 16 * 2**30


def storage_bytes(deltas) -> tuple[float, float]:
    """(paper-convention value bytes, honest bytes incl indices+offsets)."""
    vals = honest = 0.0
    flat = flatten_with_paths(deltas, is_leaf=lambda x: isinstance(x, PackedDelta))
    for d in flat.values():
        if d is None:
            continue
        import numpy as np
        stack = int(np.prod(d.stack_shape())) if d.stack_shape() else 1
        vals += d.value_bits() * stack / 8
        honest += (d.value_bits() + d.index_bits()) * stack / 8
        # group offsets: one int per (group,col) per part (paper's CSR rows)
        honest += d.m * d.n_groups * d.h_out * stack * 4 / 64  # amortized 64-entry offsets
    return vals, honest


def main():
    t0 = time.time()
    cfg, base, ft = get_models()
    alpha = 8.0

    print("final_bits,m,k_codes,ratio,value_bytes,honest_bytes,accuracy")
    rows = {}
    # fixed FINAL storage bits, growing m -> k = bits + log2(m) resolution
    for final_bits in (2, 1):
        for m in (1, 2, 4, 8):
            import math
            k = final_bits + int(math.log2(m))
            if k > 8:
                continue
            spec = DeltaDQSpec(alpha=alpha, k_bits=k, m=m, h_g=64)
            deltas, _ = compress(base, ft, spec)
            vb, hb = storage_bytes(deltas)
            acc = task_accuracy(cfg, base, deltas=deltas, n_batches=2)
            rows[(final_bits, m)] = (vb, acc)
            print(f"{final_bits},{m},{k},{spec.ratio():.0f},{vb:.0f},{hb:.0f},{acc:.3f}")

    # memory constant in m at fixed final bits; accuracy grows with m
    (v1, a1), (v8, a8) = rows[(1, 1)], rows[(1, 8)]
    us = (time.time() - t0) * 1e6
    csv_row("memory_fig7", us,
            f"mem_growth_m8={v8 / v1:.3f}x;acc_1bit_m1={a1:.3f};acc_1bit_m8={a8:.3f}")


if __name__ == "__main__":
    main()
