"""Fig. 5 reproduction: impact of group size on accuracy at fixed ratio.

Sweeps h_g for the bench model's SFT delta at alpha=8 and reports task
accuracy + the attention-proxy error per candidate. The paper's finding:
the optimum is an interior h_g* (smaller is not monotonically better),
unlike group-wise quantization.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import csv_row, get_models, task, task_accuracy
from repro.core import DeltaDQSpec, candidate_group_sizes, compress
from repro.core.groupsearch import attention_proxy_error
from repro.models import lm
import jax


def main():
    t0 = time.time()
    cfg, base, ft = get_models()
    alpha = 8.0
    batch = task().batch_at(0)
    x = lm.embed_tokens(cfg, base, jnp.asarray(batch["tokens"][:2])).reshape(-1, cfg.d_model)
    x = x.astype(jnp.float32)

    print("h_g,accuracy,proxy_error")
    accs = {}
    for hg in candidate_group_sizes(cfg.d_model, alpha):
        spec = DeltaDQSpec(alpha=alpha, k_bits=None, h_g=hg)
        deltas, _ = compress(base, ft, spec)
        acc = task_accuracy(cfg, base, deltas=deltas, n_batches=2)
        err = float(attention_proxy_error(
            x, base["attn"]["wq"][0].astype(jnp.float32),
            base["attn"]["wk"][0].astype(jnp.float32),
            ft["attn"]["wq"][0].astype(jnp.float32),
            ft["attn"]["wk"][0].astype(jnp.float32),
            hg, spec, jax.random.PRNGKey(hg)))
        accs[hg] = acc
        print(f"{hg},{acc:.3f},{err:.4e}")

    best = max(accs, key=accs.get)
    us = (time.time() - t0) * 1e6
    csv_row("fig5_groupsize", us, f"best_hg={best};spread={max(accs.values()) - min(accs.values()):.3f}")


if __name__ == "__main__":
    main()
