"""Tables 2-3 reproduction: ultra-high compression (32x..256x).

The paper's key result: pushing alpha alone (m=1) collapses accuracy, but
holding alpha at its safe value and growing m (Separate Quantization's
storage decomposition) keeps accuracy flat while the ratio multiplies —
DeltaDQ(m=8) at 128x == DeltaDQ(m=1) at 32x, while DARE/Magnitude/
DeltaZip degrade or die (paper Tables 2 and 3).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import csv_row, get_models, task_accuracy
from benchmarks.table1_basic import apply_dense_delta, compress_with_baseline, pick_hg
from repro.core import DeltaDQSpec, compress

# ratio -> list of (label, spec); mirrors the paper's rows
ROWS = [
    (32, [("DeltaDQ(m=1)", DeltaDQSpec(alpha=8, k_bits=4, m=1))]),
    (64, [("DeltaDQ(m=1)", DeltaDQSpec(alpha=16, k_bits=4, m=1)),
          ("DeltaDQ(m=4)", DeltaDQSpec(alpha=8, k_bits=4, m=4))]),
    (128, [("DeltaDQ(m=1)", DeltaDQSpec(alpha=32, k_bits=4, m=1)),
           ("DeltaDQ(m=8)", DeltaDQSpec(alpha=8, k_bits=4, m=8))]),
]


def main():
    t0 = time.time()
    cfg, base, ft = get_models()
    rng = jax.random.PRNGKey(1)
    acc_orig = task_accuracy(cfg, ft)
    print(f"# original(ft) acc={acc_orig:.3f}")
    print("method,ratio,accuracy")

    flat_acc = {}
    for ratio, entries in ROWS:
        for label, spec in entries:
            hg = pick_hg(cfg, base, ft, spec)
            spec = DeltaDQSpec(alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m, h_g=hg)
            assert abs(spec.ratio() - ratio) < 1e-6, (spec, ratio)
            deltas, _ = compress(base, ft, spec)
            acc = task_accuracy(cfg, base, deltas=deltas)
            flat_acc[(label, ratio)] = acc
            print(f"{label},{ratio},{acc:.3f}")
        for method in ("magnitude", "dare", "deltazip"):
            dd = compress_with_baseline(base, ft, method, float(ratio), rng)
            acc = task_accuracy(cfg, apply_dense_delta(base, dd))
            flat_acc[(method, ratio)] = acc
            print(f"{method},{ratio},{acc:.3f}")

    # the paper's signature pattern: m>1 at 128x matches m=1 at 32x
    a32 = flat_acc[("DeltaDQ(m=1)", 32)]
    a128m8 = flat_acc[("DeltaDQ(m=8)", 128)]
    us = (time.time() - t0) * 1e6
    csv_row("table23_ultra", us,
            f"acc32x={a32:.3f};acc128x_m8={a128m8:.3f};identical={abs(a32 - a128m8) < 1e-9}")


if __name__ == "__main__":
    main()
