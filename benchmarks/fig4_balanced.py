"""Fig. 4 reproduction: Balanced Intermediate Results.

For each output element a_pq = sum_j x_pj * w_qj, compare the variance and
min-max range of the per-j intermediate products for the DELTA weight vs
the FINE-TUNED weight. The paper's observation: delta products are orders
of magnitude more balanced — the property that makes random dropping
near-lossless.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_models, task
from repro.models import lm
from repro.utils import flatten_with_paths


def intermediate_stats(x: jnp.ndarray, w: jnp.ndarray, n_out: int = 32):
    """x [t, h_in]; w [h_in, h_out] -> per-(p,q) variance and range of the
    h_in intermediate products, averaged."""
    prods = x[:, :, None] * w[None, :, :n_out]        # [t, h_in, n_out]
    var = jnp.var(prods, axis=1)
    rng = jnp.max(prods, axis=1) - jnp.min(prods, axis=1)
    return float(jnp.mean(var)), float(jnp.mean(rng))


def main():
    t0 = time.time()
    cfg, base, ft = get_models()
    fb = flatten_with_paths(base)
    ff = flatten_with_paths(ft)
    batch = task().batch_at(0)
    x = lm.embed_tokens(cfg, base, jnp.asarray(batch["tokens"][:2])).reshape(-1, cfg.d_model)
    x = x.astype(jnp.float32)

    print("layer,var_ft,var_delta,range_ft,range_delta,var_ratio,range_ratio")
    ratios = []
    for key in ("attn/wq", "attn/wk", "mlp/wi"):
        wf = ff[key][0].astype(jnp.float32)           # layer 0
        wb = fb[key][0].astype(jnp.float32)
        d = wf - wb
        v_ft, r_ft = intermediate_stats(x, wf)
        v_d, r_d = intermediate_stats(x, d)
        ratios.append(v_ft / max(v_d, 1e-20))
        print(f"{key},{v_ft:.3e},{v_d:.3e},{r_ft:.3e},{r_d:.3e},"
              f"{v_ft / max(v_d, 1e-20):.1f},{r_ft / max(r_d, 1e-20):.1f}")

    us = (time.time() - t0) * 1e6
    csv_row("fig4_balanced", us, f"median_var_ratio={np.median(ratios):.1f}x")
    assert np.median(ratios) > 3, "delta products should be more balanced"


if __name__ == "__main__":
    main()
