"""Delta-correction kernel microbenchmarks (the decode-path hot ops).

Times every correction formulation the serving engine can dispatch to,
at decode- and prefill-shaped workloads, and writes ``BENCH_kernels.json``
at the repo root so the kernel-level perf trajectory is measurable and
CI-gated (the serve bench measures the end-to-end step; this isolates
the correction itself).

Variants per shape:

* ``xla_dense_us``    — reconstruct dense + matmul (the old hot path)
* ``xla_gather_us``   — gather formulation (kernels/fallback.py)
* ``per_row_dup_us``  / ``per_row_distinct_us``   — per-row slot dispatch
  (row-gathered stack) on duplicate-heavy / all-distinct decode batches
* ``segments_dup_us`` / ``segments_distinct_us``  — unique-tenant segment
  dispatch on the same batches

On CPU hosts the Pallas kernels only run in interpret mode (validation,
not perf), so the wall-clock variants are the XLA formulations that
actually serve on this host; compiled-kernel tile timing happens on TPU
via ``repro.kernels.autotune``. The unique-tenant dedup is a *kernel*
property (each [h_g, Ob] tile decoded once per segment instead of once
per row), so the segments-vs-per-row invariant is gated on the
deterministic decode-tile accounting (``ops.segment_decode_tiles`` vs
``ops.per_row_decode_tiles``) rather than CPU wall-clock, which cannot
observe VMEM tile reuse.

Since chunked prefill drives the correction at chunk-sized token
counts, each shape also times a ``chunk`` phase (T = the engine's
default chunk size) and records the per-T formulation view of the v3
autotune table (``autotune_by_t``) alongside the served decision, so a
baseline diff shows the gather/dense crossover moving with T.

CI regression gate::

    python -m benchmarks.kernel_bench --quick --check BENCH_kernels.json

``--check`` fails (exit 1) when a fresh timing exceeds the committed
baseline by more than ``tolerance`` x (default 2.25 — timings are
min-of-repeats, see ``_time``), and enforces the structural invariant
that segment dispatch beats per-row dispatch whenever the decode batch
contains duplicate tenants.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (name, h_in, h_out, h_g, alpha, k_bits, T_decode, T_prefill)
SHAPES = [
    ("serve_hg16", 128, 256, 16, 8, 4, 8, 64),
    ("bench_hg64", 128, 256, 64, 8, 4, 8, 64),
    ("wide_hg64", 512, 512, 64, 8, 4, 8, 128),
]
QUICK_SHAPES = SHAPES[:2]

# duplicate-heavy vs all-distinct decode batches (B = 8 slots)
DUP_ROWS = np.array([1, 1, 1, 2, 1, 1, 2, 1], np.int32)
DISTINCT_ROWS = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.int32)


def _time(fn, *args, n: int = 25, repeats: int = 4) -> float:
    # autotune's mean-of-n, hardened for a gated bench: take the MIN of
    # several independent mean-of-n measurements. Scheduler noise and
    # host contention only ever ADD time, so min-of-repeats converges on
    # the true cost where a single mean wanders by 3-5x on a contended
    # host — measured worst-key spread across 6 back-to-back runs
    # dropped from 5.1x (single mean-of-50) to 1.84x (min of 4 x
    # mean-of-25), which is what lets --check gate at 2.25x instead of
    # the old 3.0x.
    from repro.kernels.autotune import _time as autotune_time
    return min(autotune_time(fn, *args, n=n) for _ in range(repeats))


def kernel_decode_work(h_in=128, h_out=256, h_g=64, ob=128, tb=8) -> dict:
    """Decode-tile accounting for the Pallas kernels on the two decode
    batches: the segments kernel must dequantize fewer [h_g, Ob] tiles
    than the vmapped per-row kernel whenever tenants repeat (that IS the
    unique-tenant optimization; deterministic, unlike CPU wall-clock)."""
    from repro.kernels import ops
    from repro.serve.scheduler import tenant_segments
    G = h_in // h_g
    out = {}
    for tag, rows in (("dup", DUP_ROWS), ("distinct", DISTINCT_ROWS)):
        seg = tenant_segments(rows)
        out[f"per_row_{tag}_tiles"] = ops.per_row_decode_tiles(
            len(rows), n_groups=G, h_out=h_out, ob=ob)
        out[f"segments_{tag}_tiles"] = ops.segment_decode_tiles(
            seg.seg_offsets, n_groups=G, h_out=h_out, tb=tb, ob=ob)
    print(f"kernel decode tiles (dup batch): per-row "
          f"{out['per_row_dup_tiles']} segments "
          f"{out['segments_dup_tiles']}")
    return out


def bench_shape(name, h_in, h_out, h_g, alpha, k_bits, t_dec, t_pre) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import groupwise_dropout_pack
    from repro.core.apply import stack_tenant_deltas
    from repro.kernels import fallback
    from repro.serve.scheduler import tenant_segments

    rng = jax.random.PRNGKey(0)
    packs = []
    for s in range(9):   # rows 0..8 for the distinct batch
        d = jax.random.normal(jax.random.PRNGKey(s), (h_in, h_out)) * 0.01
        packs.append(groupwise_dropout_pack(jax.random.PRNGKey(s), d,
                                            h_g=h_g, alpha=alpha,
                                            k_bits=k_bits))
    p = packs[1]
    stk = stack_tenant_deltas([{"w": q} for q in packs])["w"]

    out = {"shape": {"h_in": h_in, "h_out": h_out, "h_g": h_g,
                     "alpha": alpha, "k_bits": k_bits,
                     "T_decode": t_dec, "T_prefill": t_pre}}

    # which formulation the autotune table ACTUALLY selects at this
    # shape's decode/prefill token counts — the winner's identity, so a
    # BENCH_kernels.json diff can explain a crossover move instead of
    # showing two timings and leaving the dispatch decision invisible.
    # Captured through the same attribution hook the serving engine
    # uses, from the real chooser (fallback.correction_nd), so the
    # recorded winner can never drift from the served decision.
    from repro.kernels import autotune
    from repro.serve.trace import attribution
    out["autotune"] = autotune.lookup(h_g, p.keep, k_bits, h_in, h_out)
    # the v3 per-T overlay for this envelope point: measured gather/
    # dense timings + the formulation at each T_GRID bucket (None where
    # the point isn't in the swept table) — the record that explains a
    # crossover move in a baseline diff
    out["autotune_by_t"] = {
        str(T): autotune.load_table().get(
            autotune.envelope_key(h_g, p.keep, k_bits, h_in, h_out, t=T))
        for T in autotune.T_GRID}

    # "chunk" is the chunked-prefill engine's default chunk size: the
    # token count the combined decode+chunk step actually drives
    for phase, T in (("decode", t_dec), ("chunk", 16), ("prefill", t_pre)):
        x = jax.random.normal(rng, (T, h_in))
        with attribution() as notes:
            fallback.correction_nd(x, p)
        sel = next((n for n in notes if n["site"] == "correction"), None)
        out[f"{phase}_selected"] = sel["formulation"] if sel else None
        out[f"{phase}_codec"] = sel.get("codec") if sel else None
        out[f"{phase}_xla_dense_us"] = _time(
            lambda x: fallback.dense_correction(x, p), x)
        out[f"{phase}_xla_gather_us"] = _time(
            lambda x: fallback.gather_correction(x, p), x)

    # slot dispatch at the apply seam (includes the per-row packed
    # gather / the sort+unsort, exactly what the engine's decode pays)
    from repro.core.apply import (get_slot_dispatch, set_slot_dispatch,
                                  slot_delta_matmul, wrap_slot_deltas)
    xb = jax.random.normal(rng, (len(DUP_ROWS), 1, h_in))
    prev = get_slot_dispatch()
    try:
        for tag, rows in (("dup", DUP_ROWS), ("distinct", DISTINCT_ROWS)):
            seg = jax.tree.map(jnp.asarray, tenant_segments(rows))
            sd = wrap_slot_deltas({"w": stk}, jnp.asarray(rows),
                                  segments=seg)["w"]
            set_slot_dispatch("per_row")
            out[f"per_row_{tag}_us"] = _time(
                lambda x, sd: slot_delta_matmul(x, sd), xb, sd)
            set_slot_dispatch("segments")
            out[f"segments_{tag}_us"] = _time(
                lambda x, sd: slot_delta_matmul(x, sd), xb, sd)
            with attribution() as notes:
                slot_delta_matmul(xb, sd)
            out[f"segments_{tag}_selected"] = next(
                (n["formulation"] for n in notes if "formulation" in n),
                None)
            out[f"segments_{tag}_codec"] = next(
                (n["codec"] for n in notes if "codec" in n), None)
    finally:
        set_slot_dispatch(prev)

    print(f"{name}: decode dense {out['decode_xla_dense_us']:.0f}us "
          f"gather {out['decode_xla_gather_us']:.0f}us "
          f"(selected {out['decode_selected']}; "
          f"chunk {out['chunk_selected']}; "
          f"prefill {out['prefill_selected']}) | "
          f"dup per-row {out['per_row_dup_us']:.0f}us "
          f"segments {out['segments_dup_us']:.0f}us")
    return out


def compare_against(fresh: dict, baseline_path: str, tolerance: float) -> list:
    with open(baseline_path) as f:
        baseline = json.load(f)
    fails = []
    base_entries = baseline.get("entries", {})
    for name, entry in fresh.get("entries", {}).items():
        b = base_entries.get(name)
        if not b:
            continue
        for key, us in entry.items():
            if not key.endswith("_us"):
                continue
            base_us = b.get(key)
            if base_us and us > base_us * tolerance:
                fails.append(f"{name}.{key} {us:.0f}us > "
                             f"{tolerance}x baseline {base_us:.0f}us")
    # structural invariant: the segments kernel must dequantize strictly
    # fewer tiles than the vmapped per-row kernel whenever the decode
    # batch has duplicate tenants (deterministic work accounting), and
    # never more on an all-distinct batch
    k = fresh.get("kernel_decode_work", {})
    seg, row = k.get("segments_dup_tiles"), k.get("per_row_dup_tiles")
    if seg is not None and row is not None and seg >= row:
        fails.append(f"segments kernel decodes {seg} tiles, per-row {row} "
                     "on a duplicate-tenant batch (dedup not effective)")
    seg_d = k.get("segments_distinct_tiles")
    row_d = k.get("per_row_distinct_tiles")
    if seg_d is not None and row_d is not None and seg_d > row_d:
        fails.append(f"segments kernel decodes {seg_d} tiles > per-row "
                     f"{row_d} on an all-distinct batch")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="trimmed shape sweep for CI")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: repo-root BENCH_kernels.json;"
                         " quick runs default to BENCH_kernels.quick.json)")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail (exit 1) on regression vs this baseline")
    # min-of-repeats timing (see _time) bounds the measured repeat
    # spread at 1.84x worst-key, so the gate runs at 2.25x (was 3.0x
    # when a single mean-of-50 could wander 5x on a contended host);
    # the decode-tile invariant is exact regardless
    ap.add_argument("--tolerance", type=float, default=2.25)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            REPO, "BENCH_kernels.quick.json" if args.quick
            else "BENCH_kernels.json")

    import jax
    shapes = QUICK_SHAPES if args.quick else SHAPES
    report = {"backend": jax.default_backend(),
              "timing": {"method": "min of 4 x mean-of-25",
                         "measured_worst_spread_x": 1.84,
                         "spread_runs": 6},
              "entries": {}}
    for spec in shapes:
        report["entries"][spec[0]] = bench_shape(*spec)
    report["kernel_decode_work"] = kernel_decode_work()

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")

    if args.check:
        fails = compare_against(report, args.check, args.tolerance)
        if fails:
            for f_ in fails:
                print(f"REGRESSION: {f_}", file=sys.stderr)
            sys.exit(1)
        print(f"# kernel bench regression check vs {args.check}: OK "
              f"(tolerance {args.tolerance}x)")


if __name__ == "__main__":
    main()
