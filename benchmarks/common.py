"""Shared fixtures for the paper-fidelity benchmarks.

Trains (once, cached to results/bench_models/) a small Llama-class model:
  * base  — pretrained on the noise mixture
  * ft    — base fine-tuned on the Sort task (the "WizardMath" stand-in)
Benchmarks then compress the REAL SFT delta and measure exact-match task
accuracy through the multi-tenant engine, mirroring the paper's
GSM8k/HumanEval protocol at tiny scale.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ArchConfig
from repro.data import PretrainMixture, SortTask
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.serve import Engine
from repro.train import make_train_step

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")

BENCH_ARCH = ArchConfig(
    name="bench-llama-3m", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv=2, head_dim=32, d_ff=256, vocab=64, act="silu", tie_embeddings=True,
)
N_DIGITS = 6
SEQ = 32


def task():
    return SortTask(vocab=BENCH_ARCH.vocab, seq_len=SEQ, batch=32,
                    n_digits=N_DIGITS, seed=1)


def _train(cfg, params, data, steps, lr):
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=lr, weight_decay=0.0)))
    m = {}
    for i in range(steps):
        params, opt, m = step(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
    return params, float(m.get("loss", jnp.nan))


def get_models(force: bool = False):
    """(cfg, base_params, ft_params) — cached across benchmark modules."""
    cfg = BENCH_ARCH
    ckdir = os.path.join(RESULTS, "bench_models")
    ck = Checkpointer(ckdir)
    tmpl = {"base": lm.init_params(cfg, jax.random.PRNGKey(0)),
            "ft": lm.init_params(cfg, jax.random.PRNGKey(0))}
    if not force and ck.latest_step() is not None:
        state, _ = ck.restore(tmpl)
        return cfg, state["base"], state["ft"]
    t0 = time.time()
    base = lm.init_params(cfg, jax.random.PRNGKey(0))
    # base learns token statistics + the task FORMAT (random answers), so
    # the SFT delta is small relative to W_base — the paper's regime
    from repro.data.pipeline import FormatOnlyTask
    pre = PretrainMixture(vocab=cfg.vocab, seq_len=SEQ, batch=32, seed=0)
    base, pre_loss = _train(cfg, base, pre, 80, 5e-3)
    fmt = FormatOnlyTask(vocab=cfg.vocab, seq_len=SEQ, batch=32, n_digits=N_DIGITS, seed=2)
    base, fmt_loss = _train(cfg, base, fmt, 250, 3e-3)
    ft, ft_loss = _train(cfg, base, task(), 300, 1e-3)
    import repro.utils as u
    dn = np.sqrt(sum(float(jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2))
                     for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(ft))))
    bn = np.sqrt(sum(float(jnp.sum(a.astype(jnp.float32) ** 2))
                     for a in jax.tree.leaves(base)))
    print(f"# trained bench models in {time.time() - t0:.0f}s "
          f"(pre {pre_loss:.3f}, fmt {fmt_loss:.3f}, sft {ft_loss:.3f}, "
          f"|delta|/|base|={dn / bn:.3f})")
    ck.save(1, {"base": base, "ft": ft})
    return cfg, base, ft


def task_accuracy(cfg, params, deltas=None, n_batches=3, base_params=None) -> float:
    """Exact-match accuracy on held-out sort prompts via the serve engine."""
    eng = Engine(cfg, base_params if base_params is not None else params,
                 max_seq=SEQ + N_DIGITS + 2)
    tname = None
    if deltas is not None:
        eng.register_tenant("t", deltas)
        tname = "t"
    t = task()
    correct = total = 0
    for s in range(n_batches):
        prompts, targets = t.prompts_at(10_000 + s)
        gen = eng.generate(tname, prompts, max_new_tokens=N_DIGITS)
        correct += (gen[:, :N_DIGITS] == targets).sum()
        total += targets.size
    return float(correct) / float(total)


def layer_l2(cfg, base, ft, deltas, n_tokens=64) -> float:
    """Paper Eq. 2 proxy: mean over compressed layers of ||XW - XW_hat||^2."""
    from repro.core import reconstruct_dense
    from repro.utils import flatten_with_paths
    from repro.core.pack import PackedDelta
    rng = jax.random.PRNGKey(5)
    fb = flatten_with_paths(base)
    ff = flatten_with_paths(ft)
    fd = flatten_with_paths(deltas, is_leaf=lambda x: isinstance(x, PackedDelta))
    errs = []
    for k, d in fd.items():
        if d is None or not isinstance(d, PackedDelta):
            continue
        wb = fb[k].astype(jnp.float32).reshape(-1, d.h_in, d.h_out)
        wf = ff[k].astype(jnp.float32).reshape(-1, d.h_in, d.h_out)
        dense = reconstruct_dense(d).reshape(-1, d.h_in, d.h_out)
        x = jax.random.normal(jax.random.fold_in(rng, hash(k) & 0xFFFF), (n_tokens, d.h_in))
        for i in range(wb.shape[0]):
            errs.append(float(jnp.mean((x @ wf[i] - x @ (wb[i] + dense[i])) ** 2)))
    return float(np.mean(errs))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
