"""Llama-3.2-11B-Vision — text backbone with gated cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
A gated cross-attention block is inserted after every 5th self-attn layer
(8 cross blocks). The vision tower is a STUB: ``input_specs()`` supplies
precomputed patch embeddings (batch, 1600, d_model).
Full self-attention backbone -> long_500k skipped.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14_336,
    vocab=128_256,
    act="silu",
    rope_theta=500_000.0,
    tie_embeddings=False,
    frontend="vision",
    n_frontend_tokens=1600,
    cross_attn_every=5,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    act="silu",
    tie_embeddings=False,
    frontend="vision",
    n_frontend_tokens=16,
    cross_attn_every=2,
)

register(FULL, SMOKE)
