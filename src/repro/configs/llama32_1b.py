"""Llama-3.2-1B — small dense llama3.

[hf:meta-llama/Llama-3.2-1B; unverified]
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
Pure full attention -> long_500k skipped.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv=8,
    head_dim=64,
    d_ff=8192,
    vocab=128_256,
    act="silu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="llama3.2-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    act="silu",
    tie_embeddings=True,
)

register(FULL, SMOKE)
