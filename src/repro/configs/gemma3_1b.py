"""Gemma3-1B — 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256.
Pattern: 5 sliding-window (512) layers then 1 global, repeating.
Mostly-local attention -> long_500k runs (global-layer KV sequence-sharded).
"""
from repro.configs.arch import ArchConfig, register

_N = 26
_WINDOWS = tuple(0 if (i % 6 == 5) else 512 for i in range(_N))

FULL = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=_N,
    d_model=1152,
    n_heads=4,
    n_kv=1,
    head_dim=256,
    d_ff=6912,
    vocab=262_144,
    act="gelu",
    rope_theta=1_000_000.0,
    qk_norm=True,
    logit_softcap=30.0,
    tie_embeddings=True,
    layer_windows=_WINDOWS,
    subquadratic=True,
)

_SN = 6
SMOKE = ArchConfig(
    name="gemma3-1b-smoke",
    family="dense",
    n_layers=_SN,
    d_model=64,
    n_heads=2,
    n_kv=1,
    head_dim=32,
    d_ff=128,
    vocab=512,
    act="gelu",
    qk_norm=True,
    logit_softcap=30.0,
    tie_embeddings=True,
    layer_windows=tuple(0 if (i % 6 == 5) else 8 for i in range(_SN)),
    subquadratic=True,
)

register(FULL, SMOKE)
