"""Gemma-7B — GeGLU, head_dim=256.

[arXiv:2403.08295; hf]
28L d_model=3072 16H (GQA kv=16, i.e. MHA) d_ff=24576 vocab=256000.
Pure full attention -> long_500k skipped.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv=16,
    head_dim=256,
    d_ff=24_576,
    vocab=256_000,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="gemma-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    act="gelu",
    tie_embeddings=True,
)

register(FULL, SMOKE)
