"""SeamlessM4T-medium — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]
12L (enc) + 12L (dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.

The audio frontend (w2v-BERT conformer feature extractor) is a STUB per the
task spec: ``input_specs()`` supplies precomputed frame embeddings of shape
(batch, frames, d_model); the transformer backbone (encoder, decoder with
cross-attention) is real. Full attention enc-dec -> long_500k skipped.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    head_dim=64,
    d_ff=4096,
    vocab=256_206,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    frontend="audio",
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    act="gelu",
    tie_embeddings=True,
    frontend="audio",
)

register(FULL, SMOKE)
