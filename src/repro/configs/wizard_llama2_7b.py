"""WizardMath/WizardLM-7B class — the paper's own evaluation target.

Llama-2-7B geometry [arXiv:2308.09583]: 32L d_model=4096 32H (MHA) d_ff=11008
vocab=32000. Used by the paper-fidelity benchmarks (Tables 1-4) and by the
end-to-end SFT -> delta -> DeltaDQ examples.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="wizard-llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    head_dim=128,
    d_ff=11_008,
    vocab=32_000,
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="wizard-llama2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=16,
    d_ff=192,
    vocab=512,
    act="silu",
    tie_embeddings=False,
)

register(FULL, SMOKE)
