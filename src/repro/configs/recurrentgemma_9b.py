"""RecurrentGemma-9B — RG-LRU + local attention, 2 recurrent : 1 attention.

[arXiv:2402.19427; unverified]
38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000, head_dim=256.
Pattern: (rec, rec, attn) repeating; attn layers use a 2048 sliding window.
Recurrent state decode + windowed attention -> long_500k runs.
"""
from repro.configs.arch import ArchConfig, RglruCfg, register

_N = 38
_KINDS = tuple("attn" if i % 3 == 2 else "rec" for i in range(_N))
_WINDOWS = tuple(2048 if k == "attn" else 0 for k in _KINDS)

FULL = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=_N,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12_288,
    vocab=256_000,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    layer_kinds=_KINDS,
    layer_windows=_WINDOWS,
    rglru=RglruCfg(lru_width=4096, conv_width=4, window=2048),
    subquadratic=True,
)

_SN = 6
_SKINDS = tuple("attn" if i % 3 == 2 else "rec" for i in range(_SN))
SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=_SN,
    d_model=64,
    n_heads=2,
    n_kv=1,
    head_dim=32,
    d_ff=128,
    vocab=512,
    act="gelu",
    tie_embeddings=True,
    layer_kinds=_SKINDS,
    layer_windows=tuple(8 if k == "attn" else 0 for k in _SKINDS),
    rglru=RglruCfg(lru_width=64, conv_width=4, window=8),
    subquadratic=True,
)

register(FULL, SMOKE)
