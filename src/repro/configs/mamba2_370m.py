"""Mamba2-370m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]
48L d_model=1024 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
SSM decode is O(1)/token -> long_500k runs.
"""
from repro.configs.arch import ArchConfig, SsmCfg, register

FULL = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,          # SSD heads: d_inner(2048) / head_dim(64)
    n_kv=32,
    head_dim=64,
    d_ff=0,
    vocab=50_280,
    tie_embeddings=True,
    ssm=SsmCfg(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,           # d_inner 128 / head_dim 32
    n_kv=4,
    head_dim=32,
    d_ff=0,
    vocab=256,
    tie_embeddings=True,
    ssm=SsmCfg(d_state=16, head_dim=32, expand=2, conv_width=4, chunk=16),
    subquadratic=True,
)

register(FULL, SMOKE)
