"""Qwen3-30B-A3B — MoE, 128 experts top-8, QK-norm.

[hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936, 128e top-8.
Pure full attention -> long_500k skipped (DESIGN.md §Arch-applicability).
"""
from repro.configs.arch import ArchConfig, MoeCfg, register

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=768,
    vocab=151_936,
    act="silu",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=False,
    moe=MoeCfg(n_experts=128, top_k=8, d_expert=768),
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=32,
    vocab=512,
    act="silu",
    qk_norm=True,
    tie_embeddings=False,
    moe=MoeCfg(n_experts=8, top_k=2, d_expert=32),
)

register(FULL, SMOKE)
