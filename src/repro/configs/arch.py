"""Architecture configuration system.

Every supported model is described by one frozen :class:`ArchConfig`.
The model zoo (``repro.models``) consumes these configs; there is one
``src/repro/configs/<id>.py`` per assigned architecture plus the paper's
own Llama-2-class targets, and each config file also exposes a
``smoke()`` reduced config of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoeCfg:
    n_experts: int
    top_k: int
    d_expert: int           # per-expert FFN hidden dim
    shared_expert: bool = False  # llama4-style always-on shared expert
    router_jitter: float = 0.0
    capacity_factor: float = 1.25  # per-expert buffer slack; tokens beyond it drop


@dataclass(frozen=True)
class SsmCfg:
    """Mamba-2 SSD settings."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class RglruCfg:
    """RecurrentGemma RG-LRU settings."""
    lru_width: int = 0      # 0 -> d_model
    conv_width: int = 4
    window: int = 2048      # local-attention window of the attn layers


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str             # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int

    act: str = "silu"       # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # Per-layer attention pattern. ``layer_windows[i] == 0`` means full/global
    # attention at layer i; ``w > 0`` means sliding-window (local) attention
    # of width w. ``layer_kinds[i]`` in {"attn", "moe", "ssm", "rec"}.
    layer_kinds: tuple = ()
    layer_windows: tuple = ()

    moe: Optional[MoeCfg] = None
    ssm: Optional[SsmCfg] = None
    rglru: Optional[RglruCfg] = None

    # --- encoder-decoder (seamless-m4t) ---
    n_enc_layers: int = 0

    # --- multimodal stub frontend ---
    frontend: Optional[str] = None   # "audio" | "vision"
    n_frontend_tokens: int = 0       # precomputed embedding tokens per example
    cross_attn_every: int = 0        # vlm: gated cross-attn block after every k-th layer

    # long-context capability: archs without a sub-quadratic path skip long_500k
    subquadratic: bool = False
    # chunked-attention chunk size for iRoPE-style long context (llama4)
    attn_chunk: int = 0

    param_dtype: str = "bfloat16"

    def __post_init__(self):
        if not self.layer_kinds:
            kind = {"moe": "moe", "ssm": "ssm"}.get(self.family, "attn")
            object.__setattr__(self, "layer_kinds", tuple([kind] * self.n_layers))
        if not self.layer_windows:
            object.__setattr__(self, "layer_windows", tuple([0] * self.n_layers))
        if len(self.layer_kinds) != self.n_layers:
            raise ValueError(
                f"arch {self.name!r}: {len(self.layer_kinds)} layer_kinds "
                f"for n_layers={self.n_layers}")
        if len(self.layer_windows) != self.n_layers:
            raise ValueError(
                f"arch {self.name!r}: {len(self.layer_windows)} "
                f"layer_windows for n_layers={self.n_layers}")

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    def n_params(self) -> int:
        """Analytic parameter count (matches param_specs; used for roofline)."""
        from repro.models.lm import param_specs
        from repro.utils import tree_params
        return tree_params(param_specs(self))

    def n_active_params(self, seq_len: int = 1) -> int:
        """Active params per token (MoE: only routed experts count)."""
        total = self.n_params()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        n_moe = sum(1 for k in self.layer_kinds if k == "moe")
        inactive = (m.n_experts - m.top_k) * per_expert * n_moe
        return total - inactive

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, "ArchConfig"] = {}
_SMOKE: dict[str, "ArchConfig"] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every config module for its register() side effect
    from repro.configs import (  # noqa: F401
        gemma3_1b,
        gemma_7b,
        llama32_1b,
        llama32_vision_11b,
        llama4_scout_17b_a16e,
        mamba2_370m,
        phi3_medium_14b,
        qwen3_moe_30b_a3b,
        recurrentgemma_9b,
        seamless_m4t_medium,
        wizard_llama2_7b,
    )
