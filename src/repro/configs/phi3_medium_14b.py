"""Phi-3-medium-14B — dense, RoPE SwiGLU GQA.

[arXiv:2404.14219; unverified]
40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
Pure full attention -> long_500k skipped.
"""
from repro.configs.arch import ArchConfig, register

FULL = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=10,
    head_dim=128,
    d_ff=17_920,
    vocab=100_352,
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="phi3-medium-14b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=192,
    vocab=512,
    act="silu",
    tie_embeddings=False,
)

register(FULL, SMOKE)
