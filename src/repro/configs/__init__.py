from repro.configs.arch import (
    ArchConfig,
    MoeCfg,
    RglruCfg,
    SsmCfg,
    get_config,
    get_smoke_config,
    list_archs,
    register,
)

__all__ = [
    "ArchConfig",
    "MoeCfg",
    "RglruCfg",
    "SsmCfg",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "register",
]
