"""Llama-4-Scout-17B-16E — MoE, 16 experts top-1 + shared expert, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.

Long context: Llama-4 uses iRoPE chunked attention; we model it as
chunked-local attention (8192-token chunks) which is sub-quadratic, so the
long_500k cell runs for this arch (DESIGN.md §Arch-applicability).
"""
from repro.configs.arch import ArchConfig, MoeCfg, register

FULL = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    act="silu",
    rope_theta=500_000.0,
    tie_embeddings=False,
    moe=MoeCfg(n_experts=16, top_k=1, d_expert=8192, shared_expert=True),
    subquadratic=True,
    attn_chunk=8192,
)

SMOKE = ArchConfig(
    name="llama4-scout-17b-a16e-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    act="silu",
    tie_embeddings=False,
    moe=MoeCfg(n_experts=4, top_k=1, d_expert=128, shared_expert=True),
    subquadratic=True,
    attn_chunk=32,
)

register(FULL, SMOKE)
