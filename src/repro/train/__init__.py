from repro.train.train_step import make_eval_step, make_loss, make_train_step

__all__ = ["make_eval_step", "make_loss", "make_train_step"]
