"""Training step: loss -> grads -> AdamW, with gradient accumulation.

``make_train_step(cfg, opt_cfg, n_micro)`` returns a pure function
    (params, opt_state, batch, rng) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with in/out shardings from ``repro.dist``.

Microbatching: the global batch [B, S] is split into ``n_micro`` chunks and
scanned, accumulating fp32 grads. Besides memory, this is the main
compute/communication overlap lever at scale: XLA's latency-hiding
scheduler overlaps each microbatch's backward with the previous gradient
all-reduce chunk (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


def make_loss(cfg: ArchConfig, remat: bool = True):
    def loss(params, batch):
        return lm.loss_fn(cfg, params, batch, remat=remat)
    return loss


def _split_micro(batch: dict, n_micro: int) -> dict:
    def sp(x):
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(
                f"batch size {b} must be a multiple of n_micro={n_micro}")
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, n_micro: int = 1,
                    remat: bool = True,
                    grad_transform: Optional[Callable] = None):
    """grad_transform: optional fn(grads) -> grads applied before the
    optimizer (e.g. the int8 compressed all-reduce in repro.dist)."""
    loss_fn = make_loss(cfg, remat=remat)

    def step(params, opt_state, batch, rng):
        del rng  # data pipeline is deterministic; kept for API stability

        def one_micro(carry, mb):
            acc, _ = carry
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (grads, l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if n_micro == 1:
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = _split_micro(batch, n_micro)
            (grads, l), _ = jax.lax.scan(one_micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        if grad_transform is not None:
            grads = grad_transform(grads)

        params, opt_state, opt_metrics = adamw.update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": l, **opt_metrics}
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ArchConfig):
    def step(params, batch, deltas=None):
        loss, metrics = lm.loss_fn(cfg, params, batch, deltas=deltas)
        return metrics
    return step
