"""Uniform quantization + the paper's Separate Quantization (§3.4).

Quantizer (paper Eqs. 6-8, per-tensor granularity):

    q = clip(round(dW / s) + z, 0, 2^k - 1)
    s = (max(dW) - min(dW)) / (2^k - 1)
    z = round(-min(dW) / s)

Separate Quantization (Eqs. 9-11) then partitions the k-bit codes into m
parts by value range; part j stores codes offset by o_j = -(2^k/m)(j-1) so
each part needs only k - log2(m) storage bits. Parts have disjoint support,
so the decomposition is exactly invertible: it changes *storage bits*, not
code resolution. Accuracy therefore depends on k alone; the compression
ratio becomes alpha * 16 / (k - log2 m)  (paper's value-bits convention).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class QuantParams(NamedTuple):
    scale: jnp.ndarray   # f32 scalar
    zero: jnp.ndarray    # int32 scalar
    k_bits: int


def quantize(x: jnp.ndarray, k_bits: int, lead_dims: int = 0) -> tuple[jnp.ndarray, QuantParams]:
    """Per-tensor uniform quantization to k-bit codes (int32 in [0, 2^k)).

    ``lead_dims`` > 0 treats the leading dims as a stack of independent
    tensors (per-layer / per-expert scales), matching the paper's per-tensor
    granularity applied to each weight matrix.
    """
    if not 1 <= k_bits <= 8:
        raise ValueError(f"k_bits={k_bits} must be in [1, 8]")
    red = tuple(range(lead_dims, x.ndim))
    lo = jnp.min(x, axis=red, keepdims=True).astype(jnp.float32)
    hi = jnp.max(x, axis=red, keepdims=True).astype(jnp.float32)
    span = jnp.maximum(hi - lo, 1e-12)
    s = span / (2**k_bits - 1)
    z = jnp.round(-lo / s).astype(jnp.int32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s).astype(jnp.int32) + z, 0, 2**k_bits - 1)
    s = s.reshape(x.shape[:lead_dims])
    z = z.reshape(x.shape[:lead_dims])
    return q, QuantParams(scale=s, zero=z, k_bits=k_bits)


def dequantize(q: jnp.ndarray, qp: QuantParams) -> jnp.ndarray:
    """Combined-code dequantization: s * (q - z)."""
    return (q.astype(jnp.float32) - qp.zero.astype(jnp.float32)) * qp.scale


# ---------------------------------------------------------------------------
# Separate Quantization: m-part decomposition of the code space
# ---------------------------------------------------------------------------
def part_id(q: jnp.ndarray, k_bits: int, m: int) -> jnp.ndarray:
    """Which of the m value-range parts each code belongs to (Eq. 10)."""
    if m < 1 or (m & (m - 1)) != 0:
        raise ValueError(f"m={m} must be a power of two >= 1")
    if m > 2**k_bits:
        raise ValueError(f"m={m} exceeds the code space of k_bits={k_bits} "
                         f"({2**k_bits} codes)")
    width = (2**k_bits) // m
    return q // width


def decompose(q: jnp.ndarray, k_bits: int, m: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split combined codes into (part_id, low_code).

    ``low_code`` is the (k - log2 m)-bit stored code of Eq. 9 after applying
    the offset o_j; ``part_id`` is implicit in CSR storage (which part's list
    an element appears in) and is materialized here for fixed-shape layouts.
    """
    pid = part_id(q, k_bits, m)
    width = (2**k_bits) // m
    low = q - pid * width
    return pid, low


def recompose(pid: jnp.ndarray, low: jnp.ndarray, k_bits: int, m: int) -> jnp.ndarray:
    """Inverse of :func:`decompose` (Eq. 12 summed over disjoint parts)."""
    width = (2**k_bits) // m
    return pid * width + low


def storage_bits_per_value(k_bits: int, m: int) -> float:
    """Stored bits per surviving value under Separate Quantization."""
    return k_bits - math.log2(m)


def compression_ratio(alpha: float, k_bits: int | None, m: int = 1) -> float:
    """Paper's ratio convention: alpha * 16/(k - log2 m); bf16 reference."""
    if k_bits is None:
        return float(alpha)
    bits = storage_bits_per_value(k_bits, m)
    if bits <= 0:
        # paper's "-" rows: every part holds identical values; one scalar each
        return float("inf")
    return alpha * 16.0 / bits


# ---------------------------------------------------------------------------
# Bit packing (k in {1,2,4,8} codes per uint8 byte, packed along one axis)
# ---------------------------------------------------------------------------
def pack_width(k_bits: int) -> int:
    """Physical bit width used to pack k-bit codes (next of 1/2/4/8).

    Odd widths (k=3,5,6,7 — they arise from final_bits + log2 m sweeps)
    are stored at the next supported width; the *accounted* storage bits
    stay k (the paper's CSR lists are not byte-aligned either way)."""
    for w in (1, 2, 4, 8):
        if k_bits <= w:
            return w
    raise ValueError(k_bits)


def packed_len(n: int, k_bits: int) -> int:
    per = 8 // pack_width(k_bits)
    return (n + per - 1) // per


def pack_bits(q: jnp.ndarray, k_bits: int, axis: int = 0) -> jnp.ndarray:
    """Pack k-bit codes into uint8 along ``axis`` (pads with zeros)."""
    if k_bits not in (1, 2, 4, 8):
        raise ValueError(f"k_bits={k_bits} must be one of (1, 2, 4, 8) "
                         "to pack into whole uint8 lanes")
    per = 8 // k_bits
    q = jnp.moveaxis(q, axis, 0).astype(jnp.uint8)
    n = q.shape[0]
    pad = (-n) % per
    if pad:
        q = jnp.concatenate([q, jnp.zeros((pad, *q.shape[1:]), jnp.uint8)], axis=0)
    q = q.reshape(q.shape[0] // per, per, *q.shape[1:])
    shifts = (jnp.arange(per, dtype=jnp.uint8) * k_bits).reshape(1, per, *([1] * (q.ndim - 2)))
    packed = (jnp.bitwise_or.reduce(q << shifts, axis=1)
              if hasattr(jnp.bitwise_or, "reduce") else None)
    if packed is None:  # jnp ufuncs lack .reduce in some versions
        packed = jnp.zeros((q.shape[0], *q.shape[2:]), jnp.uint8)
        for i in range(per):
            packed = packed | (q[:, i] << jnp.uint8(i * k_bits))
    return jnp.moveaxis(packed, 0, axis)


def unpack_bits(packed: jnp.ndarray, k_bits: int, n: int, axis: int = 0) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`; returns int32 codes, trimmed to n."""
    if k_bits not in (1, 2, 4, 8):
        raise ValueError(f"k_bits={k_bits} must be one of (1, 2, 4, 8) "
                         "to unpack from whole uint8 lanes")
    per = 8 // k_bits
    p = jnp.moveaxis(packed, axis, 0)
    mask = jnp.uint8(2**k_bits - 1)
    cols = [( (p >> jnp.uint8(i * k_bits)) & mask ) for i in range(per)]
    q = jnp.stack(cols, axis=1).reshape(p.shape[0] * per, *p.shape[1:])
    q = q[:n].astype(jnp.int32)
    return jnp.moveaxis(q, 0, axis)


# ---------------------------------------------------------------------------
# numpy twins (storage layer; never traced)
# ---------------------------------------------------------------------------
def np_quantize(x: np.ndarray, k_bits: int):
    lo, hi = float(x.min()), float(x.max())
    s = max(hi - lo, 1e-12) / (2**k_bits - 1)
    z = int(round(-lo / s))
    q = np.clip(np.round(x / s).astype(np.int64) + z, 0, 2**k_bits - 1).astype(np.int32)
    return q, s, z
