"""Packed delta-weight formats.

Two layouts (DESIGN.md §3 — hardware adaptation):

* :class:`PackedDelta` — the **runtime** layout. Group-wise dropout with an
  exact per-group keep count yields *structured* sparsity: every
  (group, output-column) stores a fixed-shape ``[keep]`` vector of local
  indices (log2 h_g bits) and k-bit codes (bit-packed). Dense, tileable,
  TPU-friendly; this is what kernels and the XLA fallback consume.

* :func:`to_storage_parts` — the **paper-faithful storage** layout for
  Separate Quantization: m per-part ragged lists (CSR-style) whose codes
  need only k - log2(m) bits because the part id is positional. Used for
  checkpointing compressed deltas and for the Fig. 7 memory accounting.

Weights are stored as ``w[h_in, h_out]`` (y = x @ w); the paper's rows
(h_out) are our columns, and dropout groups run along h_in — the matrix-
computation (contraction) dimension, as in the paper.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant


@jax.tree_util.register_pytree_node_class
@dataclass
class PackedDelta:
    """Structured-sparse, quantized delta for one [h_in, h_out] weight.

    Array fields may carry extra *leading* stack dims (layers, experts).
      idx:   local in-group indices, int dtype,   [..., G, K, O]
      codes: bit-packed k-bit codes, uint8,       [..., G, Kp, O]   (Kp = packed_len(K,k))
             or float values                      [..., G, K, O]    when k_bits is None
      scale, zero: per-tensor quant params (scalars; stacked if leading dims)
    Static meta: h_in, h_out, h_g, keep, alpha, k_bits, m, codec.

    ``codec`` names the :mod:`repro.core.codecs` entry that produced this
    runtime form ("deltadq" natively; other codecs lower to PackedDelta at
    tenant registration). It rides in the pytree aux so mixed-codec trees
    never stack silently and attribution can report the decode source.
    """
    idx: jnp.ndarray
    codes: jnp.ndarray
    scale: Any
    zero: Any
    h_in: int
    h_out: int
    h_g: int
    keep: int
    alpha: float
    k_bits: int | None
    m: int
    codec: str = "deltadq"

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (self.idx, self.codes, self.scale, self.zero)
        aux = (self.h_in, self.h_out, self.h_g, self.keep, self.alpha,
               self.k_bits, self.m, self.codec)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- derived -----------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return self.h_in // self.h_g

    @property
    def nnz(self) -> int:
        return self.n_groups * self.keep * self.h_out

    def stack_shape(self) -> tuple[int, ...]:
        return tuple(self.idx.shape[:-3])

    def index(self, i) -> "PackedDelta":
        """Slice one element off the leading stack dim (for layer loops)."""
        return PackedDelta(self.idx[i], self.codes[i],
                           self.scale[i] if jnp.ndim(self.scale) else self.scale,
                           self.zero[i] if jnp.ndim(self.zero) else self.zero,
                           self.h_in, self.h_out, self.h_g, self.keep,
                           self.alpha, self.k_bits, self.m, self.codec)

    # -- storage accounting (bits; paper conventions in quant.py) ----------
    def value_bits(self) -> float:
        if self.k_bits is None:
            return 16.0 * self.nnz
        return quant.storage_bits_per_value(self.k_bits, self.m) * self.nnz

    def index_bits(self) -> float:
        return math.log2(max(self.h_g, 2)) * self.nnz

    def total_bits(self, include_indices: bool = True) -> float:
        """Storage bits for the whole (possibly stacked) delta."""
        stack = int(np.prod(self.stack_shape())) if self.stack_shape() else 1
        per_matrix = self.value_bits() + (self.index_bits() if include_indices else 0.0)
        return per_matrix * stack


def decode_values(d: PackedDelta) -> jnp.ndarray:
    """Return dequantized kept values, f32 [..., G, K, O]."""
    if d.k_bits is None:
        return d.codes.astype(jnp.float32)
    q = quant.unpack_bits(d.codes, quant.pack_width(d.k_bits), d.keep,
                          axis=d.codes.ndim - 2)
    z = jnp.asarray(d.zero, jnp.float32)
    s = jnp.asarray(d.scale, jnp.float32)
    if jnp.ndim(z):  # stacked scalars -> broadcast over trailing (G,K,O)
        z = z.reshape(z.shape + (1, 1, 1))
        s = s.reshape(s.shape + (1, 1, 1))
    return (q.astype(jnp.float32) - z) * s


def reconstruct_dense(d: PackedDelta, dtype=jnp.float32) -> jnp.ndarray:
    """Scatter the packed delta back to a dense [..., h_in, h_out] matrix.

    This is the XLA-fallback analogue of the Pallas kernel's in-VMEM
    scatter; on TPU hot paths the kernel does this per-tile in VMEM instead.
    """
    vals = decode_values(d) * jnp.float32(1.0)  # alpha already folded at pack time
    idx = d.idx.astype(jnp.int32)
    lead = vals.shape[:-3]
    G, K, O = vals.shape[-3:]
    vals = vals.reshape((-1, G, K, O))
    idx = idx.reshape((-1, G, K, O))

    def one(v, ix):
        dense = jnp.zeros((G, d.h_g, O), jnp.float32)
        gi = jnp.arange(G)[:, None, None]
        oi = jnp.arange(O)[None, None, :]
        dense = dense.at[gi, ix, oi].add(v)
        return dense.reshape(d.h_in, d.h_out)

    out = jax.vmap(one)(vals, idx)
    out = out.reshape(lead + (d.h_in, d.h_out)) if lead else out[0]
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Paper-faithful m-part CSR storage (numpy, offline)
# ---------------------------------------------------------------------------
@dataclass
class StoragePart:
    """One of the m Separate-Quantization parts: a group-CSR sparse matrix."""
    part: int                 # 1..m
    group_offsets: np.ndarray  # int64 [G*O + 1] prefix sums of per-(g,o) counts
    local_idx: np.ndarray      # per-element local index within group
    low_codes: np.ndarray      # (k - log2 m)-bit stored codes (uint8)

    def storage_bits(self, k_bits: int, m: int, h_g: int) -> float:
        vb = quant.storage_bits_per_value(k_bits, m) * len(self.low_codes)
        ib = math.log2(max(h_g, 2)) * len(self.local_idx)
        ob = 64.0 * len(self.group_offsets)
        return vb + ib + ob


def to_storage_parts(d: PackedDelta) -> list[StoragePart]:
    """Decompose a (non-stacked) PackedDelta into m paper-faithful parts."""
    if d.k_bits is None:
        raise ValueError(
            "separate quantization requires quantized codes; this "
            "PackedDelta has k_bits=None (raw float values)")
    if d.stack_shape():
        raise ValueError(
            "storage layer operates per-matrix; got stacked delta with "
            f"stack_shape={d.stack_shape()}")
    q = np.asarray(quant.unpack_bits(d.codes, quant.pack_width(d.k_bits), d.keep,
                                     axis=d.codes.ndim - 2))
    idx = np.asarray(d.idx)
    G, K, O = q.shape
    width = (2**d.k_bits) // d.m
    pid = q // width
    low = (q - pid * width).astype(np.uint8)
    # order elements by (g, o) then k so group offsets are well defined
    pidf = pid.transpose(0, 2, 1).reshape(G * O, K)
    lowf = low.transpose(0, 2, 1).reshape(G * O, K)
    idxf = idx.transpose(0, 2, 1).reshape(G * O, K)
    parts = []
    for j in range(d.m):
        sel = pidf == j
        counts = sel.sum(axis=1)
        offs = np.zeros(G * O + 1, np.int64)
        np.cumsum(counts, out=offs[1:])
        parts.append(StoragePart(
            part=j + 1,
            group_offsets=offs,
            local_idx=idxf[sel].astype(np.uint16),
            low_codes=lowf[sel],
        ))
    return parts


def from_storage_parts(parts: list[StoragePart], *, h_in: int, h_out: int, h_g: int,
                       keep: int, alpha: float, k_bits: int, scale, zero) -> PackedDelta:
    """Reassemble the runtime layout from m storage parts (load path)."""
    m = len(parts)
    G = h_in // h_g
    width = (2**k_bits) // m
    q = np.zeros((G * h_out, keep), np.int32)
    ix = np.zeros((G * h_out, keep), np.int32)
    fill = np.zeros(G * h_out, np.int64)  # next free slot per (group, col) row
    for j, p in enumerate(parts):
        counts = np.diff(p.group_offsets)
        rows = np.repeat(np.arange(G * h_out), counts)
        within = np.arange(len(rows)) - np.repeat(p.group_offsets[:-1], counts)
        slot = fill[rows] + within
        q[rows, slot] = p.low_codes.astype(np.int32) + j * width
        ix[rows, slot] = p.local_idx
        fill += counts
    q = q.reshape(G, h_out, keep).transpose(0, 2, 1)
    ix = ix.reshape(G, h_out, keep).transpose(0, 2, 1)
    codes = quant.pack_bits(jnp.asarray(q), quant.pack_width(k_bits), axis=1)
    idx_dtype = jnp.uint8 if h_g <= 256 else jnp.int32
    return PackedDelta(
        idx=jnp.asarray(ix, idx_dtype), codes=codes,
        scale=jnp.float32(scale), zero=jnp.int32(zero),
        h_in=h_in, h_out=h_out, h_g=h_g, keep=keep,
        alpha=alpha, k_bits=k_bits, m=m,
    )
