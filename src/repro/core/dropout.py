"""Group-wise Dropout (paper §3.3), exact-count structured variant.

The paper draws a Bernoulli mask per group (keep-rate 1/alpha in
expectation) and rescales survivors by alpha. We keep **exactly**
``h_g / alpha`` uniformly-random elements per group — same estimator, but
the fixed per-group count makes the result *structured* sparsity with a
dense packed layout (DESIGN.md §3). ``tests/test_core_dropout.py`` checks
the layer-wise l2 error matches the Bernoulli variant statistically.

Groups run along the contraction dim (h_in), within each output column —
this is the paper's "row dimension" in its [h_out, h_in] convention and is
what makes the Balanced-Intermediate-Results argument apply: each survivor
stands in for h_g/keep near-identical intermediate products.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.pack import PackedDelta


def keep_count(h_g: int, alpha: float) -> int:
    """Kept elements per (group, column): the ONE definition.

    Every consumer — real packing (:func:`groupwise_dropout_pack` via
    ``_check``) and the shape-only dry-run twins
    (``core.compress.delta_leaf_spec``) — derives ``keep`` here, so a
    dry-run spec can never drift from what packing actually produces.
    """
    keep = int(round(h_g / alpha))
    if keep < 1:
        raise ValueError(f"alpha={alpha} too large for h_g={h_g}")
    return keep


def _check(h_in: int, h_g: int, alpha: float):
    if h_in % h_g:
        raise ValueError(f"h_g={h_g} must divide h_in={h_in}")
    return keep_count(h_g, alpha)


def groupwise_dropout_mask(rng, h_in: int, h_out: int, h_g: int, alpha: float) -> jnp.ndarray:
    """Bernoulli-free exact mask [h_in, h_out]; True = kept. (Reference.)"""
    keep = _check(h_in, h_g, alpha)
    G = h_in // h_g
    u = jax.random.uniform(rng, (G, h_g, h_out))
    ranks = jnp.argsort(jnp.argsort(u, axis=1), axis=1)
    return (ranks < keep).reshape(h_in, h_out)


def groupwise_dropout_pack(
    rng,
    delta: jnp.ndarray,
    *,
    h_g: int,
    alpha: float,
    k_bits: int | None = None,
    m: int = 1,
) -> PackedDelta:
    """Compress one [h_in, h_out] delta: dropout -> rescale -> quantize -> pack.

    The alpha rescale is folded into the stored values (equivalently into the
    quantization scale), so reconstruction needs no extra multiply.
    """
    h_in, h_out = delta.shape[-2:]
    keep = _check(h_in, h_g, alpha)
    G = h_in // h_g
    grouped = delta.reshape(*delta.shape[:-2], G, h_g, h_out).astype(jnp.float32)

    u = jax.random.uniform(rng, grouped.shape)
    # exact-count uniform subset per (group, column): take the `keep`
    # positions with the smallest random keys, then sort indices so the
    # packed layout is ordered (helps the kernel's sequential scatter).
    sel = jnp.argsort(u, axis=-2)[..., :keep, :]
    sel = jnp.sort(sel, axis=-2)
    vals = jnp.take_along_axis(grouped, sel, axis=-2) * jnp.float32(alpha)

    if k_bits is None:
        codes = vals
        scale = jnp.float32(1.0)
        zero = jnp.int32(0)
    else:
        # per-matrix scales: leading stack dims (layers/experts) quantize
        # independently, matching the paper's per-tensor granularity
        q, qp = quant.quantize(vals, k_bits, lead_dims=vals.ndim - 3)
        codes = quant.pack_bits(q, quant.pack_width(k_bits), axis=q.ndim - 2)
        scale, zero = qp.scale, qp.zero

    idx_dtype = jnp.uint8 if h_g <= 256 else jnp.int32
    return PackedDelta(
        idx=sel.astype(idx_dtype), codes=codes, scale=scale, zero=zero,
        h_in=h_in, h_out=h_out, h_g=h_g, keep=keep,
        alpha=float(alpha), k_bits=k_bits, m=m,
    )


def rowwise_dropout_pack(rng, delta: jnp.ndarray, *, alpha: float,
                         k_bits: int | None = None, m: int = 1) -> PackedDelta:
    """Paper's Row-wise Dropout = group size h_g == h_in (one group per row)."""
    return groupwise_dropout_pack(rng, delta, h_g=delta.shape[-2], alpha=alpha,
                                  k_bits=k_bits, m=m)


def bernoulli_dropout_dense(rng, delta: jnp.ndarray, *, alpha: float) -> jnp.ndarray:
    """Paper's original (expected-count) formulation, dense output. Used to
    validate that the exact-count variant is statistically equivalent."""
    keep_rate = 1.0 / alpha
    mask = jax.random.bernoulli(rng, keep_rate, delta.shape)
    return jnp.where(mask, delta * alpha, 0.0)
