"""Baseline delta-compression methods the paper compares against (§4.1).

* ``magnitude`` — Han et al. 2015: keep the top-|w| fraction 1/alpha of the
  delta, globally per tensor, no rescale.
* ``dare`` — Yu et al. 2023: global Bernoulli dropout at keep-rate 1/alpha
  with 1/keep-rate rescale (the paper's "random drop, whole tensor" point).
* ``deltazip`` — Yao & Klimovic 2023 (lite): per-row magnitude sparsification
  followed by 4-bit group-128 quantization. (Full DeltaZip uses SparseGPT's
  Hessian-weighted updates; we implement the magnitude variant and note the
  difference — it is the *stronger* baseline at low alpha per paper Table 1.)

All return a **dense** compressed delta (same shape as the input) plus a
bit count, so evaluation code can treat every method uniformly:
``W_hat = W_base + compressed_delta``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp



def magnitude(rng, delta: jnp.ndarray, *, alpha: float, **_) -> jnp.ndarray:
    n = delta.size
    keep = max(int(n / alpha), 1)
    flat = jnp.abs(delta.reshape(-1))
    thresh = jax.lax.top_k(flat, keep)[0][-1]
    return jnp.where(jnp.abs(delta) >= thresh, delta, 0.0)


def dare(rng, delta: jnp.ndarray, *, alpha: float, **_) -> jnp.ndarray:
    keep_rate = 1.0 / alpha
    mask = jax.random.bernoulli(rng, keep_rate, delta.shape)
    return jnp.where(mask, delta / keep_rate, 0.0)


def _group_quant(x: jnp.ndarray, k_bits: int, group: int = 128) -> jnp.ndarray:
    """Per-group (along h_in) uniform quant-dequant, GPTQ-style granularity."""
    h_in, h_out = x.shape[-2], x.shape[-1]
    g = max(min(group, h_in), 1)
    while h_in % g:
        g //= 2
    xg = x.reshape(*x.shape[:-2], h_in // g, g, h_out)
    lo = xg.min(axis=-2, keepdims=True)
    hi = xg.max(axis=-2, keepdims=True)
    s = jnp.maximum(hi - lo, 1e-12) / (2**k_bits - 1)
    q = jnp.clip(jnp.round((xg - lo) / s), 0, 2**k_bits - 1)
    return (q * s + lo).reshape(x.shape)


def _colwise_thresh(mag: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Per-output-column threshold keeping `keep` largest along h_in."""
    srt = jnp.sort(mag, axis=-2)  # ascending
    return jnp.take(srt, mag.shape[-2] - keep, axis=-2)[..., None, :]


def deltazip(rng, delta: jnp.ndarray, *, alpha: float, k_bits: int = 4, **_) -> jnp.ndarray:
    # Total budget alpha = alpha_sparse * (16 / k_bits): pick the sparsity so
    # that sparsification times 4-bit quantization hits the target ratio.
    alpha_sparse = max(alpha * k_bits / 16.0, 1.0)
    keep = max(int(round(delta.shape[-2] / alpha_sparse)), 1)
    if keep >= delta.shape[-2]:
        sparse = delta
    else:
        mag = jnp.abs(delta)
        sparse = jnp.where(mag >= _colwise_thresh(mag, keep), delta, 0.0)
    return jnp.where(sparse != 0, _group_quant(sparse, k_bits), 0.0)


METHODS: dict[str, Callable] = {
    "magnitude": magnitude,
    "dare": dare,
    "deltazip": deltazip,
}


def method_bits(name: str, delta_shape, *, alpha: float, k_bits: int = 4) -> float:
    """Stored value-bits under each method (paper convention, for reports)."""
    import numpy as np
    n = float(np.prod(delta_shape))
    if name in ("magnitude", "dare"):
        return 16.0 * n / alpha
    if name == "deltazip":
        alpha_sparse = max(alpha * k_bits / 16.0, 1.0)
        return k_bits * n / alpha_sparse
    raise KeyError(name)
