"""DeltaDQ core: the paper's contribution as composable JAX modules."""
from repro.core.apply import (
    MultiSlotDelta,
    SlotDelta,
    apply_linear,
    apply_linear_batched,
    combine_slot_deltas,
    delta_matmul,
    dget,
    dindex,
    merge_delta,
    none_like,
    set_use_pallas,
    slot_delta_matmul,
    stack_tenant_deltas,
    wrap_slot_deltas,
    zero_delta_like,
)
from repro.core.codecs import (
    BitDeltaCodec,
    BitDeltaLeaf,
    BitDeltaSpec,
    DeltaCodec,
    DeltaDQCodec,
    LowRankCodec,
    LowRankLeaf,
    LowRankSpec,
    codec_for_spec,
    codec_names,
    codec_of_leaf,
    get_codec,
    reconstruct_dense_any,
    register_codec,
    runtime_delta_tree,
)
from repro.core.compress import (
    CompressionReport,
    DeltaDQSpec,
    compress,
    compress_leaf,
    decompress,
    delta_axes,
    delta_specs,
    is_compressible,
)
from repro.core.dropout import (
    bernoulli_dropout_dense,
    groupwise_dropout_mask,
    groupwise_dropout_pack,
    rowwise_dropout_pack,
)
from repro.core.groupsearch import (
    SearchResult,
    attention_proxy_error,
    candidate_group_sizes,
    search_direct,
    search_proxy,
)
from repro.core.pack import (
    PackedDelta,
    StoragePart,
    decode_values,
    from_storage_parts,
    reconstruct_dense,
    to_storage_parts,
)
from repro.core.quant import (
    QuantParams,
    compression_ratio,
    dequantize,
    pack_bits,
    quantize,
    storage_bits_per_value,
    unpack_bits,
)

__all__ = [k for k in dir() if not k.startswith("_")]
