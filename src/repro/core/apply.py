"""Delta application — the paper's separate-computation scheme (§3.1, Fig. 3).

Every linear site in the model zoo routes through :func:`apply_linear`:

    y = x @ W_base            (+ x @ dequant(packed delta)   if delta given)

On TPU hot paths the correction term is the Pallas ``delta_spmm`` kernel
(scatter-to-dense in VMEM + MXU); under SPMD dry-runs and CPU tests the
mathematically identical XLA fallback below is used (config
``use_pallas_kernels``). Both share the pure-jnp oracle in
``repro/kernels/ref.py`` for tests.

Multi-tenant slot dispatch: the continuous-batching engine serves one
decode step whose batch rows belong to *different* tenants. For that it
stacks every tenant's :class:`PackedDelta` along a new leading axis
(:func:`stack_tenant_deltas`) and wraps each leaf in a :class:`SlotDelta`
carrying the per-row tenant index, so ``apply_linear`` gathers each row's
delta before applying the correction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.pack import PackedDelta, reconstruct_dense

# Global switch flipped by serving/launch configs. The Pallas path only
# lowers on real TPUs; everything else uses the XLA fallback.
_USE_PALLAS = False

# Mixed-tenant decode dispatch mode. "segments" (default) groups batch
# rows by tenant so each unique delta is dequantized once per step
# (requires the SlotDelta to carry a TenantSegments layout — built host-
# side by serve.scheduler.tenant_segments). "per_row" is the legacy
# path: gather a per-row delta stack and reconstruct/apply per row.
_SLOT_DISPATCH = "segments"

# Active serving mesh (set by mesh-mode engines/launchers). When a mesh
# with a >1 `model` axis is installed, every delta correction routes
# through the shard_map'd output-column-partitioned path in
# ``kernels.ops.delta_correction_sharded`` — each shard touches only its
# own slice of the compressed bytes. One mesh per process.
_MESH = None


def _note(site: str, **attrs) -> None:
    """Report the chosen dispatch to an open trace context (no-op
    otherwise). Lazy import: serve's __init__ imports the engine, which
    imports this module."""
    from repro.serve.trace import note_path
    note_path(site, **attrs)


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def get_use_pallas() -> bool:
    return _USE_PALLAS


def set_slot_dispatch(mode: str) -> None:
    """Select the mixed-tenant decode dispatch: "segments" | "per_row"."""
    if mode not in ("segments", "per_row"):
        raise ValueError(
            f"slot_dispatch mode {mode!r} not in ('segments', 'per_row')")
    global _SLOT_DISPATCH
    _SLOT_DISPATCH = mode


def get_slot_dispatch() -> str:
    return _SLOT_DISPATCH


def set_mesh(mesh) -> None:
    """Install (or clear, with None) the process-wide serving mesh."""
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def _sharded_correction(x: jnp.ndarray, d: PackedDelta):
    """Mesh-partitioned delta correction, or None if it doesn't apply."""
    if _MESH is None:
        return None
    from repro.kernels import ops
    return ops.delta_correction_sharded(x, d, _MESH, use_pallas=_USE_PALLAS)


@jax.custom_vjp
def _pinned(c: jnp.ndarray) -> jnp.ndarray:
    """optimization_barrier with an identity gradient.

    The barrier pins the correction's fusion boundary (bit-identity
    across mesh layouts, see apply_linear) but has no differentiation
    rule — a bare barrier would make every ``deltas=`` forward
    non-differentiable. The barrier is an identity function, so the
    straight-through VJP is exact.
    """
    return jax.lax.optimization_barrier(c)


def _pinned_fwd(c):
    return _pinned(c), None


def _pinned_bwd(_, g):
    return (g,)


_pinned.defvjp(_pinned_fwd, _pinned_bwd)


def _replicated(t: jnp.ndarray) -> jnp.ndarray:
    """Pin an activation replicated over the serving mesh.

    The serve layout is column-parallel only: weights shard their output
    axis, never the contraction axis, and activations are gathered back
    to replicated after every linear site. Every matmul then reduces
    over the full contraction locally — in the same order as a single
    device — which is what makes sharded decode bit-identical to the
    single-device engine (the CI token-identity check). At decode batch
    sizes the gathered activations are tiny; the multi-GB object (the
    base) stays sharded in HBM.
    """
    if _MESH is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(_MESH, PartitionSpec()))


@jax.tree_util.register_pytree_node_class
@dataclass
class TenantSegments:
    """Static-shape tenant-segment layout for a mixed decode batch.

    Built host-side (``serve.scheduler.tenant_segments``) from the
    per-slot tenant rows: batch rows are sorted (stably) by tenant so
    each unique tenant occupies one contiguous segment. All arrays have
    shapes that depend only on the slot count B, so the decode step
    still compiles exactly once:

      order       int32 [B]    row permutation (sorted by tenant row)
      inv_order   int32 [B]    inverse permutation (unsort the output)
      seg_rows    int32 [B]    tenant row per segment (padding rows 0)
      seg_offsets int32 [B+1]  half-open row ranges; empty segments have
                               equal offsets and are skipped at runtime

    A :class:`ShardedTenantSegments` flattened with
    ``global_order()``/``global_segments()`` is also a valid instance
    of this layout: rows sorted by tenant only within each contiguous
    shard pool, each pool contributing its own segment run (a tenant on
    two shards gets two segments). Nothing downstream changes —
    segments are consumed only as (tenant row, contiguous range) pairs,
    so the same envelope and the same jit signature serve data=1 and
    data=N — but because the permutation never crosses a pool boundary,
    the sorted batch partitions over the mesh ``data`` axis exactly
    like the slot rows, and every segment's work stays on the shard
    hosting its rows.
    """
    order: jnp.ndarray
    inv_order: jnp.ndarray
    seg_rows: jnp.ndarray
    seg_offsets: jnp.ndarray

    def tree_flatten(self):
        return (self.order, self.inv_order, self.seg_rows,
                self.seg_offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclass
class ShardedTenantSegments:
    """Per-data-shard tenant-segment layout (``data > 1`` decode).

    Built host-side by ``serve.scheduler.tenant_segments_sharded`` from
    the per-slot tenant rows: each contiguous shard pool of
    B_s = B / D slots sorts its own rows by tenant and carries its own
    (pool-local) segment list. All arrays are [D, B_s]-shaped — the
    static global envelope — so one jit signature serves every step:

      order       int32 [D, B_s]    pool-LOCAL row permutation
      inv_order   int32 [D, B_s]    its inverse (also pool-local)
      seg_rows    int32 [D, B_s]    tenant row per segment (padding 0)
      seg_offsets int32 [D, B_s+1]  pool-local half-open ranges

    The leading D axis partitions over the mesh ``data`` axis inside the
    shard_map'd correction: each device shard receives exactly its
    pool's rows and its pool's segment list, so it dequantizes only the
    tenants it actually hosts. :meth:`global_order` /
    :meth:`global_segments` flatten to the equivalent single-pool
    layout (block-diagonal permutation, concatenated segment runs) for
    the unsharded execution paths — bit-identical by construction.
    """
    order: jnp.ndarray
    inv_order: jnp.ndarray
    seg_rows: jnp.ndarray
    seg_offsets: jnp.ndarray

    def tree_flatten(self):
        return (self.order, self.inv_order, self.seg_rows,
                self.seg_offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def data_shards(self) -> int:
        return self.order.shape[0]

    def global_order(self):
        """Flatten to global [B] (order, inv_order). The permutation is
        block-diagonal (never crosses a pool), so the global inverse is
        the per-pool inverse shifted by each pool's base offset."""
        D, Bs = self.order.shape
        base = (jnp.arange(D, dtype=jnp.int32) * Bs)[:, None]
        return ((jnp.asarray(self.order) + base).reshape(D * Bs),
                (jnp.asarray(self.inv_order) + base).reshape(D * Bs))

    def global_segments(self):
        """Flatten to the global [B] seg_rows / [B+1] seg_offsets form
        (each pool's padding segments collapse onto its end boundary, so
        offsets stay monotone and segments never cross a pool)."""
        D, Bs = self.seg_rows.shape
        B = D * Bs
        base = (jnp.arange(D, dtype=jnp.int32) * Bs)[:, None]
        sr = jnp.asarray(self.seg_rows).reshape(B)
        so = jnp.concatenate([
            (jnp.asarray(self.seg_offsets)[:, :Bs] + base).reshape(B),
            jnp.full((1,), B, jnp.int32)])
        return sr, so


@jax.tree_util.register_pytree_node_class
@dataclass
class SlotDelta:
    """A tenant-stacked :class:`PackedDelta` plus per-batch-row tenant ids.

    ``delta`` arrays carry a leading tenant axis T (then, optionally, the
    per-kind layer stack): idx/codes [T, *lead, G, K, O], scale/zero
    [T, *lead]. ``slots`` is int32 [B] mapping each batch row to a tenant
    row; row 0 is conventionally the zero delta (base model).
    ``segments`` (optional) carries the sorted tenant-segment layout
    consumed by the unique-tenant dispatch — either the single-pool
    :class:`TenantSegments` or, for ``data > 1`` serving, the per-shard
    :class:`ShardedTenantSegments`.

    ``values``/``res_map`` (optional, only with ``segments``) carry the
    pre-decoded delta residency tier (``serve.engine.DeltaResidency``):
    ``values`` f32 [C, *lead, G, K, O] holds ``pack.decode_values``
    output for C *resident* tenant rows, ``res_map`` int32 [T] maps a
    tenant row to its residency row (rows the engine did not make
    resident this step map to 0 and are never referenced by a live
    segment). When present, the segment dispatch skips the per-step
    code unpack and reads the decoded values directly; the packed
    arrays still ride along for the index gather, and every path
    without values decodes the codes as before (the always-correct
    fallback).
    """
    delta: PackedDelta
    slots: jnp.ndarray
    segments: Optional[Any] = None
    values: Optional[jnp.ndarray] = None
    res_map: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        return (self.delta, self.slots, self.segments, self.values,
                self.res_map), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def index(self, i) -> "SlotDelta":
        """Slice the *layer* stack (axis 1, after the tenant axis)."""
        d = self.delta
        return SlotDelta(PackedDelta(
            d.idx[:, i], d.codes[:, i],
            d.scale[:, i] if jnp.ndim(d.scale) >= 2 else d.scale,
            d.zero[:, i] if jnp.ndim(d.zero) >= 2 else d.zero,
            d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m, d.codec),
            self.slots, self.segments,
            self.values[:, i] if self.values is not None else None,
            self.res_map)

    def gather(self) -> PackedDelta:
        """Per-row delta: [B, G, K, O] gathered from the tenant stack."""
        d = self.delta
        s = self.slots
        return PackedDelta(
            d.idx[s], d.codes[s],
            jnp.asarray(d.scale, jnp.float32)[s],
            jnp.asarray(d.zero, jnp.int32)[s],
            d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m, d.codec)


@jax.tree_util.register_pytree_node_class
@dataclass
class MultiSlotDelta:
    """Mixed-codec decode: one :class:`SlotDelta` part per codec group.

    The engine cannot stack tenants whose runtime packings differ (codec,
    group size, quantization width...), so it stacks each compatible
    *group* separately and routes every group's rows through that group's
    own segment layout. Rows a group does not own map to its row 0 — the
    zero delta — so the per-leaf correction is simply the SUM of the
    parts' corrections: exactly one part contributes the row's real
    correction and every other part contributes an exact 0.0, keeping
    mixed-codec decode token-identical to serving each tenant alone.
    """
    parts: tuple

    def tree_flatten(self):
        return tuple(self.parts), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children))

    def index(self, i) -> "MultiSlotDelta":
        return MultiSlotDelta(tuple(p.index(i) for p in self.parts))


def combine_slot_deltas(wrapped: list) -> Any:
    """Merge per-group slot-wrapped trees (see ``wrap_slot_deltas``) into
    one tree of :class:`MultiSlotDelta` leaves (identity for one group)."""
    if len(wrapped) == 1:
        return wrapped[0]
    return jax.tree.map(lambda *ls: MultiSlotDelta(ls), *wrapped,
                        is_leaf=lambda x: isinstance(x, SlotDelta))


def _row_sharded(t: jnp.ndarray) -> jnp.ndarray:
    """Pin a [rows, ...] array's leading axis over the mesh ``data`` axis.

    Used inside the segment dispatch when the active mesh has a ``data``
    axis > 1: the slot-sorted batch (whose permutation never crosses a
    shard-pool boundary — see TenantSegments) then partitions over
    ``data`` like the KV slot rows do, so each shard's segment
    corrections read and write only local rows. No-op without a mesh,
    with data=1, or when the row count doesn't divide (batch-1 prefill).
    """
    if _MESH is None or _MESH.shape.get("data", 1) <= 1 \
            or t.shape[0] % _MESH.shape["data"]:
        return t
    from jax.sharding import NamedSharding, PartitionSpec
    spec = PartitionSpec(*(["data"] + [None] * (t.ndim - 1)))
    return jax.lax.with_sharding_constraint(t, NamedSharding(_MESH, spec))


def _segment_dispatch(x: jnp.ndarray, sd: SlotDelta) -> jnp.ndarray:
    """Unique-tenant correction: sort rows by tenant, dequantize each
    unique delta once, apply per segment, unsort. x [B, ..., h_in].

    With a :class:`ShardedTenantSegments` layout the mesh path hands the
    per-shard [D, B_s] arrays straight to the shard_map'd correction
    (each data shard processes its own pool's rows and segments); every
    other path runs the flattened global-envelope equivalent, which is
    the same permutation and the same per-row bits.
    """
    seg = sd.segments
    d = sd.delta
    B = x.shape[0]
    lead = x.shape[1:-1]
    tokens_per_row = 1
    for n in lead:
        tokens_per_row *= n
    sharded = isinstance(seg, ShardedTenantSegments)
    order, inv_order = seg.global_order() if sharded \
        else (seg.order, seg.inv_order)
    xs = jnp.take(x, order, axis=0)
    x2 = _row_sharded(xs.reshape(B * tokens_per_row, d.h_in))
    y2 = None
    if _MESH is not None:
        from repro.kernels import ops
        # ranges (pool-local [D, B_s+1] or global [B+1]) scale with the
        # tokens folded out of each batch row; ops detects the per-shard
        # form by its 2-D seg_rows
        y2 = ops.delta_correction_sharded(
            x2, d, _MESH, use_pallas=_USE_PALLAS,
            segments=(seg.seg_rows, seg.seg_offsets * tokens_per_row),
            values=sd.values, res_map=sd.res_map)
    if y2 is None:
        sr, so = seg.global_segments() if sharded \
            else (seg.seg_rows, seg.seg_offsets)
        # row ranges scale with the tokens folded out of each batch row
        y2 = _segment_local(x2, d, sr, so * tokens_per_row,
                            sd.values, sd.res_map)
    # same dtype round-trip as every other path (no-op for f32)
    y = y2.reshape(B, *lead, d.h_out).astype(x.dtype)
    return jnp.take(y, inv_order, axis=0)


def _segment_local(x2, d, seg_rows, seg_offsets, values=None, res_map=None):
    from repro.kernels import fallback, ops
    if _USE_PALLAS:
        return ops.delta_spmm_segments(x2, d, seg_rows, seg_offsets,
                                       values=values, res_map=res_map)
    return fallback.segment_correction(x2, d, seg_rows, seg_offsets,
                                       values=values, res_map=res_map)


def slot_delta_matmul(x: jnp.ndarray, sd: SlotDelta) -> jnp.ndarray:
    """Mixed-tenant correction: x [B, S, h_in] with row b using tenant
    slots[b].

    Default ("segments" dispatch, when the SlotDelta carries a
    TenantSegments layout): rows are grouped by tenant so each *unique*
    delta is dequantized once per step. Fallback ("per_row" dispatch, or
    no layout attached): gather each row's packed delta (tiny vs dense)
    then contract per row; on TPU hot paths the gathered stack routes
    through the vmapped Pallas kernel. The per-row path is the legacy
    behavior, kept selectable via :func:`set_slot_dispatch`.
    """
    if sd.segments is not None and _SLOT_DISPATCH == "segments":
        _note("slot_dispatch", dispatch="segments")
        return _segment_dispatch(x, sd)
    _note("slot_dispatch", dispatch="per_row")
    g = sd.gather()
    y = _sharded_correction(x, g)
    if y is not None:
        return y
    from repro.kernels import fallback, ops
    if _USE_PALLAS:
        return ops.delta_spmm_slots(x, g)
    # per-row gather: never materializes the dense [B, h_in, h_out]
    # stack, and bit-matches the shared-tenant gather formulation
    _note("slot_dispatch", formulation="per-row-gather")
    return fallback.gather_correction_rows(x, g).astype(x.dtype)


def delta_matmul(x: jnp.ndarray, d) -> jnp.ndarray:
    """x [..., h_in] @ dequant(delta) [h_in, h_out] -> [..., h_out]."""
    if isinstance(d, MultiSlotDelta):
        # mixed-codec groups: sum the per-group corrections in f32. Each
        # row is owned by exactly one group; the others map it to the
        # zero-delta row, contributing an exact 0.0 (scale and codes are
        # all zero), so the sum preserves the token-identity contract.
        y = slot_delta_matmul(x, d.parts[0]).astype(jnp.float32)
        for p in d.parts[1:]:
            y = y + slot_delta_matmul(x, p).astype(jnp.float32)
        return y.astype(x.dtype)
    if isinstance(d, SlotDelta):
        return slot_delta_matmul(x, d)
    if not d.stack_shape():
        y = _sharded_correction(x, d)
        if y is not None:
            return y
        if _USE_PALLAS:
            from repro.kernels import ops
            return ops.delta_spmm(x, d)
        # XLA fallback: the gather formulation at decode-sized token
        # counts, dense reconstruction at prefill-sized ones. The same
        # primitive (same contraction shape) backs the segment dispatch,
        # which is what keeps mixed-stream decode token-identical to
        # this per-tenant reference path.
        from repro.kernels import fallback
        return fallback.correction_nd(x, d).astype(x.dtype)
    dense = reconstruct_dense(d, dtype=x.dtype)
    return x @ dense


def apply_linear(x: jnp.ndarray, w: jnp.ndarray, d: Optional[PackedDelta] = None) -> jnp.ndarray:
    """Base matmul plus (optionally) the tenant's delta correction.

    The correction is computed behind an ``optimization_barrier`` and
    added in f32 with ONE explicit final rounding. Without the barrier
    XLA fuses the correction into its consumers at fusion-dependent
    precision, and the fusion decisions shift when the shard_map'd
    sharded-correction region is present — sharded and single-device
    decode then drift by an ulp, enough to flip greedy argmax near
    ties. The pinned boundary + fixed-precision add keep the hot path
    bit-identical across mesh layouts (the CI token-identity check).
    """
    x = _replicated(x)
    y = x @ w
    if d is not None:
        c = _pinned(delta_matmul(x, d).astype(jnp.float32))
        y = (y.astype(jnp.float32) + c).astype(y.dtype)
    return _replicated(y)


def apply_linear_batched(x: jnp.ndarray, w: jnp.ndarray,
                         d: Optional[PackedDelta] = None) -> jnp.ndarray:
    """Batched over a leading stack dim (e.g. MoE experts):
    x [E, ..., h_in], w [E, h_in, h_out], delta stacked [E, ...]."""
    if isinstance(d, (SlotDelta, MultiSlotDelta)):
        # Expert buffers mix tokens from many slots; a per-row gather has no
        # meaning here. The serving engine must group such archs per tenant.
        raise NotImplementedError(
            "slot-dispatched deltas are not supported at expert-batched "
            "linear sites (MoE); serve these tenants via per-tenant grouping")
    x = _replicated(x)
    # deltalint: allow[DL001] audited MoE expert-batched base matmul: no
    # per-row identity contract at this site (tenants are served grouped,
    # never mixed-batch through expert buffers — see the raise above)
    y = jnp.einsum("e...d,edf->e...f", x, w)
    if d is not None:
        dense = reconstruct_dense(d, dtype=x.dtype)  # [E, h_in, h_out]
        # same fusion pin + fixed-precision add as apply_linear, so MoE
        # expert-site corrections keep the mesh bit-identity contract too
        # deltalint: allow[DL001] audited MoE correction: grouped-per-tenant
        # serving only, so batch extent is fixed per tenant group
        c = _pinned(jnp.einsum("e...d,edf->e...f", x, dense)
                    .astype(jnp.float32))
        y = (y.astype(jnp.float32) + c).astype(y.dtype)
    return _replicated(y)


# ---------------------------------------------------------------------------
# Delta-tree helpers: deltas mirror the params tree with None at
# uncompressed leaves, so block code can slice them alongside params.
# ---------------------------------------------------------------------------
def none_like(params: Any) -> Any:
    """A deltas pytree of all-None matching ``params``' dict structure."""
    if isinstance(params, dict):
        return {k: none_like(v) for k, v in params.items()}
    return None


def dget(deltas: Any, *keys: str) -> Any:
    """None-safe nested lookup into a deltas tree."""
    node = deltas
    for k in keys:
        if node is None:
            return None
        node = node.get(k) if isinstance(node, dict) else None
    return node


def dindex(deltas: Any, i) -> Any:
    """Slice every PackedDelta in a deltas subtree at stacked-layer index i."""
    if deltas is None:
        return None
    if isinstance(deltas, (SlotDelta, MultiSlotDelta)):
        return deltas.index(i)
    if isinstance(deltas, PackedDelta):
        return deltas.index(i)
    if isinstance(deltas, dict):
        return {k: dindex(v, i) for k, v in deltas.items()}
    return None


# ---------------------------------------------------------------------------
# Tenant stacking for the continuous-batching engine
# ---------------------------------------------------------------------------
def _is_pd(x) -> bool:
    return isinstance(x, PackedDelta)


def zero_delta_like(deltas: Any) -> Any:
    """An all-zero deltas tree with the same packed structure/shapes.

    Dequantizes to exactly 0 at every leaf (scale 0, codes 0), so the base
    model can occupy a row of a tenant stack without a structure change.
    """
    def z(d: PackedDelta) -> PackedDelta:
        return PackedDelta(
            jnp.zeros_like(d.idx), jnp.zeros_like(d.codes),
            jnp.zeros(jnp.shape(d.scale), jnp.float32),
            jnp.zeros(jnp.shape(d.zero), jnp.int32),
            d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m, d.codec)

    return jax.tree.map(z, deltas, is_leaf=_is_pd)


def stack_tenant_deltas(trees: list) -> Any:
    """Stack N structurally identical delta trees along a new tenant axis.

    Every leaf becomes a PackedDelta with arrays [T, ...]; scale/zero
    become [T, *lead]. Raises ValueError when the trees disagree in
    structure or packing meta (different specs cannot share one stack).
    """
    if not trees:
        raise ValueError("need at least one delta tree to stack")
    ref = jax.tree.structure(trees[0], is_leaf=_is_pd)
    for t in trees[1:]:
        if jax.tree.structure(t, is_leaf=_is_pd) != ref:
            raise ValueError("tenant delta trees differ in structure; "
                             "cannot stack for slot dispatch")

    def stack(*leaves):
        d0 = leaves[0]
        for d in leaves[1:]:
            if (d.h_in, d.h_out, d.h_g, d.keep, d.k_bits, d.m, d.codec,
                    d.idx.shape, d.codes.shape) != \
               (d0.h_in, d0.h_out, d0.h_g, d0.keep, d0.k_bits, d0.m,
                    d0.codec, d0.idx.shape, d0.codes.shape):
                raise ValueError("tenant deltas use different packing specs; "
                                 "cannot stack for slot dispatch")
        return PackedDelta(
            jnp.stack([d.idx for d in leaves]),
            jnp.stack([d.codes for d in leaves]),
            jnp.stack([jnp.asarray(d.scale, jnp.float32) for d in leaves]),
            jnp.stack([jnp.asarray(d.zero, jnp.int32) for d in leaves]),
            d0.h_in, d0.h_out, d0.h_g, d0.keep, d0.alpha, d0.k_bits, d0.m,
            d0.codec)

    return jax.tree.map(stack, *trees, is_leaf=_is_pd)


def wrap_slot_deltas(stacked: Any, slots: jnp.ndarray,
                     segments: Optional[TenantSegments] = None,
                     values: Any = None,
                     res_map: Optional[jnp.ndarray] = None) -> Any:
    """Attach per-row tenant ids (and, optionally, the sorted tenant-
    segment layout for unique-tenant dispatch, plus the pre-decoded
    residency tier: ``values`` a tree of f32 buffers mirroring
    ``stacked`` leaf-for-leaf and ``res_map`` the shared tenant-row ->
    residency-row indirection) to every leaf of a tenant-stacked tree."""
    if values is None:
        return jax.tree.map(lambda d: SlotDelta(d, slots, segments), stacked,
                            is_leaf=_is_pd)
    return jax.tree.map(
        lambda d, v: SlotDelta(d, slots, segments, v, res_map),
        stacked, values, is_leaf=_is_pd)


def merge_delta(params: Any, deltas: Any) -> Any:
    """Materialize fine-tuned params = base + dense(delta). (Eval/reference.)"""
    if isinstance(params, dict):
        return {k: merge_delta(v, deltas.get(k) if isinstance(deltas, dict) else None)
                for k, v in params.items()}
    if deltas is None:
        return params
    if isinstance(deltas, PackedDelta):
        dense = reconstruct_dense(deltas)
    else:
        # other codecs' leaves (BitDelta, low-rank residual, ...)
        from repro.core.codecs import reconstruct_dense_any
        dense = reconstruct_dense_any(deltas)
    return (params.astype(jnp.float32) + dense).astype(params.dtype)
