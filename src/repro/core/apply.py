"""Delta application — the paper's separate-computation scheme (§3.1, Fig. 3).

Every linear site in the model zoo routes through :func:`apply_linear`:

    y = x @ W_base            (+ x @ dequant(packed delta)   if delta given)

On TPU hot paths the correction term is the Pallas ``delta_spmm`` kernel
(scatter-to-dense in VMEM + MXU); under SPMD dry-runs and CPU tests the
mathematically identical XLA fallback below is used (config
``use_pallas_kernels``). Both share the pure-jnp oracle in
``repro/kernels/ref.py`` for tests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.pack import PackedDelta, reconstruct_dense

# Global switch flipped by serving/launch configs. The Pallas path only
# lowers on real TPUs; everything else uses the XLA fallback.
_USE_PALLAS = False


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def delta_matmul(x: jnp.ndarray, d: PackedDelta) -> jnp.ndarray:
    """x [..., h_in] @ dequant(delta) [h_in, h_out] -> [..., h_out]."""
    if _USE_PALLAS and not d.stack_shape():
        from repro.kernels import ops
        return ops.delta_spmm(x, d)
    dense = reconstruct_dense(d, dtype=x.dtype)
    return x @ dense


def apply_linear(x: jnp.ndarray, w: jnp.ndarray, d: Optional[PackedDelta] = None) -> jnp.ndarray:
    """Base matmul plus (optionally) the tenant's delta correction."""
    y = x @ w
    if d is not None:
        y = y + delta_matmul(x, d).astype(y.dtype)
    return y


def apply_linear_batched(x: jnp.ndarray, w: jnp.ndarray, d: Optional[PackedDelta] = None) -> jnp.ndarray:
    """Batched over a leading stack dim (e.g. MoE experts):
    x [E, ..., h_in], w [E, h_in, h_out], delta stacked [E, ...]."""
    y = jnp.einsum("e...d,edf->e...f", x, w)
    if d is not None:
        dense = reconstruct_dense(d, dtype=x.dtype)  # [E, h_in, h_out]
        y = y + jnp.einsum("e...d,edf->e...f", x, dense)
    return y


# ---------------------------------------------------------------------------
# Delta-tree helpers: deltas mirror the params tree with None at
# uncompressed leaves, so block code can slice them alongside params.
# ---------------------------------------------------------------------------
def none_like(params: Any) -> Any:
    """A deltas pytree of all-None matching ``params``' dict structure."""
    if isinstance(params, dict):
        return {k: none_like(v) for k, v in params.items()}
    return None


def dget(deltas: Any, *keys: str) -> Any:
    """None-safe nested lookup into a deltas tree."""
    node = deltas
    for k in keys:
        if node is None:
            return None
        node = node.get(k) if isinstance(node, dict) else None
    return node


def dindex(deltas: Any, i) -> Any:
    """Slice every PackedDelta in a deltas subtree at stacked-layer index i."""
    if deltas is None:
        return None
    if isinstance(deltas, PackedDelta):
        return deltas.index(i)
    if isinstance(deltas, dict):
        return {k: dindex(v, i) for k, v in deltas.items()}
    return None


def merge_delta(params: Any, deltas: Any) -> Any:
    """Materialize fine-tuned params = base + dense(delta). (Eval/reference.)"""
    if isinstance(params, dict):
        return {k: merge_delta(v, deltas.get(k) if isinstance(deltas, dict) else None)
                for k, v in params.items()}
    if deltas is None:
        return params
    assert isinstance(deltas, PackedDelta)
    return (params.astype(jnp.float32) + reconstruct_dense(deltas)).astype(params.dtype)
