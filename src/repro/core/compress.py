"""End-to-end DeltaDQ compression pipeline over a whole params tree.

    spec = DeltaDQSpec(alpha=8, k_bits=4, m=8)         # 128x
    deltas, report = compress(base_params, ft_params, spec, rng)

Selection rule: 2-D (or expert-stacked 3-D) projection matrices are
compressed; embeddings, unembeddings, norms, biases, convs, routers and
SSM/LRU per-channel params stay dense per BitDelta/DeltaZip convention
(DESIGN.md §4). Uncompressed leaves' deltas are carried dense in the
report so nothing is silently dropped.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.dropout import groupwise_dropout_pack, keep_count
from repro.core.pack import PackedDelta
from repro.utils import map_with_paths

_EXCLUDE_TOKENS = (
    "embed", "unembed", "norm", "ln1", "ln2", "ln", "scale", "bias",
    "conv", "a_param", "dt_bias", "a_log", "d_skip", "gate_attn",
    "gate_mlp", "router", "q_norm", "k_norm",
)


def is_compressible(path: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    low = path.lower()
    if any(t in low.split("/") or t in low for t in _EXCLUDE_TOKENS):
        return False
    h_in, h_out = leaf.shape[-2], leaf.shape[-1]
    return h_in >= 16 and h_out >= 8


@dataclass(frozen=True)
class DeltaDQSpec:
    alpha: float = 8.0            # dropout compression (keep-rate 1/alpha)
    k_bits: Optional[int] = None  # None -> dropout only (paper's 2x..8x rows)
    m: int = 1                    # separate-quantization parts
    h_g: Optional[int] = None     # None -> use h_in (row-wise); search sets it
    seed: int = 0

    def ratio(self) -> float:
        return quant.compression_ratio(self.alpha, self.k_bits, self.m)


@dataclass
class CompressionReport:
    spec: DeltaDQSpec
    n_compressed: int = 0
    n_dense: int = 0
    dense_delta_bits: float = 0.0      # bits of the raw bf16 delta we compressed
    packed_value_bits: float = 0.0     # paper convention (values only)
    packed_total_bits: float = 0.0     # honest: + indices
    skipped_paths: list = field(default_factory=list)

    @property
    def ratio_paper(self) -> float:
        return self.dense_delta_bits / max(self.packed_value_bits, 1e-9)

    @property
    def ratio_honest(self) -> float:
        return self.dense_delta_bits / max(self.packed_total_bits, 1e-9)

    def summary(self) -> str:
        return (f"DeltaDQ(alpha={self.spec.alpha}, h_g={self.spec.h_g}, "
                f"k={self.spec.k_bits}, m={self.spec.m}): "
                f"{self.n_compressed} tensors packed, {self.n_dense} left dense; "
                f"ratio paper-convention={self.ratio_paper:.1f}x "
                f"honest(+indices)={self.ratio_honest:.1f}x "
                f"(spec target {self.spec.ratio():.0f}x)")


def _pick_hg(h_in: int, spec: DeltaDQSpec) -> int:
    if spec.h_g is None:
        return h_in
    # clamp to a divisor of h_in: largest halving of h_g dividing h_in.
    # Candidates below alpha are unsatisfiable (keep would round to 0 and
    # halving only shrinks hg further), so detect that up front instead
    # of walking to hg < 1 and raising a misleading divisibility error.
    floor = max(spec.alpha, 1.0)
    hg = min(spec.h_g, h_in)
    if hg < floor:
        raise ValueError(
            f"unsatisfiable group size: requested h_g={spec.h_g} "
            f"(clamped to {hg} for h_in={h_in}) is below alpha={spec.alpha}; "
            f"every group must keep h_g/alpha >= 1 elements, so pick "
            f"h_g >= alpha")
    while h_in % hg:
        hg //= 2
        if hg < floor:
            raise ValueError(
                f"unsatisfiable group size: no halving of h_g={spec.h_g} "
                f"both divides h_in={h_in} and stays >= alpha={spec.alpha}")
    return int(hg)


def compress_leaf(rng, base_leaf, ft_leaf, spec: DeltaDQSpec) -> PackedDelta:
    """Compress one (possibly expert-stacked) weight's delta."""
    delta = ft_leaf.astype(jnp.float32) - base_leaf.astype(jnp.float32)
    h_in = delta.shape[-2]
    hg = _pick_hg(h_in, spec)
    return groupwise_dropout_pack(rng, delta, h_g=hg, alpha=spec.alpha,
                                  k_bits=spec.k_bits, m=spec.m)


def compress(base_params: Any, ft_params: Any, spec: DeltaDQSpec,
             rng: Optional[jax.Array] = None) -> tuple[Any, CompressionReport]:
    """Compress every eligible delta leaf; returns (deltas tree, report)."""
    if rng is None:
        rng = jax.random.PRNGKey(spec.seed)
    report = CompressionReport(spec=spec)

    def fn(path: str, b, f):
        if not is_compressible(path, b):
            report.n_dense += 1
            report.skipped_paths.append(path)
            return None
        # stable digest, NOT hash(): str hashes are randomized by
        # PYTHONHASHSEED, which made the "same" compression produce
        # different deltas across processes — breaking checkpoint
        # reproducibility and any cross-host identity contract
        leaf_rng = jax.random.fold_in(
            rng, zlib.crc32(path.encode("utf-8")) & 0x7FFFFFFF)
        d = compress_leaf(leaf_rng, b, f, spec)
        report.n_compressed += 1
        stack = int(np.prod(d.stack_shape())) if d.stack_shape() else 1
        report.dense_delta_bits += 16.0 * d.h_in * d.h_out * stack
        report.packed_value_bits += d.value_bits() * stack
        report.packed_total_bits += (d.value_bits() + d.index_bits()) * stack
        return d

    deltas = map_with_paths(fn, base_params, ft_params)
    return deltas, report


def decompress(base_params: Any, deltas: Any) -> Any:
    """Reconstruct approximate fine-tuned params (reference/eval path)."""
    from repro.core.apply import merge_delta
    return merge_delta(base_params, deltas)


# ---------------------------------------------------------------------------
# Shape-only twins for the multi-pod dry-run (no compression computed)
# ---------------------------------------------------------------------------
def delta_leaf_spec(leaf_spec, spec: DeltaDQSpec) -> PackedDelta:
    """PackedDelta of ShapeDtypeStructs for one weight's compressed delta."""
    from repro.core.quant import packed_len

    shape = leaf_spec.shape
    lead, (h_in, h_out) = shape[:-2], shape[-2:]
    hg = _pick_hg(h_in, spec)
    # the same helper real packing uses (dropout._check): shape-only
    # dry-run specs can never drift from what packing actually produces
    keep = keep_count(hg, spec.alpha)
    G = h_in // hg
    idx_dtype = jnp.uint8 if hg <= 256 else jnp.int32
    if spec.k_bits is None:
        codes = jax.ShapeDtypeStruct((*lead, G, keep, h_out), jnp.float32)
        scale = jax.ShapeDtypeStruct(lead, jnp.float32)
        zero = jax.ShapeDtypeStruct(lead, jnp.int32)
    else:
        kp = packed_len(keep, spec.k_bits)
        codes = jax.ShapeDtypeStruct((*lead, G, kp, h_out), jnp.uint8)
        scale = jax.ShapeDtypeStruct(lead, jnp.float32)
        zero = jax.ShapeDtypeStruct(lead, jnp.int32)
    return PackedDelta(
        idx=jax.ShapeDtypeStruct((*lead, G, keep, h_out), idx_dtype),
        codes=codes, scale=scale, zero=zero,
        h_in=h_in, h_out=h_out, h_g=hg, keep=keep,
        alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m,
    )


def delta_specs(param_specs: Any, spec: DeltaDQSpec) -> Any:
    """ShapeDtypeStruct deltas tree mirroring a param-specs tree."""

    def fn(path, leaf):
        if not is_compressible(path, leaf):
            return None
        return delta_leaf_spec(leaf, spec)

    return map_with_paths(fn, param_specs)


def delta_axes(param_specs: Any, param_axes: Any, spec: DeltaDQSpec,
               model_axis_size: int) -> Any:
    """Logical-axes tree matching :func:`delta_specs` structure.

    idx/codes [lead..., G, K, O]: O inherits the base weight's output axis;
    the G (group) axis inherits the input axis only when group boundaries
    align with the shard boundaries (G divisible by the mesh axis) — else
    it is replicated, which is cheap because deltas are tiny (the paper's
    point). scale/zero inherit the lead axes.
    """

    def fn(path, leaf, ax):
        if not is_compressible(path, leaf):
            return None
        d = delta_leaf_spec(leaf, spec)
        lead_ax = tuple(ax[:-2])
        in_ax, out_ax = ax[-2], ax[-1]
        g_ax = in_ax if d.n_groups % max(model_axis_size, 1) == 0 else None
        arr_ax = (*lead_ax, g_ax, None, out_ax)
        return PackedDelta(
            idx=arr_ax, codes=arr_ax, scale=lead_ax, zero=lead_ax,
            h_in=d.h_in, h_out=d.h_out, h_g=d.h_g, keep=d.keep,
            alpha=d.alpha, k_bits=d.k_bits, m=d.m,
        )

    return map_with_paths(fn, param_specs, param_axes,
                          is_leaf=lambda x: hasattr(x, "shape"))
