"""End-to-end delta compression over a whole params tree, any codec.

    spec = DeltaDQSpec(alpha=8, k_bits=4, m=8)         # 128x
    deltas, report = compress(base_params, ft_params, spec, rng)

    # pick a codec by name (default spec), or per leaf under a budget:
    deltas, report = compress(base, ft, codec="bitdelta")
    deltas, report = compress(base, ft, codec="auto", budget_bits=1.5)

The codec family lives in :mod:`repro.core.codecs`; ``compress`` routes
each leaf through the codec owning the given spec (``DeltaDQSpec`` stays
importable from here for compatibility). ``codec="auto"`` compresses each
leaf with every registered codec's candidate spec and keeps the one that
meets ``budget_bits`` (total stored bits per weight element, indices
included) at the lowest relative reconstruction error — recorded per leaf
in the report.

Selection rule: 2-D (or expert-stacked 3-D) projection matrices are
compressed; embeddings, unembeddings, norms, biases, convs, routers and
SSM/LRU per-channel params stay dense per BitDelta/DeltaZip convention
(DESIGN.md §4). Uncompressed leaves' deltas are carried dense in the
report so nothing is silently dropped.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

# DeltaDQSpec/_pick_hg moved to codecs.py with the codec extraction; both
# stay importable from here (tests and launchers use this path)
from repro.core.codecs import (  # noqa: F401  (re-exports)
    BitDeltaSpec, DeltaCodec, DeltaDQSpec, LowRankSpec, _pick_hg,
    codec_for_spec, codec_names, get_codec,
)
from repro.utils import map_with_paths

_EXCLUDE_TOKENS = (
    "embed", "unembed", "norm", "ln1", "ln2", "ln", "scale", "bias",
    "conv", "a_param", "dt_bias", "a_log", "d_skip", "gate_attn",
    "gate_mlp", "router", "q_norm", "k_norm",
)


def is_compressible(path: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    low = path.lower()
    if any(t in low.split("/") or t in low for t in _EXCLUDE_TOKENS):
        return False
    h_in, h_out = leaf.shape[-2], leaf.shape[-1]
    return h_in >= 16 and h_out >= 8


@dataclass
class CompressionReport:
    spec: Any = None                   # None for codec="auto"
    n_compressed: int = 0
    n_dense: int = 0
    dense_delta_bits: float = 0.0      # bits of the raw bf16 delta we compressed
    packed_value_bits: float = 0.0     # paper convention (values only)
    packed_total_bits: float = 0.0     # honest: + indices/factors/metadata
    skipped_paths: list = field(default_factory=list)
    # per-codec breakdown: name -> {n_leaves, dense_bits, value_bits, total_bits}
    per_codec: dict = field(default_factory=dict)
    leaf_codecs: dict = field(default_factory=dict)   # path -> codec name
    # auto-picker records: path -> {codec, bits_per_element, rel_error, budget_met}
    auto_choices: dict = field(default_factory=dict)
    budget_bits: Optional[float] = None
    # wall-clock spent compressing (the registry's register-to-first-token
    # accounting needs the ingest cost split from the table-write cost)
    wall_s: float = 0.0

    @property
    def ratio_paper(self) -> float:
        return self.dense_delta_bits / max(self.packed_value_bits, 1e-9)

    @property
    def ratio_honest(self) -> float:
        return self.dense_delta_bits / max(self.packed_total_bits, 1e-9)

    @property
    def budget_met(self) -> bool:
        """True iff every auto-picked leaf met the requested budget."""
        return all(c["budget_met"] for c in self.auto_choices.values())

    def add_leaf(self, path: str, codec: DeltaCodec, leaf) -> None:
        """Account one compressed leaf via its codec's storage_bits."""
        bits = codec.storage_bits(leaf)
        stack = int(np.prod(leaf.stack_shape())) if leaf.stack_shape() else 1
        dense = 16.0 * leaf.h_in * leaf.h_out * stack
        self.n_compressed += 1
        self.dense_delta_bits += dense
        self.packed_value_bits += bits["value_bits"]
        self.packed_total_bits += bits["total_bits"]
        pc = self.per_codec.setdefault(
            codec.name, {"n_leaves": 0, "dense_bits": 0.0,
                         "value_bits": 0.0, "total_bits": 0.0})
        pc["n_leaves"] += 1
        pc["dense_bits"] += dense
        pc["value_bits"] += bits["value_bits"]
        pc["total_bits"] += bits["total_bits"]
        self.leaf_codecs[path] = codec.name

    def summary(self) -> str:
        if isinstance(self.spec, DeltaDQSpec):
            head = (f"DeltaDQ(alpha={self.spec.alpha}, h_g={self.spec.h_g}, "
                    f"k={self.spec.k_bits}, m={self.spec.m})")
        elif self.spec is not None:
            head = repr(self.spec)      # dataclass repr: Name(field=...)
        else:
            head = (f"auto(budget={self.budget_bits} bits/elt, "
                    f"met={self.budget_met})")
        s = (f"{head}: "
             f"{self.n_compressed} tensors packed, {self.n_dense} left dense; "
             f"ratio paper-convention={self.ratio_paper:.1f}x "
             f"honest(+indices)={self.ratio_honest:.1f}x")
        if self.spec is not None and hasattr(self.spec, "ratio"):
            s += f" (spec target {self.spec.ratio():.0f}x)"
        if len(self.per_codec) > 1 or self.spec is None:
            for name, pc in self.per_codec.items():
                r = pc["dense_bits"] / max(pc["total_bits"], 1e-9)
                s += (f"\n  {name}: {pc['n_leaves']} leaves, "
                      f"honest {r:.1f}x")
        return s


def compress_leaf(rng, base_leaf, ft_leaf, spec) -> Any:
    """Compress one (possibly expert-stacked) weight's delta with the
    codec owning ``spec`` (DeltaDQSpec -> PackedDelta, other specs ->
    their codec's leaf type)."""
    return codec_for_spec(spec).compress_leaf(rng, base_leaf, ft_leaf, spec)


def _leaf_rng(rng, path: str):
    # stable digest, NOT hash(): str hashes are randomized by
    # PYTHONHASHSEED, which made the "same" compression produce
    # different deltas across processes — breaking checkpoint
    # reproducibility and any cross-host identity contract
    return jax.random.fold_in(
        rng, zlib.crc32(path.encode("utf-8")) & 0x7FFFFFFF)


def _resolve(spec, codec: Optional[str]) -> tuple[Any, DeltaCodec]:
    if codec is not None:
        c = get_codec(codec)
        if spec is None:
            spec = c.default_spec()
        elif not isinstance(spec, c.spec_cls):
            raise ValueError(
                f"spec {type(spec).__name__} does not belong to codec "
                f"{codec!r} (expects {c.spec_cls.__name__})")
        return spec, c
    if spec is None:
        spec = DeltaDQSpec()
    return spec, codec_for_spec(spec)


def compress(base_params: Any, ft_params: Any, spec: Any = None,
             rng: Optional[jax.Array] = None, *,
             codec: Optional[str] = None,
             budget_bits: Optional[float] = None,
             progress: Optional[Callable[[str, Optional[str]], None]] = None,
             ) -> tuple[Any, CompressionReport]:
    """Compress every eligible delta leaf; returns (deltas tree, report).

    ``spec`` picks the codec by its class (default: ``DeltaDQSpec()``,
    dropout-only — the registry default codec). ``codec`` selects by name
    with the codec's default spec; ``codec="auto"`` runs the per-leaf
    auto-picker and requires ``budget_bits`` (stored bits per weight
    element, indices included).

    ``progress(path, codec_name_or_None)`` is called once per leaf as it
    resolves (None = left dense) — the serve registry's ingest worker
    reports live compression progress through it. The report's ``wall_s``
    records the wall-clock the whole tree took.
    """
    t0 = time.perf_counter()
    if codec == "auto":
        deltas, report = _compress_auto(base_params, ft_params, spec, rng,
                                        budget_bits, progress)
        report.wall_s = time.perf_counter() - t0
        return deltas, report
    if budget_bits is not None:
        raise ValueError("budget_bits only applies to codec='auto'")
    spec, c = _resolve(spec, codec)
    if rng is None:
        rng = jax.random.PRNGKey(getattr(spec, "seed", 0))
    report = CompressionReport(spec=spec)

    def fn(path: str, b, f):
        if not is_compressible(path, b):
            report.n_dense += 1
            report.skipped_paths.append(path)
            if progress is not None:
                progress(path, None)
            return None
        d = c.compress_leaf(_leaf_rng(rng, path), b, f, spec)
        report.add_leaf(path, c, d)
        if progress is not None:
            progress(path, c.name)
        return d

    deltas = map_with_paths(fn, base_params, ft_params)
    report.wall_s = time.perf_counter() - t0
    return deltas, report


def auto_candidates(spec: Any = None) -> list[tuple[DeltaCodec, Any]]:
    """The (codec, spec) candidates the auto-picker evaluates: every
    registered codec at its default spec, except that an explicit ``spec``
    replaces its own codec's default."""
    out = []
    for name in codec_names():
        c = get_codec(name)
        sp = spec if (spec is not None and isinstance(spec, c.spec_cls)) \
            else c.default_spec()
        out.append((c, sp))
    return out


def _compress_auto(base_params, ft_params, spec, rng, budget_bits,
                   progress=None) -> tuple[Any, CompressionReport]:
    """Per-leaf codec auto-pick: cheapest codec meeting the size budget
    at the lowest measured reconstruction error.

    Rule per leaf: among candidates whose honest bits/element (indices
    included) fit ``budget_bits``, keep the lowest relative Frobenius
    reconstruction error (ties -> fewer bits). If none fit, keep the
    smallest candidate and mark the leaf ``budget_met=False``.
    """
    if budget_bits is None:
        raise ValueError("codec='auto' requires budget_bits")
    if rng is None:
        rng = jax.random.PRNGKey(getattr(spec, "seed", 0) if spec else 0)
    candidates = auto_candidates(spec)
    report = CompressionReport(spec=None, budget_bits=budget_bits)

    def fn(path: str, b, f):
        if not is_compressible(path, b):
            report.n_dense += 1
            report.skipped_paths.append(path)
            if progress is not None:
                progress(path, None)
            return None
        leaf_rng = _leaf_rng(rng, path)
        delta = np.asarray(f, np.float32) - np.asarray(b, np.float32)
        dnorm = float(np.linalg.norm(delta))
        n_elems = delta.size
        scored = []
        for c, sp in candidates:
            d = c.compress_leaf(leaf_rng, b, f, sp)
            bpe = c.storage_bits(d)["total_bits"] / n_elems
            recon = np.asarray(c.reconstruct_dense(d), np.float32)
            err = float(np.linalg.norm(recon - delta)) / max(dnorm, 1e-12)
            scored.append((c, d, bpe, err))
        feasible = [s for s in scored if s[2] <= budget_bits]
        if feasible:
            c, d, bpe, err = min(feasible, key=lambda s: (s[3], s[2]))
        else:
            c, d, bpe, err = min(scored, key=lambda s: (s[2], s[3]))
        report.add_leaf(path, c, d)
        report.auto_choices[path] = {
            "codec": c.name, "bits_per_element": bpe, "rel_error": err,
            "budget_met": bool(bpe <= budget_bits)}
        if progress is not None:
            progress(path, c.name)
        return d

    deltas = map_with_paths(fn, base_params, ft_params)
    return deltas, report


def decompress(base_params: Any, deltas: Any) -> Any:
    """Reconstruct approximate fine-tuned params (reference/eval path)."""
    from repro.core.apply import merge_delta
    return merge_delta(base_params, deltas)


# ---------------------------------------------------------------------------
# Shape-only twins for the multi-pod dry-run (no compression computed)
# ---------------------------------------------------------------------------
def delta_leaf_spec(leaf_spec, spec) -> Any:
    """Codec leaf of ShapeDtypeStructs for one weight's compressed delta."""
    return codec_for_spec(spec).leaf_spec(leaf_spec, spec)


def delta_specs(param_specs: Any, spec: Any) -> Any:
    """ShapeDtypeStruct deltas tree mirroring a param-specs tree (any
    registered codec's spec)."""
    c = codec_for_spec(spec)

    def fn(path, leaf):
        if not is_compressible(path, leaf):
            return None
        return c.leaf_spec(leaf, spec)

    return map_with_paths(fn, param_specs)


def delta_axes(param_specs: Any, param_axes: Any, spec: Any,
               model_axis_size: int) -> Any:
    """Logical-axes tree matching :func:`delta_specs` structure.

    For DeltaDQ, idx/codes [lead..., G, K, O]: O inherits the base
    weight's output axis; the G (group) axis inherits the input axis only
    when group boundaries align with the shard boundaries (G divisible by
    the mesh axis) — else it is replicated, which is cheap because deltas
    are tiny (the paper's point). scale/zero inherit the lead axes. Other
    codecs define the analogous mapping in their ``leaf_axes``.
    """
    c = codec_for_spec(spec)

    def fn(path, leaf, ax):
        if not is_compressible(path, leaf):
            return None
        return c.leaf_axes(leaf, ax, spec, model_axis_size)

    return map_with_paths(fn, param_specs, param_axes,
                          is_leaf=lambda x: hasattr(x, "shape"))
