"""Pluggable delta codecs: one compression interface, many formats.

A :class:`DeltaCodec` packages everything the rest of the system needs
to know about one delta-compression format:

* ``compress_leaf``       — (base, ft) weight pair -> codec leaf
* ``reconstruct_dense``   — codec leaf -> f32 [..., h_in, h_out] delta
* ``decode_values``       — per-row kept values of the *runtime* form
* ``storage_bits``        — paper/honest storage accounting per leaf
* ``to_storage_parts`` / ``from_storage_parts`` — offline (numpy)
  checkpoint layout round-trip
* ``leaf_spec`` / ``leaf_axes`` — static ShapeDtypeStruct twins +
  logical-axes twins for the multi-pod dry-run
* ``runtime_packed``      — codec leaf -> :class:`PackedDelta`

The last method is the serving contract: every codec lowers its leaf to
the structured :class:`~repro.core.pack.PackedDelta` runtime layout
(dense-as-structured when the codec has no sparsity), tagged with the
codec's name, so ALL existing decode machinery — per-row gather,
unique-tenant segments, shard_map'd mesh corrections, the residency
value tier — serves any codec unchanged. The lowering must be
*bit-faithful*: ``pack.reconstruct_dense(runtime_packed(leaf))`` equals
``codec.reconstruct_dense(leaf)`` exactly, which is what extends the
token-identity contract to mixed-codec serving.

Registered codecs:

* ``deltadq``  — the paper's group-wise dropout + separate quantization
  (the registry default; :class:`DeltaDQSpec`).
* ``bitdelta`` — 1-bit sign bitmap + per-tensor scale
  (arXiv 2402.10193; :class:`BitDeltaSpec`). delta ~ scale * sign(delta)
  with scale = mean |delta|.
* ``lowrank``  — int-quantized dense core + rank-r f32 residual factors
  (quantization + low-rank residual; :class:`LowRankSpec`).

Register a new codec with :func:`register_codec`; ``compress(...,
codec=<name>)`` and the per-leaf auto-picker pick it up automatically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.dropout import groupwise_dropout_pack, keep_count
from repro.core.pack import PackedDelta
from repro.core import pack as pack_lib


# ---------------------------------------------------------------------------
# Specs (small frozen hyperparameter records; one per codec)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DeltaDQSpec:
    """DeltaDQ hyperparameters (group-wise dropout + separate quant)."""
    alpha: float = 8.0            # dropout compression (keep-rate 1/alpha)
    k_bits: Optional[int] = None  # None -> dropout only (paper's 2x..8x rows)
    m: int = 1                    # separate-quantization parts
    h_g: Optional[int] = None     # None -> use h_in (row-wise); search sets it
    seed: int = 0

    def ratio(self) -> float:
        return quant.compression_ratio(self.alpha, self.k_bits, self.m)


@dataclass(frozen=True)
class BitDeltaSpec:
    """BitDelta: sign bitmap + per-tensor scale = mean |delta|."""
    seed: int = 0

    def ratio(self) -> float:
        return 16.0               # 1 bit per element vs bf16


@dataclass(frozen=True)
class LowRankSpec:
    """Quantized dense core + rank-r f32 residual factors."""
    rank: int = 8
    k_bits: int = 4
    seed: int = 0


def _pick_hg(h_in: int, spec: DeltaDQSpec) -> int:
    if spec.h_g is None:
        return h_in
    # clamp to a divisor of h_in: largest halving of h_g dividing h_in.
    # Candidates below alpha are unsatisfiable (keep would round to 0 and
    # halving only shrinks hg further), so detect that up front instead
    # of walking to hg < 1 and raising a misleading divisibility error.
    floor = max(spec.alpha, 1.0)
    hg = min(spec.h_g, h_in)
    if hg < floor:
        raise ValueError(
            f"unsatisfiable group size: requested h_g={spec.h_g} "
            f"(clamped to {hg} for h_in={h_in}) is below alpha={spec.alpha}; "
            f"every group must keep h_g/alpha >= 1 elements, so pick "
            f"h_g >= alpha")
    while h_in % hg:
        hg //= 2
        if hg < floor:
            raise ValueError(
                f"unsatisfiable group size: no halving of h_g={spec.h_g} "
                f"both divides h_in={h_in} and stays >= alpha={spec.alpha}")
    return int(hg)


def _runtime_hg(h_in: int) -> int:
    """Group size for dense-as-structured runtime lowering: the largest
    divisor of h_in within the kernel envelope (h_g <= MAX_HG and, since
    these lowerings keep every element, keep = h_g <= MAX_KEEP = 128)."""
    for hg in range(min(h_in, 128), 0, -1):
        if h_in % hg == 0:
            return hg
    return 1


def _lead_scalar(lead: tuple, value, dtype):
    """Per-tensor scalar in PackedDelta convention: a scalar without
    leading stack dims, a [lead]-shaped array with them."""
    if lead:
        return jnp.full(lead, value, dtype)
    return jnp.asarray(value, dtype)


def _dense_as_structured(dense_vals: jnp.ndarray, codes: jnp.ndarray,
                         scale, zero, h_in: int, h_out: int,
                         k_bits: Optional[int], codec: str) -> PackedDelta:
    """Wrap per-group values/codes [..., G, h_g, O] as a keep-everything
    PackedDelta (idx = arange within each group)."""
    hg = dense_vals.shape[-2]
    idx = jnp.broadcast_to(
        jnp.arange(hg, dtype=jnp.uint8)[:, None], dense_vals.shape[-2:])
    idx = jnp.broadcast_to(idx, dense_vals.shape[:-2] + idx.shape)
    return PackedDelta(
        idx=idx, codes=codes, scale=scale, zero=zero,
        h_in=h_in, h_out=h_out, h_g=hg, keep=hg,
        alpha=1.0, k_bits=k_bits, m=1, codec=codec)


# ---------------------------------------------------------------------------
# Codec leaves for the non-DeltaDQ formats (registered pytrees)
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class BitDeltaLeaf:
    """BitDelta-compressed delta for one [h_in, h_out] weight.

    ``sign`` is the bit-packed (along h_in) sign bitmap, uint8
    [..., ceil(h_in/8), h_out] with bit 1 = positive; ``scale`` is the
    per-tensor mean |delta| (f32 scalar; stacked if leading dims).
    """
    sign: jnp.ndarray
    scale: Any
    h_in: int
    h_out: int

    def tree_flatten(self):
        return (self.sign, self.scale), (self.h_in, self.h_out)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def stack_shape(self) -> tuple[int, ...]:
        return tuple(self.sign.shape[:-2])


@jax.tree_util.register_pytree_node_class
@dataclass
class LowRankLeaf:
    """Quantized core + rank-r residual for one [h_in, h_out] weight.

    ``codes`` are bit-packed (along h_in) k-bit core codes, uint8
    [..., packed_len(h_in, k), h_out]; ``scale``/``zero`` the per-tensor
    quant params; ``u`` [..., h_in, r] / ``v`` [..., r, h_out] the f32
    residual factors of delta - dequant(core) (u absorbs the singular
    values). Reconstruction: dequant(core) + u @ v.
    """
    codes: jnp.ndarray
    scale: Any
    zero: Any
    u: jnp.ndarray
    v: jnp.ndarray
    h_in: int
    h_out: int
    k_bits: int
    rank: int

    def tree_flatten(self):
        return ((self.codes, self.scale, self.zero, self.u, self.v),
                (self.h_in, self.h_out, self.k_bits, self.rank))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def stack_shape(self) -> tuple[int, ...]:
        return tuple(self.codes.shape[:-2])


# ---------------------------------------------------------------------------
# The codec interface
# ---------------------------------------------------------------------------
class DeltaCodec:
    """One delta-compression format behind the common interface.

    Subclasses set ``name``, ``spec_cls`` and ``leaf_cls`` and implement
    the methods below. ``storage_bits`` returns a dict with
    ``value_bits`` (the paper's values-only convention) and
    ``total_bits`` (honest: + indices/factors/metadata) for the whole
    possibly-stacked leaf.
    """

    name: str = "?"
    spec_cls: type = object
    leaf_cls: type = object

    def default_spec(self):
        return self.spec_cls()

    # -- compression --------------------------------------------------------
    def compress_leaf(self, rng, base_leaf, ft_leaf, spec):
        raise NotImplementedError

    # -- decode -------------------------------------------------------------
    def reconstruct_dense(self, leaf) -> jnp.ndarray:
        raise NotImplementedError

    def runtime_packed(self, leaf) -> PackedDelta:
        raise NotImplementedError

    def decode_values(self, leaf) -> jnp.ndarray:
        """Kept values [..., G, K, O] of the runtime form (the
        codec-neutral seam the values-given segment dispatch consumes)."""
        return pack_lib.decode_values(self.runtime_packed(leaf))

    # -- storage ------------------------------------------------------------
    def storage_bits(self, leaf) -> dict:
        raise NotImplementedError

    def to_storage_parts(self, leaf) -> tuple[Any, dict]:
        raise NotImplementedError

    def from_storage_parts(self, parts, meta: dict):
        raise NotImplementedError

    # -- static twins (multi-pod dry-run) -----------------------------------
    def leaf_spec(self, leaf_sds, spec):
        raise NotImplementedError

    def leaf_axes(self, leaf_sds, axes, spec, model_axis_size: int):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# DeltaDQ (the first registered codec; leaf IS the runtime layout)
# ---------------------------------------------------------------------------
class DeltaDQCodec(DeltaCodec):
    name = "deltadq"
    spec_cls = DeltaDQSpec
    leaf_cls = PackedDelta

    def default_spec(self):
        # the launcher's 128x deployment point (alpha 8, k4, m8)
        return DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16)

    def compress_leaf(self, rng, base_leaf, ft_leaf, spec: DeltaDQSpec):
        delta = ft_leaf.astype(jnp.float32) - base_leaf.astype(jnp.float32)
        hg = _pick_hg(delta.shape[-2], spec)
        return groupwise_dropout_pack(rng, delta, h_g=hg, alpha=spec.alpha,
                                      k_bits=spec.k_bits, m=spec.m)

    def reconstruct_dense(self, leaf: PackedDelta) -> jnp.ndarray:
        return pack_lib.reconstruct_dense(leaf)

    def runtime_packed(self, leaf: PackedDelta) -> PackedDelta:
        return leaf

    def storage_bits(self, leaf: PackedDelta) -> dict:
        stack = int(np.prod(leaf.stack_shape())) if leaf.stack_shape() else 1
        vb = leaf.value_bits() * stack
        return {"value_bits": vb, "total_bits": vb + leaf.index_bits() * stack}

    def to_storage_parts(self, leaf: PackedDelta):
        meta = {"codec": self.name, "h_in": leaf.h_in, "h_out": leaf.h_out,
                "h_g": leaf.h_g, "keep": leaf.keep, "alpha": leaf.alpha,
                "k_bits": leaf.k_bits, "m": leaf.m,
                "scale": float(np.asarray(leaf.scale)),
                "zero": int(np.asarray(leaf.zero))}
        if leaf.k_bits is None:
            if leaf.stack_shape():
                raise ValueError(
                    "storage layer operates per-matrix; got stacked leaf "
                    f"with stack_shape={leaf.stack_shape()}")
            parts = {"idx": np.asarray(leaf.idx),
                     "values": np.asarray(leaf.codes)}
            return parts, meta
        return pack_lib.to_storage_parts(leaf), meta

    def from_storage_parts(self, parts, meta: dict) -> PackedDelta:
        if meta["k_bits"] is None:
            hg = meta["h_g"]
            idx_dtype = jnp.uint8 if hg <= 256 else jnp.int32
            return PackedDelta(
                idx=jnp.asarray(parts["idx"], idx_dtype),
                codes=jnp.asarray(parts["values"], jnp.float32),
                scale=jnp.float32(meta["scale"]),
                zero=jnp.int32(meta["zero"]),
                h_in=meta["h_in"], h_out=meta["h_out"], h_g=hg,
                keep=meta["keep"], alpha=meta["alpha"], k_bits=None,
                m=meta["m"])
        return pack_lib.from_storage_parts(
            parts, h_in=meta["h_in"], h_out=meta["h_out"], h_g=meta["h_g"],
            keep=meta["keep"], alpha=meta["alpha"], k_bits=meta["k_bits"],
            scale=meta["scale"], zero=meta["zero"])

    def leaf_spec(self, leaf_sds, spec: DeltaDQSpec) -> PackedDelta:
        shape = leaf_sds.shape
        lead, (h_in, h_out) = shape[:-2], shape[-2:]
        hg = _pick_hg(h_in, spec)
        # the same helper real packing uses (dropout._check): shape-only
        # dry-run specs can never drift from what packing actually produces
        keep = keep_count(hg, spec.alpha)
        G = h_in // hg
        idx_dtype = jnp.uint8 if hg <= 256 else jnp.int32
        if spec.k_bits is None:
            codes = jax.ShapeDtypeStruct((*lead, G, keep, h_out), jnp.float32)
        else:
            kp = quant.packed_len(keep, spec.k_bits)
            codes = jax.ShapeDtypeStruct((*lead, G, kp, h_out), jnp.uint8)
        return PackedDelta(
            idx=jax.ShapeDtypeStruct((*lead, G, keep, h_out), idx_dtype),
            codes=codes,
            scale=jax.ShapeDtypeStruct(lead, jnp.float32),
            zero=jax.ShapeDtypeStruct(lead, jnp.int32),
            h_in=h_in, h_out=h_out, h_g=hg, keep=keep,
            alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m)

    def leaf_axes(self, leaf_sds, ax, spec: DeltaDQSpec,
                  model_axis_size: int) -> PackedDelta:
        d = self.leaf_spec(leaf_sds, spec)
        lead_ax = tuple(ax[:-2])
        in_ax, out_ax = ax[-2], ax[-1]
        g_ax = in_ax if d.n_groups % max(model_axis_size, 1) == 0 else None
        arr_ax = (*lead_ax, g_ax, None, out_ax)
        return PackedDelta(
            idx=arr_ax, codes=arr_ax, scale=lead_ax, zero=lead_ax,
            h_in=d.h_in, h_out=d.h_out, h_g=d.h_g, keep=d.keep,
            alpha=d.alpha, k_bits=d.k_bits, m=d.m)


# ---------------------------------------------------------------------------
# BitDelta: sign bitmap + per-tensor scale (arXiv 2402.10193)
# ---------------------------------------------------------------------------
class BitDeltaCodec(DeltaCodec):
    name = "bitdelta"
    spec_cls = BitDeltaSpec
    leaf_cls = BitDeltaLeaf

    def compress_leaf(self, rng, base_leaf, ft_leaf,
                      spec: BitDeltaSpec) -> BitDeltaLeaf:
        delta = ft_leaf.astype(jnp.float32) - base_leaf.astype(jnp.float32)
        h_in, h_out = delta.shape[-2:]
        lead_dims = delta.ndim - 2
        scale = jnp.mean(jnp.abs(delta),
                         axis=tuple(range(lead_dims, delta.ndim)))
        sign = (delta >= 0).astype(jnp.uint8)     # 1 = +scale, 0 = -scale
        packed = quant.pack_bits(sign, 1, axis=sign.ndim - 2)
        return BitDeltaLeaf(sign=packed, scale=scale.astype(jnp.float32),
                            h_in=h_in, h_out=h_out)

    def _sign_codes(self, leaf: BitDeltaLeaf) -> jnp.ndarray:
        """Unpacked {0, 1} sign codes [..., h_in, h_out] int32."""
        return quant.unpack_bits(leaf.sign, 1, leaf.h_in,
                                 axis=leaf.sign.ndim - 2)

    def reconstruct_dense(self, leaf: BitDeltaLeaf) -> jnp.ndarray:
        # EXACTLY the runtime decode math ((q - zero) * scale with
        # q = 2*sign, zero = 1) so the lowering is bit-faithful
        q = 2 * self._sign_codes(leaf)
        s = jnp.asarray(leaf.scale, jnp.float32)
        if jnp.ndim(s):
            s = s.reshape(s.shape + (1, 1))
        return (q.astype(jnp.float32) - jnp.float32(1.0)) * s

    def runtime_packed(self, leaf: BitDeltaLeaf) -> PackedDelta:
        lead = leaf.stack_shape()
        hg = _runtime_hg(leaf.h_in)
        G = leaf.h_in // hg
        q = 2 * self._sign_codes(leaf)            # {0, 2}: (q - 1)*s = +/-s
        q = q.reshape(*lead, G, hg, leaf.h_out)
        codes = quant.pack_bits(q, 2, axis=q.ndim - 2)
        return _dense_as_structured(
            q, codes,
            scale=jnp.asarray(leaf.scale, jnp.float32),
            zero=_lead_scalar(lead, 1, jnp.int32),
            h_in=leaf.h_in, h_out=leaf.h_out, k_bits=2, codec=self.name)

    def storage_bits(self, leaf: BitDeltaLeaf) -> dict:
        stack = int(np.prod(leaf.stack_shape())) if leaf.stack_shape() else 1
        vb = 1.0 * leaf.h_in * leaf.h_out * stack
        return {"value_bits": vb, "total_bits": vb + 32.0 * stack}

    def to_storage_parts(self, leaf: BitDeltaLeaf):
        if leaf.stack_shape():
            raise ValueError(
                "storage layer operates per-matrix; got stacked leaf with "
                f"stack_shape={leaf.stack_shape()}")
        parts = {"sign": np.asarray(leaf.sign)}
        meta = {"codec": self.name, "h_in": leaf.h_in, "h_out": leaf.h_out,
                "scale": float(np.asarray(leaf.scale))}
        return parts, meta

    def from_storage_parts(self, parts, meta: dict) -> BitDeltaLeaf:
        return BitDeltaLeaf(sign=jnp.asarray(parts["sign"], jnp.uint8),
                            scale=jnp.float32(meta["scale"]),
                            h_in=meta["h_in"], h_out=meta["h_out"])

    def leaf_spec(self, leaf_sds, spec: BitDeltaSpec) -> BitDeltaLeaf:
        shape = leaf_sds.shape
        lead, (h_in, h_out) = shape[:-2], shape[-2:]
        return BitDeltaLeaf(
            sign=jax.ShapeDtypeStruct(
                (*lead, quant.packed_len(h_in, 1), h_out), jnp.uint8),
            scale=jax.ShapeDtypeStruct(lead, jnp.float32),
            h_in=h_in, h_out=h_out)

    def leaf_axes(self, leaf_sds, ax, spec: BitDeltaSpec,
                  model_axis_size: int) -> BitDeltaLeaf:
        d = self.leaf_spec(leaf_sds, spec)
        lead_ax = tuple(ax[:-2])
        out_ax = ax[-1]
        return BitDeltaLeaf(sign=(*lead_ax, None, out_ax), scale=lead_ax,
                            h_in=d.h_in, h_out=d.h_out)


# ---------------------------------------------------------------------------
# Low-rank residual: quantized dense core + rank-r f32 factors
# ---------------------------------------------------------------------------
class LowRankCodec(DeltaCodec):
    name = "lowrank"
    spec_cls = LowRankSpec
    leaf_cls = LowRankLeaf

    def compress_leaf(self, rng, base_leaf, ft_leaf,
                      spec: LowRankSpec) -> LowRankLeaf:
        delta = ft_leaf.astype(jnp.float32) - base_leaf.astype(jnp.float32)
        h_in, h_out = delta.shape[-2:]
        lead = delta.shape[:-2]
        q, qp = quant.quantize(delta, spec.k_bits, lead_dims=len(lead))
        core = self._dequant_core(q, qp.scale, qp.zero, len(lead))
        # residual factors via numpy SVD: compression is offline, the SVD
        # never traces (matching the storage layer's numpy-only rule)
        resid = np.asarray(delta - core)
        flat = resid.reshape((-1,) + resid.shape[-2:])
        r = spec.rank
        us = np.zeros((flat.shape[0], h_in, r), np.float32)
        vs = np.zeros((flat.shape[0], r, h_out), np.float32)
        for i, mat in enumerate(flat):
            U, S, Vt = np.linalg.svd(mat, full_matrices=False)
            k = min(r, S.shape[0])
            us[i, :, :k] = U[:, :k] * S[:k]       # u absorbs singular values
            vs[i, :k, :] = Vt[:k]
        codes = quant.pack_bits(q, quant.pack_width(spec.k_bits),
                                axis=q.ndim - 2)
        return LowRankLeaf(
            codes=codes, scale=qp.scale, zero=qp.zero,
            u=jnp.asarray(us.reshape(*lead, h_in, r)),
            v=jnp.asarray(vs.reshape(*lead, r, h_out)),
            h_in=h_in, h_out=h_out, k_bits=spec.k_bits, rank=r)

    @staticmethod
    def _dequant_core(q, scale, zero, lead_dims: int) -> jnp.ndarray:
        s = jnp.asarray(scale, jnp.float32).reshape(
            jnp.shape(scale) + (1, 1)) if lead_dims \
            else jnp.asarray(scale, jnp.float32)
        z = jnp.asarray(zero, jnp.float32).reshape(
            jnp.shape(zero) + (1, 1)) if lead_dims \
            else jnp.asarray(zero, jnp.float32)
        return (q.astype(jnp.float32) - z) * s

    def reconstruct_dense(self, leaf: LowRankLeaf) -> jnp.ndarray:
        q = quant.unpack_bits(leaf.codes, quant.pack_width(leaf.k_bits),
                              leaf.h_in, axis=leaf.codes.ndim - 2)
        core = self._dequant_core(q, leaf.scale, leaf.zero,
                                  len(leaf.stack_shape()))
        return core + leaf.u @ leaf.v

    def runtime_packed(self, leaf: LowRankLeaf) -> PackedDelta:
        # dense-as-structured f32 values (k_bits=None: decode is the
        # identity), computed ONCE at conversion time by the exact same
        # reconstruction the reference path uses — bit-faithful
        lead = leaf.stack_shape()
        hg = _runtime_hg(leaf.h_in)
        G = leaf.h_in // hg
        vals = self.reconstruct_dense(leaf).reshape(*lead, G, hg, leaf.h_out)
        return _dense_as_structured(
            vals, vals,
            scale=_lead_scalar(lead, 1.0, jnp.float32),
            zero=_lead_scalar(lead, 0, jnp.int32),
            h_in=leaf.h_in, h_out=leaf.h_out, k_bits=None, codec=self.name)

    def storage_bits(self, leaf: LowRankLeaf) -> dict:
        stack = int(np.prod(leaf.stack_shape())) if leaf.stack_shape() else 1
        vb = (leaf.k_bits * leaf.h_in * leaf.h_out
              + 32.0 * leaf.rank * (leaf.h_in + leaf.h_out)) * stack
        return {"value_bits": vb, "total_bits": vb + 64.0 * stack}

    def to_storage_parts(self, leaf: LowRankLeaf):
        if leaf.stack_shape():
            raise ValueError(
                "storage layer operates per-matrix; got stacked leaf with "
                f"stack_shape={leaf.stack_shape()}")
        parts = {"codes": np.asarray(leaf.codes),
                 "u": np.asarray(leaf.u), "v": np.asarray(leaf.v)}
        meta = {"codec": self.name, "h_in": leaf.h_in, "h_out": leaf.h_out,
                "k_bits": leaf.k_bits, "rank": leaf.rank,
                "scale": float(np.asarray(leaf.scale)),
                "zero": int(np.asarray(leaf.zero))}
        return parts, meta

    def from_storage_parts(self, parts, meta: dict) -> LowRankLeaf:
        return LowRankLeaf(
            codes=jnp.asarray(parts["codes"], jnp.uint8),
            scale=jnp.float32(meta["scale"]), zero=jnp.int32(meta["zero"]),
            u=jnp.asarray(parts["u"], jnp.float32),
            v=jnp.asarray(parts["v"], jnp.float32),
            h_in=meta["h_in"], h_out=meta["h_out"],
            k_bits=meta["k_bits"], rank=meta["rank"])

    def leaf_spec(self, leaf_sds, spec: LowRankSpec) -> LowRankLeaf:
        shape = leaf_sds.shape
        lead, (h_in, h_out) = shape[:-2], shape[-2:]
        return LowRankLeaf(
            codes=jax.ShapeDtypeStruct(
                (*lead, quant.packed_len(h_in, spec.k_bits), h_out),
                jnp.uint8),
            scale=jax.ShapeDtypeStruct(lead, jnp.float32),
            zero=jax.ShapeDtypeStruct(lead, jnp.int32),
            u=jax.ShapeDtypeStruct((*lead, h_in, spec.rank), jnp.float32),
            v=jax.ShapeDtypeStruct((*lead, spec.rank, h_out), jnp.float32),
            h_in=h_in, h_out=h_out, k_bits=spec.k_bits, rank=spec.rank)

    def leaf_axes(self, leaf_sds, ax, spec: LowRankSpec,
                  model_axis_size: int) -> LowRankLeaf:
        d = self.leaf_spec(leaf_sds, spec)
        lead_ax = tuple(ax[:-2])
        in_ax, out_ax = ax[-2], ax[-1]
        return LowRankLeaf(
            codes=(*lead_ax, None, out_ax), scale=lead_ax, zero=lead_ax,
            u=(*lead_ax, in_ax, None), v=(*lead_ax, None, out_ax),
            h_in=d.h_in, h_out=d.h_out, k_bits=d.k_bits, rank=d.rank)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_CODECS: dict[str, DeltaCodec] = {}
DEFAULT_CODEC = "deltadq"


def register_codec(codec: DeltaCodec) -> DeltaCodec:
    """Register a codec instance under ``codec.name`` (idempotent for the
    same instance; raises on a name collision with a different one)."""
    prev = _CODECS.get(codec.name)
    if prev is not None and prev is not codec:
        raise ValueError(f"codec {codec.name!r} is already registered")
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> DeltaCodec:
    try:
        return _CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; registered: "
                       f"{sorted(_CODECS)}") from None


def codec_names() -> list[str]:
    """Registered codec names in registration order."""
    return list(_CODECS)


def codec_for_spec(spec) -> DeltaCodec:
    """The codec owning a spec instance (by spec class)."""
    for c in _CODECS.values():
        if isinstance(spec, c.spec_cls):
            return c
    raise TypeError(f"no registered codec accepts spec {type(spec).__name__}")


def codec_of_leaf(leaf) -> DeltaCodec:
    """The codec owning a compressed leaf (PackedDelta carries its codec
    tag; other leaf types resolve by class)."""
    if isinstance(leaf, PackedDelta):
        return get_codec(leaf.codec)
    for c in _CODECS.values():
        if type(leaf) is c.leaf_cls:
            return c
    raise TypeError(f"no registered codec owns leaf {type(leaf).__name__}")


def is_codec_leaf(x) -> bool:
    return isinstance(x, tuple(c.leaf_cls for c in _CODECS.values()))


def reconstruct_dense_any(leaf) -> jnp.ndarray:
    """Dense f32 delta for any registered codec's leaf (incl. runtime
    PackedDelta forms)."""
    if isinstance(leaf, PackedDelta):
        return pack_lib.reconstruct_dense(leaf)
    return codec_of_leaf(leaf).reconstruct_dense(leaf)


def runtime_packed_leaf(leaf) -> PackedDelta:
    """Lower one codec leaf to the PackedDelta runtime layout (identity
    on PackedDelta)."""
    if isinstance(leaf, PackedDelta):
        return leaf
    return codec_of_leaf(leaf).runtime_packed(leaf)


def runtime_delta_tree(tree: Any) -> Any:
    """Lower every codec leaf of a deltas tree to its runtime PackedDelta
    form (idempotent). The serving engines call this at tenant
    registration, so model/kernel code only ever sees PackedDelta."""
    return jax.tree.map(runtime_packed_leaf, tree, is_leaf=is_codec_leaf)


register_codec(DeltaDQCodec())
register_codec(BitDeltaCodec())
register_codec(LowRankCodec())
