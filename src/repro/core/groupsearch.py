"""Optimal group-size search (paper §3.3, Table 4).

Candidates: h_g in {alpha, alpha*2, alpha*4, ..., h_in}. Two selectors:

* ``search_direct``  — compress the whole model at each candidate and score
  the true downstream objective (eval loss / accuracy). Expensive.
* ``search_proxy``   — the paper's proxy: compress only the first layer's
  Q/K projections and score the attention-matrix error
  ``||Q1 K1^T - Q1_hat K1_hat^T||^2`` on ~1% calibration data (Eq. 5).
  All layers share one h_g*; shallow layers are most compression-sensitive,
  so layer 1 is the probe.

Attention-free archs (DESIGN.md §4): mamba2 uses the SSD score matrix
``C B^T`` of layer 1 as the proxy feature; recurrentgemma probes its first
*attention* layer (index 2 in the rec,rec,attn pattern).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.compress import DeltaDQSpec
from repro.core.dropout import groupwise_dropout_pack
from repro.core.pack import reconstruct_dense


def candidate_group_sizes(h_in: int, alpha: float) -> list[int]:
    out, hg = [], int(alpha)
    while hg <= h_in:
        if h_in % hg == 0:
            out.append(hg)
        hg *= 2
    if not out or out[-1] != h_in:
        out.append(h_in)
    return out


def attention_proxy_error(x: jnp.ndarray,
                          wq_b: jnp.ndarray, wk_b: jnp.ndarray,
                          wq_f: jnp.ndarray, wk_f: jnp.ndarray,
                          h_g: int, spec: DeltaDQSpec, rng,
                          head_dim: Optional[int] = None) -> jnp.ndarray:
    """||Q K^T - Qhat Khat^T||^2 with layer-1 deltas compressed at h_g.

    GQA-aware: when q_dim != kv_dim (or head_dim is given), scores are
    computed per head with KV heads broadcast to their query groups.
    """
    dq = (wq_f - wq_b).astype(jnp.float32)
    dk = (wk_f - wk_b).astype(jnp.float32)
    r1, r2 = jax.random.split(rng)
    pq = groupwise_dropout_pack(r1, dq, h_g=h_g, alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m)
    pk = groupwise_dropout_pack(r2, dk, h_g=h_g, alpha=spec.alpha, k_bits=spec.k_bits, m=spec.m)
    x = x.astype(jnp.float32)
    q = x @ (wq_b + dq)
    k = x @ (wk_b + dk)
    qh = x @ (wq_b + reconstruct_dense(pq))
    kh = x @ (wk_b + reconstruct_dense(pk))

    q_dim, kv_dim = q.shape[-1], k.shape[-1]
    if head_dim is None and q_dim != kv_dim:
        head_dim = math.gcd(q_dim, kv_dim)

    def scores(qm, km):
        if head_dim is None:
            return jnp.einsum("td,sd->ts", qm, km)
        t = qm.shape[0]
        qs = qm.reshape(t, q_dim // head_dim, head_dim)
        ks = km.reshape(t, kv_dim // head_dim, head_dim)
        ks = jnp.repeat(ks, q_dim // kv_dim, axis=1)
        return jnp.einsum("thd,shd->hts", qs, ks)

    return jnp.sum((scores(q, k) - scores(qh, kh)) ** 2)


@dataclass
class SearchResult:
    h_g_star: int
    errors: dict           # h_g -> score (proxy error or direct loss)
    seconds: float
    method: str


def search_proxy(x_calib: jnp.ndarray,
                 wq_b, wk_b, wq_f, wk_f,
                 spec: DeltaDQSpec,
                 rng=None,
                 candidates: Sequence[int] | None = None) -> SearchResult:
    """Pick h_g* minimizing the attention proxy error on calibration input.

    ``x_calib``: [t, d_model] layer-1 inputs for ~1% of the eval set.
    """
    if rng is None:
        rng = jax.random.PRNGKey(spec.seed)
    h_in = wq_b.shape[0]
    cands = list(candidates) if candidates else candidate_group_sizes(h_in, spec.alpha)
    t0 = time.perf_counter()
    errs = {}
    for hg in cands:
        errs[hg] = float(attention_proxy_error(x_calib, wq_b, wk_b, wq_f, wk_f,
                                               hg, spec, jax.random.fold_in(rng, hg)))
    best = min(errs, key=errs.get)
    return SearchResult(best, errs, time.perf_counter() - t0, "proxy")


def search_direct(score_fn: Callable[[int], float],
                  h_in: int, spec: DeltaDQSpec,
                  candidates: Sequence[int] | None = None) -> SearchResult:
    """Direct search: ``score_fn(h_g)`` returns a loss to minimize (e.g. full
    eval loss of the compressed model). The paper's expensive reference."""
    cands = list(candidates) if candidates else candidate_group_sizes(h_in, spec.alpha)
    t0 = time.perf_counter()
    errs = {hg: float(score_fn(hg)) for hg in cands}
    best = min(errs, key=errs.get)
    return SearchResult(best, errs, time.perf_counter() - t0, "direct")
