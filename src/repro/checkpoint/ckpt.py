"""Checkpointing: sharded save/restore with elastic re-meshing.

* One ``.npz`` per checkpoint holding every leaf by path + a msgpack
  manifest (step, data cursor, RNG, mesh shape) — all state needed to
  resume bit-exactly.
* **Async save**: arrays are fetched to host synchronously (cheap), the
  file write happens on a background thread; ``wait()`` fences before the
  next save or exit.
* **Elastic restore**: leaves are re-placed with ``jax.device_put`` against
  whatever mesh/sharding the *new* job provides — a checkpoint written on a
  (16,16) mesh restores onto (8,32), (2,16,16), or 1 CPU device unchanged.
  This is the restart/elastic-rescale path of DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils import flatten_with_paths

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class Checkpointer:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> str:
        self.wait()
        flat = flatten_with_paths(state)
        host = {}
        dtypes = {}
        for k, v in flat.items():
            if v is None:
                continue
            arr = np.asarray(jax.device_get(v))
            # npz cannot round-trip ml_dtypes (bf16 etc.): store the raw bits
            if arr.dtype.kind == "V" or not arr.dtype.isnative or \
                    arr.dtype.name not in np.sctypeDict:
                dtypes[k] = arr.dtype.name
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            host[k] = arr
        path = os.path.join(self.directory, f"step_{step:08d}")
        manifest = {"step": step, "extra": extra or {},
                    "leaves": sorted(host.keys()), "bit_dtypes": dtypes}

        def write():
            os.makedirs(path, exist_ok=True)
            # atomic-ish: write to tmp then rename
            with tempfile.NamedTemporaryFile(dir=path, delete=False, suffix=".tmp") as f:
                np.savez(f, **host)
                tmp = f.name
            os.replace(tmp, os.path.join(path, _ARRAYS))
            with open(os.path.join(path, _MANIFEST), "w") as f:
                json.dump(manifest, f)

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.directory)
                 if d.startswith("step_") and
                 os.path.exists(os.path.join(self.directory, d, _MANIFEST))]
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template`` (arrays or SDS).

        ``shardings``: optional pytree (same structure) of NamedSharding —
        this is where elastic re-meshing happens: whatever mesh the new job
        built, leaves are device_put against it.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, _ARRAYS))

        flat_t = flatten_with_paths(template)
        flat_s = flatten_with_paths(shardings) if shardings is not None else {}

        bit_dtypes = manifest.get("bit_dtypes", {})
        out = {}
        for k, tmpl in flat_t.items():
            if tmpl is None:
                out[k] = None
                continue
            arr = data[k]
            if k in bit_dtypes:
                import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
                arr = arr.view(np.dtype(bit_dtypes[k]))
            sh = flat_s.get(k)
            if sh is not None:
                out[k] = jax.device_put(arr.astype(tmpl.dtype), sh)
            else:
                out[k] = jax.numpy.asarray(arr, dtype=tmpl.dtype)
        restored = _unflatten_like(template, out)
        return restored, manifest

    def restore_manifest(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, _MANIFEST)) as f:
            return json.load(f)


def _unflatten_like(template: Any, flat: dict, prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/") for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_like(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(vals) if not hasattr(template, "_fields") else type(template)(*vals)
    key = prefix[:-1]
    return flat.get(key)
