import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on placeholder devices that the production
sharding is coherent: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()``
must succeed on the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh,
and the compiled artifact yields the memory analysis + roofline terms
recorded in EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k --mesh pod --out results/

Shapes (assigned): train_4k (train_step), prefill_32k (prefill),
decode_32k / long_500k (serve_step = one token against a seq-long cache).
long_500k only runs for sub-quadratic archs (DESIGN.md §4).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.arch import ArchConfig
from repro.core.compress import DeltaDQSpec, delta_axes, delta_specs
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.roofline import analysis as roofline
from repro.train import make_train_step
from repro.utils import tree_bytes

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}

# serving dry-runs lower the technique-representative path: base + one
# tenant's packed delta at the paper's flagship 128x setting
SERVE_DELTA = DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=128)


def pick_n_micro(cfg: ArchConfig, batch: int, dp: int) -> int:
    per_dev = batch // dp
    n = cfg.n_params()
    if n > 5e10:
        target = 8
    elif n > 5e9:
        target = 4
    elif n > 1e9:
        target = 2
    else:
        target = 1
    while per_dev % target or batch % target:
        target //= 2
    return max(target, 1)


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.param_dtype)
    if info["kind"] in ("train", "prefill"):
        if cfg.family == "encdec":
            return {"tokens": jax.ShapeDtypeStruct((B, S // 2), i32),
                    "enc_feats": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), bf16)}
        if cfg.family == "vlm":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "image_embeds": jax.ShapeDtypeStruct(
                        (B, cfg.n_frontend_tokens, cfg.d_model), bf16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: single new token against a seq-long cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def _rules_for(mesh, kind: str, shape: str) -> shd.ShardingRules:
    rules = shd.ShardingRules(mesh)
    if kind == "train":
        return rules.with_overrides(**shd.TRAIN_OVERRIDES)
    if shape == "long_500k":
        return rules.with_overrides(**{**shd.SERVE_OVERRIDES,
                                       **shd.LONG_CONTEXT_OVERRIDES})
    return rules.with_overrides(**shd.SERVE_OVERRIDES)


def _tokens_of(cfg, shape) -> int:
    info = SHAPES[shape]
    if info["kind"] in ("train", "prefill"):
        s = info["seq"] // 2 if cfg.family == "encdec" else info["seq"]
        return info["batch"] * s
    return info["batch"]  # one token per row


def analytic_attention_flops(cfg: ArchConfig, batch: int, seq: int,
                             kind: str, n_devices: int) -> float:
    """Causal-attention FLOPs the q-block scan hides from cost_analysis.

    QK^T + PV = 4 MACs per (query, key, head_dim, head) pair; causal and
    window masks halve/bound the pair count. Training multiplies by 4
    (forward + remat forward + ~2x backward). Per device (batch+heads
    spread over the mesh; conservative: divide by n_devices).
    """
    total = 0.0
    for w in cfg.layer_windows:
        s_eff = min(w, seq) if w else seq
        pairs = batch * (seq * s_eff - (s_eff * (s_eff - 1)) // 2 if w
                         else seq * (seq + 1) // 2)
        total += 4.0 * pairs * cfg.head_dim * cfg.n_heads
    # encdec: counts the decoder stack only (encoder/cross are same-order;
    # documented undercount in EXPERIMENTS.md)
    return total * (4.0 if kind == "train" else 1.0) / n_devices


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    skip_reason: Optional[str] = None
    error: Optional[str] = None
    memory: Optional[dict] = None
    roofline: Optional[dict] = None
    collectives: Optional[dict] = None
    notes: Optional[dict] = None


def lower_cell(arch: str, shape: str, multi_pod: bool,
               use_delta: bool = True, rules_overrides: Optional[dict] = None,
               n_micro: Optional[int] = None,
               want_text: bool = False) -> CellResult:
    t0 = time.time()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    info = SHAPES[shape]
    if shape == "long_500k" and not cfg.subquadratic:
        return CellResult(arch, shape, mesh_name, ok=True, seconds=0.0,
                          skip_reason="pure full attention (DESIGN.md §4)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _rules_for(mesh, info["kind"], shape)
    if rules_overrides:
        rules = rules.with_overrides(**rules_overrides)
    n_dev = int(np.prod(list(mesh.shape.values())))

    p_specs = lm.param_specs(cfg)
    p_axes = lm.param_axes(cfg)
    p_sh = shd.tree_shardings(rules, p_specs, p_axes)

    batch_specs = input_specs(cfg, shape)
    b_axes = shd.batch_axes(batch_specs)
    b_sh = shd.tree_shardings(rules, batch_specs, b_axes)

    notes = {"n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
             "param_bytes_global": tree_bytes(p_specs)}

    # H2: reshard-for-lookup embedding (EXPERIMENTS.md §Perf)
    lm.set_embed_gather_reshard(True)
    with mesh:
        if info["kind"] == "train":
            # roofline fidelity: unroll layers so SPMD doesn't hide scan trip
            # counts from cost_analysis (EXPERIMENTS.md §Perf, fix M1)
            lm.set_force_loop(True)
            dp = mesh.shape.get("pod", 1) * mesh.shape["data"]
            nm = n_micro or pick_n_micro(cfg, info["batch"], dp)
            notes["n_micro"] = nm
            from repro.optim.adamw import AdamWConfig
            step = make_train_step(cfg, AdamWConfig(), n_micro=nm, remat=True)
            zaxes = ("pod", "data") if multi_pod else ("data",)
            o_sh = {"m": shd.zero1_shardings(rules, p_specs, p_axes, zaxes),
                    "v": shd.zero1_shardings(rules, p_specs, p_axes, zaxes),
                    "master": shd.zero1_shardings(rules, p_specs, p_axes, zaxes),
                    "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
            rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, None))
            lowered = jf.lower(p_specs, {**adamw.state_specs(p_specs)}, batch_specs, rng_spec)
        elif info["kind"] == "prefill":
            d_specs = delta_specs(p_specs, SERVE_DELTA) if use_delta else None
            d_sh = (shd.tree_shardings(
                rules, d_specs,
                delta_axes(p_specs, p_axes, SERVE_DELTA, mesh.shape["model"]))
                if use_delta else None)
            cache = lm.cache_specs(cfg, info["batch"], info["seq"],
                                   enc_len=info["seq"] // 2 if cfg.family == "encdec" else 0)
            c_sh = shd.tree_shardings(rules, cache, shd.cache_axes(cache))

            def fn(params, deltas, batch, cache):
                return lm.prefill(cfg, params, batch, cache, deltas=deltas)

            jf = jax.jit(fn, in_shardings=(p_sh, d_sh, b_sh, c_sh))
            lowered = jf.lower(p_specs, d_specs, batch_specs, cache)
        else:  # decode
            d_specs = delta_specs(p_specs, SERVE_DELTA) if use_delta else None
            d_sh = (shd.tree_shardings(
                rules, d_specs,
                delta_axes(p_specs, p_axes, SERVE_DELTA, mesh.shape["model"]))
                if use_delta else None)
            enc_len = info["seq"] // 2 if cfg.family == "encdec" else 0
            dec_seq = info["seq"] // 2 if cfg.family == "encdec" else info["seq"]
            cache = lm.cache_specs(cfg, info["batch"], dec_seq, enc_len=enc_len)
            c_sh = shd.tree_shardings(rules, cache, shd.cache_axes(cache))

            def fn(params, deltas, cache, tokens, pos):
                return lm.decode_step(cfg, params, cache, tokens, pos, deltas=deltas)

            jf = jax.jit(fn, in_shardings=(p_sh, d_sh, c_sh, b_sh["tokens"], None))
            lowered = jf.lower(p_specs, d_specs, cache,
                               batch_specs["tokens"], jax.ShapeDtypeStruct((), jnp.int32))

        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        memory = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        lm.set_force_loop(False)
        text = compiled.as_text()
        rl = roofline.from_compiled(
            compiled, text, info["kind"],
            notes["n_params"], notes["n_active"], _tokens_of(cfg, shape), n_dev)
        coll = roofline.collective_bytes(text)
        notes["fallbacks"] = rules.fallbacks[:40]

        # --- measurement corrections (documented in EXPERIMENTS.md §Perf) ---
        # M2: the microbatch scan body is counted once by cost_analysis under
        #     SPMD; scale body terms by n_micro (optimizer traffic excluded).
        # M3: the attention q-block scan likewise hides (trips-1)/trips of
        #     attention FLOPs; add the analytic causal-attention count.
        nm = notes.get("n_micro", 1)
        if info["kind"] in ("train", "prefill"):
            opt_bytes = 28.0 * notes["n_params"] / n_dev if info["kind"] == "train" else 0.0
            rl.flops = rl.flops * nm
            rl.bytes_accessed = (rl.bytes_accessed - opt_bytes) * nm + opt_bytes
            rl.coll_bytes = rl.coll_bytes * nm
            seq = SHAPES[shape]["seq"] // (2 if cfg.family == "encdec" else 1)
            rl.flops += analytic_attention_flops(
                cfg, SHAPES[shape]["batch"] // nm, seq, info["kind"], n_dev) * nm
        rl_dict = rl.to_dict()
        # memory_frac: ideal HBM traffic (read args + write outs once) over
        # actual bytes accessed — the score that matters for memory-bound cells
        ideal = float((memory["argument_bytes"] or 0) + (memory["output_bytes"] or 0))
        rl_dict["memory_frac"] = min(1.0, ideal / rl.bytes_accessed) if rl.bytes_accessed else None
        res = CellResult(arch, shape, mesh_name, ok=True, seconds=time.time() - t0,
                         memory=memory, roofline=rl_dict, collectives=coll,
                         notes=notes)
        if want_text:
            res.notes["hlo_text"] = text
        return res


def run_cell(arch, shape, multi_pod, out_dir=None, **kw) -> CellResult:
    try:
        res = lower_cell(arch, shape, multi_pod, **kw)
    except Exception as e:  # failure here = a bug in our sharding config
        res = CellResult(arch, shape, "2x16x16" if multi_pod else "16x16",
                         ok=False, seconds=0.0,
                         error=f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}")
    finally:
        lm.set_force_loop(False)
        lm.set_embed_gather_reshard(False)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{res.mesh}"
        payload = dataclasses.asdict(res)
        if payload.get("notes"):
            payload["notes"].pop("hlo_text", None)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(payload, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-delta", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                res = run_cell(arch, shape, mp, out_dir=args.out,
                               use_delta=not args.no_delta)
                status = ("SKIP " + res.skip_reason) if res.skip_reason else \
                    ("ok" if res.ok else "FAIL")
                extra = ""
                if res.roofline:
                    extra = (f" bottleneck={res.roofline['bottleneck']}"
                             f" frac={res.roofline['roofline_frac']:.3f}")
                print(f"[{status}] {tag} ({res.seconds:.0f}s){extra}", flush=True)
                if not res.ok:
                    print(res.error)


if __name__ == "__main__":
    main()
