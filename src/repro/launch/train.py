"""Production training launcher.

Ties together: arch configs, mesh + sharding rules (FSDP/TP/ZeRO-1),
deterministic data, AdamW, microbatching, optional int8 compressed gradient
all-reduce, periodic async checkpointing and crash-restart resume.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --batch 8 --seq 128 --data 2 --model 1 \
        --ckpt-dir /tmp/run1 [--resume] [--grad-compress]

On the CPU container this runs the smoke config by default; pass
``--full`` to use the production config (real-cluster usage).
"""
import argparse
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data import PretrainMixture
from repro.dist import ShardingRules, tree_shardings, zero1_shardings
from repro.dist.sharding import TRAIN_OVERRIDES
from repro.models import lm
from repro.optim import adamw, schedule
from repro.optim.adamw import AdamWConfig
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true", help="production config (not smoke)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--data", type=int, default=1, help="data-parallel mesh size")
    ap.add_argument("--model", type=int, default=1, help="model-parallel mesh size")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback compressed DP all-reduce")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    rules = ShardingRules(mesh).with_overrides(**TRAIN_OVERRIDES)

    p_specs, p_axes = lm.param_specs(cfg), lm.param_axes(cfg)
    p_sh = tree_shardings(rules, p_specs, p_axes)
    o_sh = {
        "m": zero1_shardings(rules, p_specs, p_axes),
        "v": zero1_shardings(rules, p_specs, p_axes),
        "master": zero1_shardings(rules, p_specs, p_axes),
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }

    data = PretrainMixture(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    opt_cfg = AdamWConfig(lr=args.lr,
                          schedule=schedule.cosine_with_warmup(
                              max(args.steps // 20, 1), args.steps))
    grad_transform = None
    if args.grad_compress and args.data > 1:
        from repro.dist import make_compressed_allreduce
        grad_transform = make_compressed_allreduce(mesh, "data")
    step_fn = make_train_step(cfg, opt_cfg, n_micro=args.n_micro, remat=True,
                              grad_transform=grad_transform)

    with mesh:
        params = jax.tree.map(lambda a, s: jax.device_put(a, s),
                              lm.init_params(cfg, jax.random.PRNGKey(0)), p_sh)
        # moments/master inherit the param layout at init; re-place them on
        # the ZeRO-1 layout (data-sharded free dims) the jit expects
        opt = jax.tree.map(lambda a, s: jax.device_put(a, s),
                           adamw.init(params), o_sh)
        start = 0
        ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if args.resume and ck and ck.latest_step() is not None:
            state, man = ck.restore({"params": params, "opt": opt},
                                    shardings={"params": p_sh, "opt": o_sh})
            params, opt, start = state["params"], state["opt"], man["extra"]["data_step"]
            print(f"resumed from step {start}")

        # pin outputs too: params/opt must round-trip on their layouts, or
        # step i+1 sees different committed shardings than step i
        jf = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None, None),
                     out_shardings=(p_sh, o_sh, None))
        t0 = time.time()
        tokens = 0
        for i in range(start, args.steps):
            params, opt, m = jf(params, opt, data.batch_at(i), jax.random.PRNGKey(i))
            tokens += args.batch * args.seq
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
                      f"tok/s {tokens / max(dt, 1e-9):.0f}", flush=True)
            if ck and (i + 1) % args.ckpt_every == 0:
                ck.save(i + 1, {"params": params, "opt": opt},
                        extra={"data_step": i + 1}, blocking=False)
        if ck:
            ck.wait()
            ck.save(args.steps, {"params": params, "opt": opt},
                    extra={"data_step": args.steps})
    print("done")


if __name__ == "__main__":
    main()
