"""Production serving launcher: base model + N DeltaDQ tenants.

Loads (or synthesizes) fine-tuned variants, compresses their deltas at the
requested ratio, and drives a mixed, staggered request stream through the
continuous-batching engine — the deployment of paper Fig. 2 as a runnable
process, now with slot-level scheduling and per-tenant metrics.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --tenants 3 --ratio 128 --requests 12 --slots 8

Multi-device (tensor-parallel base + replicated packed deltas; on CPU
the devices are faked, which is exactly how the CI multi-device job
runs it):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --tenants 2 --requests 4 --devices 8
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import CompileBudgetError, CompileGuard
from repro.configs import get_config, get_smoke_config
from repro.core import BitDeltaSpec, DeltaDQSpec, compress
from repro.models import lm
from repro.serve import ContinuousEngine
from repro.utils import tree_bytes

RATIO_SPECS = {
    8: DeltaDQSpec(alpha=8.0, k_bits=None, h_g=16),
    16: DeltaDQSpec(alpha=8.0, k_bits=8, m=1, h_g=16),
    32: DeltaDQSpec(alpha=8.0, k_bits=4, m=1, h_g=16),
    64: DeltaDQSpec(alpha=8.0, k_bits=4, m=4, h_g=16),
    128: DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16),
}


def synth_tenants(cfg, base, n, spec, rng, *, budget_bits=None):
    """Synthesize n fine-tuned variants and compress their deltas.

    ``spec`` may be a single codec spec (all tenants identical), a list
    of n per-tenant specs (mixed-codec fleets), or a codec-name string
    (``"deltadq"``/``"bitdelta"``/``"lowrank"``/``"auto"``; ``"auto"``
    takes ``budget_bits``).
    """
    specs = spec if isinstance(spec, list) else [spec] * n
    if len(specs) != n:
        raise ValueError(f"{len(specs)} codec specs for {n} tenants")
    out = []
    for t in range(n):
        ft = jax.tree.map(
            lambda p, t=t: p + 0.02 * jax.random.normal(
                jax.random.fold_in(rng, 7 + t), p.shape, jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        kw = {}
        if isinstance(specs[t], str):
            kw = {"codec": specs[t]}
            if specs[t] == "auto":
                kw["budget_bits"] = budget_bits
            out.append((f"tenant{t}", *compress(base, ft, **kw)))
        else:
            out.append((f"tenant{t}", *compress(base, ft, specs[t])))
    return out


def _tenant_specs(args) -> list:
    """Per-tenant spec list for --codec; 'mixed' alternates codecs."""
    if args.codec == "deltadq":
        return [RATIO_SPECS[args.ratio]] * args.tenants
    if args.codec == "mixed":
        # alternate codecs across the fleet: even rows keep the DeltaDQ
        # ratio spec, odd rows ship BitDelta — two codec groups served
        # by one engine
        return [RATIO_SPECS[args.ratio] if t % 2 == 0 else BitDeltaSpec()
                for t in range(args.tenants)]
    return [args.codec] * args.tenants        # codec-name strings


def run_lifecycle(args, cfg, base, rng):
    """Online-lifecycle drill: the fleet registers INTO a running engine.

    tenant0 is compressed and registered up front and starts serving;
    tenants 1..N-1 then arrive as raw checkpoints mid-traffic and are
    compressed + hot-registered by the DeltaRegistry while tenant0's
    sequences keep decoding. Afterwards tenant0 rolls out a v2 (new
    requests only) and tenant1 is retired. The whole drill must not
    retrace the decode step. With ``--check-identity`` every request is
    also gated token-identical against engines built with the same
    tenant set up front — registration time must never change tokens.
    """
    from repro.serve import DeltaRegistry, VirtualClock

    spec = RATIO_SPECS[args.ratio]
    n = args.tenants

    def ft_of(seed):
        return jax.tree.map(
            lambda p: p + 0.02 * jax.random.normal(
                jax.random.fold_in(rng, seed), p.shape,
                jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)

    fts = [ft_of(7 + t) for t in range(n)]      # v1 fleet
    ft_v2 = ft_of(777)                          # tenant0's rollout
    stream = []
    for i in range(args.requests):
        L = 4 + (i % 3) * 4
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
        stream.append((f"tenant{i % n}", prompt))

    # +1 row so the rollout lands without evicting anyone
    eng = ContinuousEngine(cfg, base, n_slots=args.slots,
                           max_seq=args.max_seq, tenant_capacity=n + 1,
                           clock=VirtualClock(tick=1e-3))
    reg = DeltaRegistry(eng, base, spec=spec, codec=None)

    reg.ingest("tenant0", fts[0]); reg.pump()
    phase_a = [(i, reg.submit(t, p, max_new_tokens=args.max_new))
               for i, (t, p) in enumerate(stream) if t == "tenant0"]
    for _ in range(2):
        eng.step(eng._now())            # tenant0 genuinely in flight
    # Warmup done — from here the decode step must never retrace.
    # CompileGuard (repro.analysis) is the one recompile-detection
    # implementation; strict mode additionally raises AT the retracing
    # call instead of at the end-of-drill check.
    guard = CompileGuard(eng, max_new={"decode": 0},
                         strict=args.strict_compile,
                         label="lifecycle").attach()
    for t in range(1, n):
        name = f"tenant{t}"
        reg.ingest(name, fts[t]); reg.pump()
        rec = reg._records[name]
        print(f"hot-registered {name}: compress {rec.compress_s:.2f}s, "
              f"register {1e3 * rec.register_s:.1f}ms", flush=True)
        phase_a += [(i, reg.submit(tn, p, max_new_tokens=args.max_new))
                    for i, (tn, p) in enumerate(stream) if tn == name]
        eng.step(eng._now())
    eng.run()
    undone = [r.rid for _, r in phase_a if not r.done]
    if undone:
        raise RuntimeError(
            f"lifecycle phase A left requests {undone} unfinished")

    # rollout: tenant0 v2 serves NEW requests only; then retire tenant1
    reg.ingest("tenant0", ft_v2); reg.pump()
    phase_b = [(i, eng.submit("tenant0", p, max_new_tokens=args.max_new))
               for i, (t, p) in enumerate(stream) if t == "tenant0"][:2]
    eng.run()
    undone = [r.rid for _, r in phase_b if not r.done]
    if undone:
        raise RuntimeError(
            f"lifecycle phase B left requests {undone} unfinished")
    if n > 1:
        eng.unregister_tenant("tenant1")
    guard.detach()

    recompiles = guard.new_compiles("decode")
    rep = eng.metrics.report()
    print(f"lifecycle events: {rep['tenant_lifecycle']}")
    print(f"decode recompiles across register/rollout/retire: {recompiles}")
    try:
        guard.check()
    except CompileBudgetError as e:
        raise SystemExit(f"hot lifecycle retraced the decode step: {e}")

    if args.check_identity:
        # registration time must not change tokens: reference engines
        # get the SAME tenant versions up front and serve the same
        # prompts — compare per-request
        def ref_engine(deltas_by_name):
            e = ContinuousEngine(cfg, base, n_slots=args.slots,
                                 max_seq=args.max_seq,
                                 tenant_capacity=n + 1,
                                 clock=VirtualClock(tick=1e-3))
            for name, d in deltas_by_name.items():
                e.register_tenant(name, d)
            return e

        v1 = {f"tenant{t}": compress(base, fts[t], spec)[0]
              for t in range(n)}
        ref = ref_engine(v1)
        ref_a = [(i, ref.submit(stream[i][0], stream[i][1],
                                max_new_tokens=args.max_new))
                 for i, _ in phase_a]
        ref.run()
        ref2 = ref_engine({"tenant0": compress(base, ft_v2, spec)[0]})
        ref_b = [(i, ref2.submit("tenant0", stream[i][1],
                                 max_new_tokens=args.max_new))
                 for i, _ in phase_b]
        ref2.run()
        bad = [r.rid for (_, r), (_, s) in zip(phase_a + phase_b,
                                               ref_a + ref_b)
               if not np.array_equal(r.output(), s.output())]
        if bad:
            raise SystemExit(f"lifecycle token identity FAILED for "
                             f"requests {bad}")
        print(f"token identity vs up-front engines: OK "
              f"({len(phase_a)} + {len(phase_b)} requests)", flush=True)

    if args.json:
        print(json.dumps(rep, indent=2))
    else:
        print(f"served {len(phase_a) + len(phase_b)} requests / "
              f"{rep['total_tokens']} tokens across the lifecycle drill")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--ratio", type=int, default=128, choices=sorted(RATIO_SPECS))
    ap.add_argument("--codec", default="deltadq",
                    choices=("deltadq", "bitdelta", "lowrank", "auto",
                             "mixed"),
                    help="delta codec for every tenant: 'deltadq' keeps "
                         "the --ratio spec table; 'bitdelta'/'lowrank' use "
                         "those codecs' defaults; 'auto' per-leaf picks the "
                         "cheapest codec meeting --budget-bits; 'mixed' "
                         "alternates DeltaDQ/BitDelta across tenants (one "
                         "engine, two codec groups)")
    ap.add_argument("--budget-bits", type=float, default=None,
                    help="per-element bit budget for --codec auto")
    ap.add_argument("--lifecycle", action="store_true",
                    help="online-lifecycle drill: tenant0 serves while "
                         "the rest of the fleet is compressed and "
                         "hot-registered mid-traffic, then a tenant0 "
                         "version rollout and a tenant1 retirement — "
                         "fails on any decode-step recompile; combine "
                         "with --check-identity to gate tokens against "
                         "all-up-front engines")
    ap.add_argument("--strict-compile", action="store_true",
                    help="attach a strict CompileGuard to the serving "
                         "engine: any jit retrace of an already-seen "
                         "signature raises at the retracing call "
                         "(static-decode-shape contract, enforced live); "
                         "with --lifecycle, the drill's post-warmup "
                         "recompile gate also raises at the call site")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--arrival-gap", type=float, default=0.05,
                    help="seconds between request arrivals (staggered stream)")
    ap.add_argument("--json", action="store_true",
                    help="print the metrics report as JSON")
    ap.add_argument("--print-tokens", action="store_true",
                    help="print every request's generated tokens (for "
                         "inspection; cross-process diffs are not stable — "
                         "use --check-identity for the identity contract)")
    ap.add_argument("--check-identity", action="store_true",
                    help="also serve the same stream on a single-device "
                         "DEFAULT-path engine (occupancy admission, packed "
                         "deltas, no mesh) in this process and fail unless "
                         "every request's tokens match exactly; needs "
                         "--devices N>1, --admission affinity, --chunked "
                         "or --residency-mb > 0 to differ from the "
                         "reference")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the base model over N devices ((data, "
                         "N/data) mesh; on CPU set XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N before launch)")
    ap.add_argument("--data", type=int, default=1,
                    help="data-axis extent of the serving mesh: slot rows "
                         "split into `data` contiguous shard pools with "
                         "occupancy-balanced admission (requires --devices "
                         "divisible by data and --slots divisible by data)")
    ap.add_argument("--admission", default="occupancy",
                    choices=("occupancy", "affinity"),
                    help="shard admission policy: 'occupancy' (balanced, "
                         "default) or 'affinity' (prefer the shard pool "
                         "already hosting the request's tenant within a "
                         "bounded imbalance — fewer unique tenants per "
                         "shard, fewer deltas dequantized per step)")
    ap.add_argument("--chunked", action="store_true",
                    help="chunked prefill: prompts stream in --chunk-size "
                         "token chunks inside the regular decode step "
                         "(one combined jit) instead of preempting it "
                         "with a whole-prompt prefill")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="prompt tokens per prefill chunk (--chunked)")
    ap.add_argument("--chunk-share", type=float, default=1.0,
                    help="SLO knob: max fraction of decode-active steps "
                         "that may carry a prefill chunk (--chunked)")
    ap.add_argument("--residency-mb", type=float, default=0.0,
                    help="pre-decoded delta residency budget in MB: hot "
                         "tenants' dequantized f32 delta values stay "
                         "resident (LRU) and decode steps skip the "
                         "per-step unpack; 0 disables the tier")
    ap.add_argument("--trace-out", metavar="FILE", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(request lifecycle spans + per-decode-step path "
                         "attribution; open at https://ui.perfetto.dev)")
    ap.add_argument("--trace-sample", type=int, default=1,
                    help="keep every Nth decode-step span in the trace "
                         "(request spans are always kept)")
    ap.add_argument("--telemetry-snapshot-secs", type=float, default=0.0,
                    help="write a JSON telemetry snapshot (metrics + SLO "
                         "counters) every N seconds of engine time; 0 "
                         "disables")
    ap.add_argument("--telemetry-out", metavar="FILE",
                    default="telemetry.json",
                    help="snapshot file for --telemetry-snapshot-secs "
                         "(atomically replaced on each write)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if args.data > 1 and args.devices % args.data:
        raise SystemExit(f"--devices {args.devices} must be a multiple of "
                         f"--data {args.data}")
    if args.data > 1 and args.slots % args.data:
        raise SystemExit(f"--slots {args.slots} must be a multiple of "
                         f"--data {args.data} (equal shard pools)")
    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.devices, data=args.data)
        print(f"mesh: {dict(mesh.shape)}", flush=True)
    elif args.data > 1:
        raise SystemExit("--data > 1 requires --devices > 1 (the shard "
                         "pools mirror the mesh data axis)")
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    if args.lifecycle:
        if mesh is not None:
            raise SystemExit("--lifecycle runs single-device (the drill "
                             "measures lifecycle, not sharding)")
        run_lifecycle(args, cfg, base, rng)
        return
    tenants = synth_tenants(cfg, base, args.tenants, _tenant_specs(args),
                            rng, budget_bits=args.budget_bits)

    stream = []
    for i in range(args.requests):
        L = 4 + (i % 3) * 4         # mixed prompt lengths -> multiple buckets
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, 100 + i), (L,), 0, cfg.vocab))
        stream.append((f"tenant{i % args.tenants}", prompt))

    def serve_stream(mesh_, default_path=False):
        # the identity reference serves the DEFAULT path (occupancy
        # admission, no residency): the contract is that affinity
        # placement and the pre-decoded value tier change scheduling and
        # arithmetic *layout* only, never any request's tokens
        from repro.serve import residency_bytes_from_mb
        kw = {} if default_path else {
            "admission": args.admission,
            "residency_budget_bytes": residency_bytes_from_mb(
                args.residency_mb),
            "chunked_prefill": args.chunked,
            "chunk_size": args.chunk_size,
            "chunk_share": args.chunk_share,
        }
        if not default_path:
            # observability rides the MAIN engine only — the identity
            # reference stays untraced so the comparison itself shows up
            # as one clean engine in the trace
            if args.trace_out:
                from repro.serve.trace import Tracer
                kw["trace"] = Tracer(step_sample=args.trace_sample)
            if args.telemetry_snapshot_secs > 0:
                from repro.serve.telemetry import (SLOCounters,
                                                   TelemetrySnapshotWriter)
                kw["slo"] = SLOCounters()
                kw["telemetry"] = TelemetrySnapshotWriter(
                    args.telemetry_out, args.telemetry_snapshot_secs)
        eng_ = ContinuousEngine(cfg, base, n_slots=args.slots,
                                max_seq=args.max_seq, mesh=mesh_, **kw)
        guard_ = None
        if args.strict_compile and not default_path:
            # fresh engine: every first trace is first=True and allowed;
            # strict mode raises only on RE-traces of a seen signature
            guard_ = CompileGuard(eng_, strict=True, label="serve").attach()
        for name, deltas, report in tenants:
            eng_.register_tenant(name, deltas, report)
        reqs_ = []
        for i, (tenant, prompt) in enumerate(stream):
            reqs_.append(eng_.submit(tenant, prompt,
                                     max_new_tokens=args.max_new,
                                     arrival=i * args.arrival_gap))
        metrics_ = eng_.run()
        if guard_ is not None:
            guard_.detach()
        undone = [r.rid for r in reqs_ if not r.done]
        if undone:
            raise RuntimeError(
                f"engine run() left requests {undone} unfinished")
        return eng_, reqs_, metrics_

    ref_reqs = None
    if args.check_identity:
        nondefault = args.admission != "occupancy" or args.residency_mb > 0 \
            or args.chunked
        if mesh is None and not nondefault and args.codec != "mixed":
            raise SystemExit("--check-identity requires --devices N > 1, "
                             "--admission affinity, --residency-mb > 0, "
                             "--chunked or --codec mixed (nothing to "
                             "compare against otherwise)")
        # single-device reference FIRST (its jits trace without the mesh).
        # With --data N this is also the data=1 reference, and it always
        # runs the default path (occupancy admission, packed deltas) —
        # so --admission/--residency-mb are covered by the same check.
        if mesh is not None or nondefault:
            _, ref_reqs, _ = serve_stream(None, default_path=True)

    for name, _, report in tenants:
        print(f"registered {name}: {report.summary()}", flush=True)
    eng, reqs, metrics = serve_stream(mesh)
    rep = metrics.report()

    if ref_reqs is not None:
        bad = [r.rid for r, s in zip(reqs, ref_reqs)
               if not np.array_equal(r.output(), s.output())]
        if bad:
            raise SystemExit(f"token identity FAILED for requests {bad}")
        print(f"token identity vs single device: OK "
              f"({len(reqs)} requests)", flush=True)

    if args.check_identity and args.codec == "mixed":
        # mixed-codec contract: each request's tokens must match an
        # engine serving ONLY that tenant (same mesh, same prompts) —
        # the other codec group's row-0 zero delta contributes exactly
        # 0.0 to the summed correction, so serving together is
        # token-identical to serving alone
        bad = []
        for name, deltas, report in tenants:
            eng_a = ContinuousEngine(cfg, base, n_slots=args.slots,
                                     max_seq=args.max_seq, mesh=mesh)
            eng_a.register_tenant(name, deltas, report)
            mine = [(i, r) for i, r in enumerate(reqs) if r.tenant == name]
            alone = [eng_a.submit(name, stream[i][1],
                                  max_new_tokens=args.max_new,
                                  arrival=k * args.arrival_gap)
                     for k, (i, _) in enumerate(mine)]
            eng_a.run()
            bad += [r.rid for (_, r), s in zip(mine, alone)
                    if not np.array_equal(r.output(), s.output())]
        if bad:
            raise SystemExit(
                f"mixed-codec identity FAILED for requests {bad}")
        print(f"token identity vs per-tenant-alone engines: OK "
              f"({len(reqs)} requests, "
              f"{len(eng._groups)} codec groups)", flush=True)

    if args.print_tokens:
        # per-request token dump for inspection. Do NOT diff these across
        # separate process runs — CPU XLA is not bit-deterministic across
        # processes (serve/README.md); the identity contract is checked
        # in-process by --check-identity, which is what CI runs.
        for r in reqs:
            print(f"tokens {r.rid} {r.tenant}: {' '.join(map(str, r.output()))}")

    if args.json:
        print(json.dumps(rep, indent=2))
    else:
        # occupancy (and, with a zero-width wall clock, tokens/sec) is
        # None when no decode step ran — e.g. --max-new 1, where every
        # request finishes on its prefill-produced first token
        tps = "n/a" if rep["tokens_per_sec"] is None \
            else f"{rep['tokens_per_sec']:.0f}"
        occ = "n/a" if rep["batch_occupancy"] is None \
            else f"{rep['batch_occupancy']:.2f}"
        print(f"served {len(reqs)} requests / {rep['total_tokens']} tokens in "
              f"{rep['wall_time_s']:.2f}s "
              f"({tps} tok/s, occupancy {occ}, "
              f"{len(eng.prefill_shapes)} prefill shapes)")
        for name, t in rep["tenants"].items():
            print(f"  {name}: {t['requests']} reqs, {t['tokens']} toks, "
                  f"ttft p50 {1e3 * t['ttft_p50']:.0f}ms "
                  f"latency p95 {1e3 * t['latency_p95']:.0f}ms")
        if rep.get("shards"):
            for s in rep["shards"]:
                # occupancy is None when no decode step ran (e.g. every
                # request finished on its prefill token with --max-new 1)
                occ = "n/a" if s["occupancy"] is None \
                    else f"{s['occupancy']:.2f}"
                uniq = "n/a" if s["unique_tenants_mean"] is None \
                    else f"{s['unique_tenants_mean']:.2f}"
                print(f"  data shard {s['shard']} (slots "
                      f"{s['slots'][0]}..{s['slots'][1] - 1}): "
                      f"occupancy {occ}, {s['tokens']} toks, "
                      f"unique tenants/step {uniq}")
            print(f"  max step imbalance: {rep['shard_imbalance_max']}")
        if rep.get("residency"):
            r_ = rep["residency"]
            hr = "n/a" if r_.get("hit_rate") is None \
                else f"{r_['hit_rate']:.2f}"
            print(f"  residency: {r_.get('resident_rows')}/"
                  f"{r_.get('capacity_rows')} rows resident "
                  f"({(r_.get('allocated_bytes') or 0) / 1e6:.2f}MB "
                  f"allocated), hit rate {hr}, {r_['value_steps']} value / "
                  f"{r_['packed_steps']} packed steps")

    if eng.trace is not None:
        trace = eng.trace.export(args.trace_out)
        from repro.serve.trace import validate_chrome_trace
        problems = validate_chrome_trace(trace)
        if problems:
            raise SystemExit("emitted trace failed validation: "
                             + "; ".join(problems))
        n_spans = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
        print(f"trace: {args.trace_out} ({n_spans} spans, "
              f"{eng.trace.n_request_spans} requests)", flush=True)
    if eng.telemetry is not None:
        # final snapshot at drain so the file always reflects the full run
        eng.telemetry.write(rep["wall_time_s"], eng._telemetry_payload())
        print(f"telemetry: {args.telemetry_out} "
              f"({eng.telemetry.n_written} snapshots)", flush=True)

    store = eng.store
    base_bytes = tree_bytes(base)
    n = len(store.ordered())
    print(f"memory: base {base_bytes / 1e6:.1f}MB + deltas "
          f"{store.total_bytes() / 1e6:.2f}MB vs {n} full models "
          f"{base_bytes * n / 1e6:.1f}MB")


if __name__ == "__main__":
    main()
