"""Production serving launcher: base model + N DeltaDQ tenants.

Loads (or synthesizes) fine-tuned variants, compresses their deltas at the
requested ratio, and drives a mixed request stream through the engine —
the deployment of paper Fig. 2 as a runnable process.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --tenants 3 --ratio 128 --requests 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import DeltaDQSpec, compress
from repro.models import lm
from repro.serve import Engine

RATIO_SPECS = {
    8: DeltaDQSpec(alpha=8.0, k_bits=None, h_g=16),
    16: DeltaDQSpec(alpha=8.0, k_bits=8, m=1, h_g=16),
    32: DeltaDQSpec(alpha=8.0, k_bits=4, m=1, h_g=16),
    64: DeltaDQSpec(alpha=8.0, k_bits=4, m=4, h_g=16),
    128: DeltaDQSpec(alpha=8.0, k_bits=4, m=8, h_g=16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--ratio", type=int, default=128, choices=sorted(RATIO_SPECS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    rng = jax.random.PRNGKey(0)
    base = lm.init_params(cfg, rng)
    eng = Engine(cfg, base, max_seq=64)

    spec = RATIO_SPECS[args.ratio]
    for t in range(args.tenants):
        ft = jax.tree.map(
            lambda p, t=t: p + 0.02 * jax.random.normal(
                jax.random.fold_in(rng, 7 + t), p.shape, jnp.float32).astype(p.dtype)
            if p.ndim >= 2 else p, base)
        deltas, report = compress(base, ft, spec)
        eng.register_tenant(f"tenant{t}", deltas, report)
        print(f"registered tenant{t}: {report.summary()}", flush=True)

    reqs = [(f"tenant{i % args.tenants}",
             np.asarray(jax.random.randint(jax.random.fold_in(rng, i), (8,), 0, cfg.vocab)))
            for i in range(args.requests)]
    t0 = time.time()
    outs = eng.serve_batch(reqs, max_new_tokens=args.max_new)
    print(f"served {len(outs)} requests in {time.time() - t0:.1f}s")
    rep = eng.memory_report()
    n = rep["n_tenants"]
    print(f"memory: base {rep['base_bytes'] / 1e6:.1f}MB + deltas "
          f"{rep['delta_bytes_total'] / 1e6:.2f}MB vs naive "
          f"{rep['base_bytes'] * (n + 1) / 1e6:.1f}MB")


if __name__ == "__main__":
    main()
