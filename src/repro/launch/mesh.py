"""Mesh construction + full sharding layouts for serving and training.

FUNCTIONS, not module-level constants: importing this module never
touches jax device state. Entry points set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before any jax
import so every layout here builds and runs on the CPU container — that
is what the multi-device CI job does.

Production topology (TPU v5e):
  single pod : (16, 16)      axes (data, model)   — 256 chips
  multi-pod  : (2, 16, 16)   axes (pod, data, model) — 512 chips
``model`` is the ICI-contiguous inner axis (TP collectives stay on-chip
-mesh); ``pod`` crosses DCI and carries only gradient reduction (training)
or nothing at all (serving; DESIGN.md §5).

Serving layout (the DeltaDQ deployment, Fig. 2 at scale):

* **base weights** — tensor-parallel along the per-layer-type matmul
  axes (attention qkv/o heads, MLP up/down, MoE experts, SSM inner,
  RG-LRU width; ``repro.dist.DEFAULT_RULES``). The dense base is the
  only multi-GB object, so it is the only thing worth splitting.
* **packed tenant deltas** — replicated by default: post-compression
  they are ~1% of the base, and replication keeps the per-shard delta
  correction collective-free. :func:`delta_shardings` can instead shard
  the output(-group) axis over ``model`` when it divides cleanly.
* **KV cache** — sharded along kv-heads (``repro.dist.cache_axes``),
  batch(slot) rows over ``data`` when it is > 1.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.pack import PackedDelta
from repro.dist import sharding as shd


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"mesh (data={data}, model={model}) needs {data * model} "
            f"devices but only {n} are visible")
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(devices: Optional[int] = None, *, data: int = 1):
    """(data, model) mesh over ``devices`` local devices (default: all).

    Serving wants the model axis as large as possible (the base is the
    footprint); ``data > 1`` replicates the model shards for decode
    throughput: KV slot rows shard over ``data`` in contiguous pools
    and the engine's scheduler balances per-pool occupancy
    (``ContinuousEngine(mesh=make_serving_mesh(n, data=d))``;
    ``launch.serve --devices n --data d``).
    """
    n = len(jax.devices()) if devices is None else devices
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"requested {n} devices but only {avail} are visible; on CPU "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before the first jax import")
    if n % data:
        raise ValueError(f"data={data} must divide the device count {n} "
                         "(equal contiguous shard pools)")
    return jax.make_mesh((data, n // data), ("data", "model"))


# ---------------------------------------------------------------------------
# Layout assembly (serve profile unless stated otherwise)
# ---------------------------------------------------------------------------
def serve_rules(mesh, **overrides) -> shd.ShardingRules:
    return shd.ShardingRules(mesh).with_overrides(
        **{**shd.SERVE_OVERRIDES, **overrides})


def param_shardings(cfg, mesh, profile: str = "serve", **overrides) -> Any:
    """NamedSharding tree for every base-model parameter class.

    ``serve``: **column-parallel** layout — every >=2-D weight shards its
    output (last) axis over ``model`` when it divides; contraction axes
    are never sharded. With activations pinned replicated at the
    ``apply_linear`` chokepoint (core.apply mesh mode) every matmul then
    reduces over the full contraction locally, in the same order as one
    device — sharded decode is *bit-identical* to single-device decode,
    which is what lets CI assert token identity. The embedding table
    stays replicated (its gather output feeds a norm directly; tied
    unembedding keeps logits replicated for an exact argmax).

    ``train``: the logical-rules layout (``repro.dist``) — Megatron
    row+column TP plus FSDP overrides; there the reduction-order
    difference is irrelevant and memory/collective balance wins.
    """
    from repro.models import lm
    if profile == "train":
        rules = shd.ShardingRules(mesh).with_overrides(
            **{**shd.TRAIN_OVERRIDES, **overrides})
        return shd.tree_shardings(rules, lm.param_specs(cfg),
                                  lm.param_axes(cfg))
    if profile != "serve":
        raise ValueError(
            f"profile {profile!r} not in ('train', 'serve')")
    from repro.core.compress import is_compressible
    n_model = mesh.shape.get("model", 1)
    repl = NamedSharding(mesh, P())

    def one(path: str, leaf) -> NamedSharding:
        # exactly the apply_linear matmul sites (= the delta sites): conv
        # taps, router, norms and the embedding stay replicated because
        # their outputs feed reductions outside the constrained chokepoint
        if not is_compressible(path, leaf):
            return repl
        shape = tuple(leaf.shape)
        if shape[-1] % n_model == 0:
            return NamedSharding(
                mesh, P(*([None] * (len(shape) - 1) + ["model"])))
        return repl

    from repro.utils import map_with_paths
    return map_with_paths(one, lm.param_specs(cfg))


def cache_shardings(cfg, mesh, batch: int, max_seq: int, enc_len: int = 0,
                    **overrides) -> Any:
    """NamedSharding tree for the slot-paged serving cache (KV on heads)."""
    from repro.models import lm
    rules = serve_rules(mesh, **overrides)
    cache = lm.cache_specs(cfg, batch, max_seq, enc_len=enc_len)
    return shd.tree_shardings(rules, cache, shd.cache_axes(cache))


def delta_shardings(deltas: Any, mesh, *, shard_output: bool = False) -> Any:
    """Shardings for a packed-delta tree (possibly tenant-stacked).

    Replicated by default — compressed deltas are tiny, and a replicated
    delta keeps the per-shard correction collective-free. With
    ``shard_output=True``, idx/codes shard their output(-column) axis
    over ``model`` wherever the mesh axis divides it (the layout the
    shard_map'd kernel consumes natively); scale/zero stay replicated.
    """
    n_model = mesh.shape.get("model", 1)
    repl = NamedSharding(mesh, P())

    def one(d: PackedDelta) -> PackedDelta:
        if shard_output and d.h_out % n_model == 0:
            nd = d.idx.ndim
            arr = NamedSharding(mesh, P(*([None] * (nd - 1) + ["model"])))
        else:
            arr = repl
        return PackedDelta(arr, arr, repl, repl, d.h_in, d.h_out, d.h_g,
                           d.keep, d.alpha, d.k_bits, d.m, d.codec)

    return jax.tree.map(one, deltas,
                        is_leaf=lambda x: isinstance(x, PackedDelta))


def replicate(tree: Any, mesh) -> Any:
    """device_put every array leaf fully replicated over the mesh."""
    return jax.device_put(tree, NamedSharding(mesh, P()))


def shard_tree(tree: Any, shardings: Any) -> Any:
    """device_put a tree to a matching NamedSharding tree."""
    return jax.tree.map(jax.device_put, tree, shardings)
