"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these meshes build on the CPU container.

Production topology (TPU v5e):
  single pod : (16, 16)      axes (data, model)   — 256 chips
  multi-pod  : (2, 16, 16)   axes (pod, data, model) — 512 chips
``model`` is the ICI-contiguous inner axis (TP collectives stay on-chip
-mesh); ``pod`` crosses DCI and carries only gradient reduction (training)
or nothing at all (serving; DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
