"""Logical-axis sharding rules: one table from model axes to mesh axes.

Every parameter/activation/cache leaf in the repo is annotated with
*logical* axis names (``lm.param_axes``, :func:`batch_axes`,
:func:`cache_axes`, ``core.compress.delta_axes``). This module owns the
single mapping from those names to physical mesh axes, so the whole
layout of a deployment is one small dict:

* base weights are **tensor-parallel** along the matmul output /
  contraction axes per layer type — attention q/kv heads, MLP up/down,
  MoE experts, SSM inner and RG-LRU width all map to ``model``;
* ``batch`` maps to ``(pod, data)`` — whichever of those axes the mesh
  actually has;
* everything else (norms, layer stacks, scalar quant params) replicates.

Divisibility is checked per leaf: an axis whose size the mesh axis does
not divide falls back to replicated, and the fallback is *recorded* in
``ShardingRules.fallbacks`` so dry-runs and tests can assert the layout
they think they asked for is the one they got.
"""
from __future__ import annotations

from typing import Any, Optional

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils import map_with_paths

# Default (serving) profile: pure tensor parallelism over `model`; the
# embedding/residual dim stays replicated so activations never need a
# gather between layers the compiler didn't choose itself.
DEFAULT_RULES: dict[Optional[str], tuple] = {
    "batch": ("pod", "data"),
    "seq": (),
    "vocab": ("model",),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),
    "inner": ("model",),
    "lru": ("model",),
    "layers": (),
}

# Training: FSDP — additionally shard the (large, otherwise replicated)
# embedding/residual dim of every weight over the data axis.
TRAIN_OVERRIDES = dict(embed=("data",))

# Serving keeps the default pure-TP layout (explicit so launchers can say
# which profile they mean).
SERVE_OVERRIDES: dict[str, tuple] = {}

# 500k-token decode: batch=1, the KV ring is the footprint — spread the
# sequence axis of the cache over the (otherwise idle) data axis.
LONG_CONTEXT_OVERRIDES = dict(seq=("data",), batch=())


class ShardingRules:
    """Maps logical axis tuples to :class:`PartitionSpec`, with fallbacks.

    ``rules`` maps logical axis name -> candidate mesh axes, tried in
    order; a candidate is used when the mesh has it, the spec has not
    used it yet, and it divides the dimension. Several candidates can
    stack on one dimension (``batch`` over ``(pod, data)``).
    """

    def __init__(self, mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES) if rules is None else dict(rules)
        self.fallbacks: list[tuple] = []   # (leaf path, logical axes, shape)

    def with_overrides(self, **overrides) -> "ShardingRules":
        return ShardingRules(self.mesh, {**self.rules, **overrides})

    def spec_for(self, axes: tuple, shape: tuple, path: str = "?") -> P:
        """PartitionSpec for one leaf; records a fallback when a mapped
        logical axis exists but no mesh axis fits (divisibility/reuse)."""
        if len(axes) != len(shape):
            raise ValueError(
                f"leaf {path!r}: logical axes {axes} (rank {len(axes)}) do "
                f"not match shape {shape} (rank {len(shape)})")
        used: set = set()
        entries = []
        fell_back = False
        for name, dim in zip(axes, shape):
            cands = self.rules.get(name, ()) if name is not None else ()
            avail = [a for a in cands if a in self.mesh.shape and a not in used]
            picked: list = []
            span = 1
            for a in avail:
                sz = self.mesh.shape[a]
                if dim % (span * sz) == 0:
                    picked.append(a)
                    span *= sz
            if avail and not picked:
                fell_back = True
            used.update(picked)
            if not picked:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(tuple(picked))
        if fell_back:
            self.fallbacks.append((path, tuple(axes), tuple(shape)))
        return P(*entries)


def tree_shardings(rules: ShardingRules, specs: Any, axes: Any) -> Any:
    """NamedSharding tree for a (specs, logical-axes) tree pair.

    ``specs`` leaves are arrays/ShapeDtypeStructs; ``axes`` mirrors the
    structure with a tuple of logical names (len == ndim) at each leaf
    position. ``None`` sub-trees (uncompressed delta slots) map to None.
    """
    def fn(path, leaf, ax):
        return NamedSharding(rules.mesh,
                             rules.spec_for(tuple(ax), tuple(leaf.shape), path))
    return map_with_paths(fn, specs, axes)


def zero1_shardings(rules: ShardingRules, specs: Any, axes: Any,
                    zero_axes: tuple = ("data",)) -> Any:
    """Optimizer-state shardings: base layout + ZeRO-1 partitioning.

    Each leaf starts from the parameter's own spec; every ``zero_axes``
    mesh axis not already used is then added on the first still-
    replicated, divisible dimension, so optimizer moments shard over the
    data(-parallel) axis without ever double-using a mesh axis.
    """
    def fn(path, leaf, ax):
        spec = list(rules.spec_for(tuple(ax), tuple(leaf.shape), path))
        spec += [None] * (len(leaf.shape) - len(spec))
        used = {a for e in spec if e is not None
                for a in (e if isinstance(e, tuple) else (e,))}
        for z in zero_axes:
            if z not in rules.mesh.shape or z in used:
                continue
            sz = rules.mesh.shape[z]
            for i, (e, dim) in enumerate(zip(spec, leaf.shape)):
                if e is None and dim % sz == 0:
                    spec[i] = z
                    used.add(z)
                    break
        return NamedSharding(rules.mesh, P(*spec))
    return map_with_paths(fn, specs, axes)


# ---------------------------------------------------------------------------
# Logical axes for non-parameter trees
# ---------------------------------------------------------------------------
_BATCH_AXES_BY_NAME = {
    "tokens": ("batch", "seq"),
    "positions": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "enc_feats": ("batch", "seq", "embed"),
    "image_embeds": ("batch", "seq", "embed"),
}


def batch_axes(batch_specs: dict) -> dict:
    """Logical axes for a model-input batch dict."""
    out = {}
    for k, v in batch_specs.items():
        ax = _BATCH_AXES_BY_NAME.get(k)
        if ax is None or len(ax) != len(v.shape):
            ax = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = ax
    return out


_CACHE_AXES_BY_NAME = {
    # attention KV ring + per-row slot positions
    "k": ("batch", "seq", "kv_heads", None),
    "v": ("batch", "seq", "kv_heads", None),
    "pos": ("batch", "seq"),
    # ssm state (conv tails + expanded state)
    "conv_x": ("batch", None, "inner"),
    "conv_bc": ("batch", None, None),
    "state": ("batch", None, None, None),
    # rg-lru state
    "conv": ("batch", None, "lru"),
    "h": ("batch", "lru"),
}


def cache_axes(cache: Any) -> Any:
    """Logical-axes tree matching ``lm.cache_specs`` structure.

    Every cache leaf leads with the batch(slot) dim; KV rings shard
    along kv-heads, ssm/rglru states along their inner width. NamedTuple
    states are rebuilt as NamedTuples of axis tuples so the result pairs
    with the cache under ``tree_shardings``.
    """
    def leaf_axes(name: str, leaf) -> tuple:
        ax = _CACHE_AXES_BY_NAME.get(name)
        nd = len(leaf.shape)
        if ax is None or len(ax) != nd:
            ax = ("batch",) + (None,) * (nd - 1)
        return ax

    def rec(node, name=""):
        if isinstance(node, dict):
            return {k: rec(v, k) for k, v in node.items()}
        if hasattr(node, "_fields"):          # NamedTuple state
            return type(node)(**{f: rec(getattr(node, f), f)
                                 for f in node._fields})
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, name) for v in node)
        return leaf_axes(name, node)

    return rec(cache)
