"""Distribution layer: sharding rules, ZeRO-1, compressed all-reduce.

One logical-axis table (``sharding.py``) maps every parameter, input,
cache and packed-delta leaf to a mesh PartitionSpec; ``grad_compress``
carries the int8 error-feedback all-reduce used by the training
launcher. ``launch/mesh.py`` assembles these into full serving/training
layouts.
"""
from repro.dist.grad_compress import ErrorFeedback, make_compressed_allreduce
from repro.dist.sharding import (
    DEFAULT_RULES,
    LONG_CONTEXT_OVERRIDES,
    SERVE_OVERRIDES,
    TRAIN_OVERRIDES,
    ShardingRules,
    batch_axes,
    cache_axes,
    tree_shardings,
    zero1_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "LONG_CONTEXT_OVERRIDES",
    "SERVE_OVERRIDES",
    "TRAIN_OVERRIDES",
    "ErrorFeedback",
    "ShardingRules",
    "batch_axes",
    "cache_axes",
    "make_compressed_allreduce",
    "tree_shardings",
    "zero1_shardings",
]
