"""int8 compressed gradient all-reduce with error feedback.

Data-parallel training at pod scale is bandwidth-bound on the gradient
all-reduce (DCI for the ``pod`` axis). The classic fix — and the same
quantize-with-shared-scale trick DeltaDQ uses for delta values — is to
reduce in int8:

two-phase compressed psum (:func:`_compressed_psum_flat`)
    phase 1: agree on a scale — ``pmax`` of every device's max-|g|
    phase 2: quantize to int8 with that shared scale, ``psum`` the int8
    payload (4x less wire traffic than f32), dequantize, divide by the
    axis size. Deterministic, and the error is bounded by scale/2 per
    device.

error feedback (:class:`ErrorFeedback`)
    the quantization residual is carried to the next step and added
    before quantizing, so the *time-averaged* reduced gradient is exact
    — the standard convergence fix for compressed all-reduce.

``make_compressed_allreduce`` is the ``grad_transform`` hook for
``train.make_train_step``: inside a GSPMD ``jit`` the mean-reduce over
the data axis is already XLA-inserted, so the transform applies int8
quantize/dequantize numerics (shared scale, rounding) to the *reduced*
gradient. Note this is an approximation of the wire format, not an
exact emulation: the wire-level path (:func:`_compressed_psum_flat`)
rounds each device's local gradient before the psum — n independent
roundings (worst case n·scale/2 pre-mean) vs one here. The explicit
collective form is exercised per-device under ``shard_map`` by
``tests/test_dist.py``; swapping the training step to run it for real
needs the grads materialized per-device (shard_map'd backward), a
follow-up on the ROADMAP.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize_int8(v: jnp.ndarray, amax: jnp.ndarray):
    """Shared-scale int8 quantization; returns (codes int8-valued, scale)."""
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127)
    return q, scale


def _compressed_psum_flat(v: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """Mean-reduce ``v`` over mesh axis ``axis`` with int8 payloads.

    Runs inside ``shard_map``: ``v`` is this device's local gradient.
    All devices return the identical reduced value (the scale is agreed
    via pmax before anything is rounded).
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis)
    q, scale = _quantize_int8(v, amax)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale / n


def make_compressed_allreduce(mesh, axis: str):
    """grad_transform for ``make_train_step``: int8-compressed DP reduce.

    Returns ``fn(grads) -> grads``. Under GSPMD jit the sum over ``axis``
    is already inserted by XLA when the batch is sharded; this transform
    rounds the reduced gradient onto the int8 grid so the optimizer
    consumes values the wire format can represent (one rounding of the
    mean — an approximation of the per-device-rounded wire path; see
    module docstring).
    """
    n = mesh.shape.get(axis, 1)

    def transform(grads: Any) -> Any:
        if n <= 1:
            return grads

        def one(g):
            q, scale = _quantize_int8(g, jnp.max(jnp.abs(g)))
            return q * scale

        return jax.tree.map(one, grads)

    return transform


class ErrorFeedback:
    """Residual carry for compressed reduction: time-averaged exactness."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any) -> tuple:
        """(sent, new_residual): sent = Q(g + r), r' = g + r - sent."""
        def one(g, r):
            e = g.astype(jnp.float32) + r
            q, scale = _quantize_int8(e, jnp.max(jnp.abs(e)))
            sent = q * scale
            return sent, e - sent

        pairs = jax.tree.map(one, grads, residual)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 \
            and not hasattr(x, "_fields")
        sent = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
        new_res = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
        return sent, new_res
