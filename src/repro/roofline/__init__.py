from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes,
    from_compiled,
    model_flops_for,
)
