"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = collective_bytes_per_device / ICI_link_bandwidth

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device program
after SPMD partitioning). Collective bytes are NOT in cost_analysis: we
parse the optimized HLO text and sum the tensor sizes moved by every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Convention (documented): bytes = result size for gather/reduce-like ops,
operand size for reduce-scatter — a within-2x proxy for wire bytes that is
consistent across iterations of the perf loop, which is what matters.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e per chip (task-provided constants)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[4,128]{1,0} all-gather(...)` / tuple results `= (f32[..], ...)`
_OP_RE = re.compile(
    r"=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _size_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device bytes moved by collectives in optimized HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.groups()
        # ignore the -done halves of async pairs (bytes counted at -start)
        if "-done(" in m.group(0):
            continue
        out[op] += _size_bytes(dtype, dims)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": float(sum(out.values()))}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device
    model_flops: float           # 6ND / 2ND useful-work reference (per device)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline achievable at the bound:
        (useful FLOP time) / (time of the dominant term)."""
        t_useful = self.model_flops / PEAK_FLOPS
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.coll_bytes,
            "model_flops_per_device": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops_for(kind: str, n_params: int, n_active: int, tokens: int,
                    n_devices: int) -> float:
    """6ND for training, 2ND for inference (active params for MoE)."""
    per_tok = 6 * n_active if kind == "train" else 2 * n_active
    return per_tok * tokens / n_devices


def from_compiled(compiled, lowered_text: Optional[str], kind: str,
                  n_params: int, n_active: int, tokens: int,
                  n_devices: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    coll = collective_bytes(text)
    return Roofline(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=coll["total_bytes"],
        model_flops=model_flops_for(kind, n_params, n_active, tokens, n_devices),
    )
