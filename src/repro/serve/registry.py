"""Online tenant lifecycle: compress-and-register service + cold tiers.

The paper's deployment story is one resident base model plus many tiny
deltas — but a fleet onboards, updates and retires fine-tunes
continuously, so compression itself must run as an online service. The
:class:`DeltaRegistry` closes that loop around a running
:class:`~repro.serve.engine.ContinuousEngine`:

* **Ingestion**: a raw fine-tuned checkpoint arrives (an
  :meth:`~DeltaRegistry.ingest` call, or a ``.npz`` dropped into a
  watched directory picked up by :meth:`~DeltaRegistry.scan`), is
  compressed by ``core.compress`` (``codec="auto"`` under a bit budget
  by default) — synchronously, or on a background worker thread — and
  lands in the registry as a *ready* record.
* **Hot registration**: :meth:`~DeltaRegistry.pump` (called from the
  serving loop between steps) drains ready records into the engine via
  ``engine.register_tenant``. With the engine in table mode
  (``tenant_capacity=``) that is a pre-allocated row write: no restart,
  no decode-step recompile, in-flight sequences untouched.
* **Cold tiers** below :class:`~repro.serve.engine.DeltaResidency`:

  ========  =============================================  ============
  tier      holds                                          owner
  ========  =============================================  ============
  hot       packed rows in the engine's tenant table       TenantTable
  (hotter)  dequantized values under the residency budget  DeltaResidency
  warm      packed tree as host (numpy) arrays             this registry
  cold      packed leaves spooled to disk (npz)            this registry
  ========  =============================================  ============

  Promotion happens on first request (:meth:`~DeltaRegistry.submit`
  re-registers a warm/cold tenant before queueing); eviction is by
  traffic — when the table is full, the least-recently-requested hot
  tenant with no in-flight sequences is retired to warm, and warm
  records beyond ``host_capacity`` spill to the disk spool.
* **Rollout / rollback**: ingesting an existing name is a version
  rollout (new requests only — the engine keeps in-flight sequences on
  the old table row until they drain); the previous version stays warm
  so :meth:`~DeltaRegistry.rollback` is one more rollout away.

Every lifecycle transition emits a typed event on the engine's bus
(``tenant_ready`` / ``tenant_promote`` / ``tenant_evict`` here;
``tenant_register`` / ``tenant_rollout`` / ``tenant_retire`` from the
engine), so Metrics/Tracer/SLO consumers see the lifecycle in the same
stream as the serving events.
"""
from __future__ import annotations

import json
import os
import queue as queue_mod
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import numpy as np

from repro.core.codecs import runtime_delta_tree
from repro.core.compress import compress
from repro.utils import flatten_with_paths, map_with_paths


def _to_host(tree: Any) -> Any:
    """Packed runtime tree -> host (numpy) arrays, same structure."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)


def _save_npz(path: str, arrays: dict) -> None:
    """Atomic npz write (the Checkpointer's tmp+rename pattern), with
    non-native dtypes (bf16 etc.) stored as raw bits + a JSON sidecar."""
    host, bit_dtypes = {}, {}
    for k, v in arrays.items():
        arr = np.asarray(v)
        if arr.dtype.kind == "V" or not arr.dtype.isnative or \
                arr.dtype.name not in np.sctypeDict:
            bit_dtypes[k] = arr.dtype.name
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        host[k] = arr
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz")
    os.close(fd)
    np.savez(tmp, **host)                # .npz suffix: savez keeps the name
    os.replace(tmp, path)
    with open(path + ".json", "w") as f:
        json.dump({"bit_dtypes": bit_dtypes, "leaves": sorted(host)}, f)


def _load_npz(path: str) -> dict:
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta_path = path + ".json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        for k, name in meta.get("bit_dtypes", {}).items():
            arrays[k] = arrays[k].view(np.dtype(name))
    return arrays


@dataclass
class TenantRecord:
    """One tenant's lifecycle state as the registry tracks it."""
    name: str
    state: str                        # queued|compressing|ready|hot|warm|cold
    version: int = 0
    report: Any = None
    host: Any = None                  # warm tier: packed tree, numpy leaves
    treedef: Any = None               # for reloading the cold spool
    spool: Optional[str] = None       # cold tier: npz path
    prev: Any = None                  # previous version (host tree)
    prev_report: Any = None
    last_used: float = float("-inf")  # engine time of the last request
    compress_s: Optional[float] = None
    register_s: Optional[float] = None
    error: Optional[str] = None

    def tier(self) -> str:
        return self.state


class DeltaRegistry:
    """Compress-and-register service around a running engine.

    ::

        eng = ContinuousEngine(cfg, base, tenant_capacity=64, ...)
        reg = DeltaRegistry(eng, base, budget_bits=2.0,
                            watch_dir="incoming/", spool_dir="spool/")
        reg.ingest("support-bot", ft_params)     # or drop an npz in incoming/
        while serving:
            reg.scan(); reg.pump()               # lifecycle work between steps
            eng.step(eng._now())
        req = reg.submit("support-bot", prompt)  # promotes warm/cold first

    ``background=True`` moves compression to a worker thread (the
    serving loop keeps stepping; ``pump()`` picks up finished work).
    Registration itself ALWAYS happens on the caller's thread — the
    engine is not thread-safe, and in table mode registration is one
    cheap row write anyway.
    """

    def __init__(self, engine, base_params: Any, *, spec: Any = None,
                 codec: Optional[str] = "auto",
                 budget_bits: Optional[float] = 2.0,
                 spool_dir: Optional[str] = None,
                 watch_dir: Optional[str] = None,
                 host_capacity: int = 64,
                 background: bool = False):
        self.engine = engine
        self.base = base_params
        self.spec = spec
        self.codec = codec
        self.budget_bits = budget_bits if codec == "auto" else None
        self.spool_dir = spool_dir
        self.watch_dir = watch_dir
        self.host_capacity = int(host_capacity)
        self._records: dict[str, TenantRecord] = {}
        self._busy: set = set()   # names mid-registration: spill must skip
        self._seen_files: set = set()
        self._lock = threading.Lock()
        self._ready: List[tuple] = []     # (name, rt_host, report)
        self._inbox: queue_mod.Queue = queue_mod.Queue()
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if background:
            self._worker = threading.Thread(target=self._worker_loop,
                                            daemon=True)
            self._worker.start()

    # -- ingestion ----------------------------------------------------------
    def ingest(self, name: str, ft_params: Any = None, *,
               deltas: Any = None, report: Any = None) -> TenantRecord:
        """Accept a fine-tuned checkpoint (or pre-compressed deltas).

        Raw params are compressed with the registry's codec/budget —
        inline, or queued to the background worker. The result becomes a
        *ready* record; ``pump()`` hot-registers it. Ingesting an
        existing name is a version rollout."""
        rec = self._records.get(name)
        if rec is None:
            rec = self._records[name] = TenantRecord(name=name,
                                                     state="queued")
        if deltas is not None:
            rt = runtime_delta_tree(deltas)
            with self._lock:
                self._ready.append((name, _to_host(rt), report))
            return rec
        if ft_params is None:
            raise ValueError(
                f"ingest({name!r}) needs ft_params or deltas; got neither")
        if self._worker is not None:
            rec.state = "queued"
            self._inbox.put((name, ft_params))
        else:
            self._compress_one(name, ft_params)
        return rec

    def scan(self) -> List[str]:
        """Pick up new ``<name>.npz`` checkpoints from the watched
        directory (flat param-path keys, the Checkpointer layout) and
        ingest them. Returns the names ingested this call."""
        if self.watch_dir is None or not os.path.isdir(self.watch_dir):
            return []
        out = []
        for fn in sorted(os.listdir(self.watch_dir)):
            if not fn.endswith(".npz") or fn in self._seen_files:
                continue
            self._seen_files.add(fn)
            name = fn[:-len(".npz")]
            ft = self._load_checkpoint(os.path.join(self.watch_dir, fn))
            self.ingest(name, ft)
            out.append(name)
        return out

    def _load_checkpoint(self, path: str) -> Any:
        arrays = _load_npz(path)
        missing = [p for p in flatten_with_paths(self.base) if p not in arrays]
        if missing:
            raise ValueError(
                f"checkpoint {path} is missing {len(missing)} param "
                f"leaves (e.g. {missing[0]!r}); it must mirror the base "
                "params tree")
        return map_with_paths(lambda p, b: arrays[p], self.base)

    def _compress_one(self, name: str, ft_params: Any) -> None:
        rec = self._records[name]
        rec.state = "compressing"
        try:
            deltas, report = compress(self.base, ft_params, self.spec,
                                      codec=self.codec,
                                      budget_bits=self.budget_bits)
            rt = _to_host(runtime_delta_tree(deltas))
        except Exception as e:          # record, don't kill the worker
            rec.state = "failed"
            rec.error = f"{type(e).__name__}: {e}"
            return
        rec.compress_s = report.wall_s
        with self._lock:
            self._ready.append((name, rt, report))
        rec.state = "ready"

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            try:
                name, ft = self._inbox.get(timeout=0.05)
            except queue_mod.Empty:
                continue
            self._compress_one(name, ft)

    def close(self) -> None:
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None

    # -- hot registration ---------------------------------------------------
    def pump(self) -> List[str]:
        """Hot-register every compressed-and-ready tenant (serving-loop
        thread). Returns the names that went hot this call."""
        with self._lock:
            ready, self._ready = self._ready, []
        out = []
        for name, rt, report in ready:
            rec = self._records[name]
            if rec.host is not None:
                rec.prev, rec.prev_report = rec.host, rec.report
            rec.host, rec.report = rt, report
            rec.version += 1
            rec.spool = None            # stale spool: new version supersedes
            self._register(rec)
            out.append(name)
            self.engine.bus.emit("tenant_ready", self.engine._now(),
                                 tenant=name, version=rec.version,
                                 compress_s=rec.compress_s)
        self._spill_warm()
        return out

    def _register(self, rec: TenantRecord) -> None:
        # the busy guard is load-bearing: _ensure_capacity can evict a
        # victim, whose _spill_warm() would otherwise pick THIS record
        # (still state="warm") as the LRU spill target and null its host
        # tree mid-promotion
        self._busy.add(rec.name)
        try:
            self._ensure_capacity(exclude=rec.name)
            t0 = time.perf_counter()
            self.engine.register_tenant(rec.name, rec.host, rec.report)
            rec.register_s = time.perf_counter() - t0
            rec.state = "hot"
        finally:
            self._busy.discard(rec.name)

    def _ensure_capacity(self, exclude: Optional[str] = None) -> None:
        """Make room in the engine's tenant table by evicting the
        least-recently-requested hot tenant (traffic-based eviction).
        No-op for dynamic-mode engines (they re-stack, no fixed rows)."""
        table = getattr(self.engine, "_table", None)
        if table is None:
            return
        while table.n_free == 0:
            self.engine._reclaim_retired()     # drained rollouts free rows
            if table.n_free:
                return
            victim = self._pick_victim(exclude)
            if victim is None:
                return      # nothing evictable: let register_tenant raise
            self.evict(victim)

    def _pick_victim(self, exclude: Optional[str]) -> Optional[str]:
        hot = [r for r in self._records.values()
               if r.state == "hot" and r.name != exclude
               and not self.engine._tenant_in_flight(r.name)
               and not any(q.tenant == r.name
                           for q in self.engine.queue.pending())]
        # hot tenants registered around the registry (engine-direct) are
        # not evictable: the registry has no warm copy to restore them
        if not hot:
            return None
        return min(hot, key=lambda r: (r.last_used, r.name)).name

    # -- tiers --------------------------------------------------------------
    def evict(self, name: str) -> None:
        """Demote a hot tenant to the warm (host RAM) tier; its table
        row is tombstoned and freed. Refuses (RuntimeError, from the
        engine) while the tenant has in-flight or queued requests."""
        rec = self._records[name]
        if rec.state != "hot":
            raise ValueError(f"tenant {name!r} is {rec.state}, not hot")
        self.engine.unregister_tenant(name)
        rec.state = "warm"
        self.engine.bus.emit("tenant_evict", self.engine._now(),
                             tenant=name, tier="warm",
                             last_used=rec.last_used)
        self._spill_warm()

    def _spill_warm(self) -> None:
        """Spill the least-recently-used warm records past
        ``host_capacity`` to the disk spool (cold tier)."""
        if self.spool_dir is None:
            return
        warm = [r for r in self._records.values()
                if r.state == "warm" and r.name not in self._busy]
        warm.sort(key=lambda r: (r.last_used, r.name))
        for rec in warm[:max(0, len(warm) - self.host_capacity)]:
            leaves, treedef = jax.tree.flatten(rec.host)
            rec.spool = os.path.join(
                self.spool_dir, f"{rec.name}-v{rec.version}.npz")
            _save_npz(rec.spool, {str(i): l for i, l in enumerate(leaves)})
            rec.treedef = treedef
            rec.host = None
            rec.state = "cold"
            self.engine.bus.emit("tenant_evict", self.engine._now(),
                                 tenant=rec.name, tier="cold",
                                 last_used=rec.last_used)

    def promote(self, name: str) -> None:
        """Bring a warm/cold tenant back into the engine's tenant table
        (the first-request path; also callable for prewarming)."""
        rec = self._records.get(name)
        if rec is None or rec.state == "hot":
            return
        t0 = time.perf_counter()
        tier = rec.state
        if rec.state == "cold":
            arrays = _load_npz(rec.spool)
            leaves = [arrays[str(i)] for i in range(len(arrays))]
            rec.host = jax.tree.unflatten(rec.treedef, leaves)
            rec.state = "warm"
        if rec.state != "warm" or rec.host is None:
            raise ValueError(
                f"tenant {name!r} is not promotable (state={rec.state})")
        self._register(rec)
        self.engine.bus.emit("tenant_promote", self.engine._now(),
                             tenant=name, tier=tier,
                             promote_s=time.perf_counter() - t0)

    def rollback(self, name: str) -> None:
        """Roll a tenant back to its previous version (one rollout back;
        in-flight sequences of the current version drain on their row)."""
        rec = self._records[name]
        if rec.prev is None:
            raise ValueError(f"tenant {name!r} has no previous version")
        rec.host, rec.prev = rec.prev, rec.host
        rec.report, rec.prev_report = rec.prev_report, rec.report
        rec.version += 1
        if rec.state == "hot":
            self._register(rec)         # rollout path: new requests only
        # warm/cold records just swap payloads; next promotion serves old

    # -- traffic ------------------------------------------------------------
    def submit(self, tenant: Optional[str], prompt, **kw):
        """Queue a request, promoting the tenant first if it is not hot
        (the cold-start path the ``tenant_lifecycle`` bench measures)."""
        if tenant is not None:
            rec = self._records.get(tenant)
            if rec is not None:
                if rec.state in ("warm", "cold"):
                    self.promote(tenant)
                rec.last_used = self.engine._now()
        return self.engine.submit(tenant, prompt, **kw)

    # -- introspection ------------------------------------------------------
    def stats(self) -> dict:
        tiers: dict[str, int] = {}
        for r in self._records.values():
            tiers[r.state] = tiers.get(r.state, 0) + 1
        table = getattr(self.engine, "_table", None)
        return {
            "tenants": {n: r.state for n, r in sorted(self._records.items())},
            "tiers": tiers,
            "table_free_rows": table.n_free if table is not None else None,
            "pending_compress": self._inbox.qsize(),
            "ready": len(self._ready),
        }
