"""Multi-tenant serving engine — the paper's deployment scheme (Fig. 2/3).

One **base model** is resident; each *tenant* (fine-tuned model) registers
only its DeltaDQ-compressed delta. Requests are grouped per tenant and each
group runs the separate-computation path: base matmuls shared, plus the
tenant's packed-delta correction at every linear site. This is exactly the
paper's deployment: memory = base + sum(tiny deltas) instead of N full
fine-tuned models.

The engine is deliberately simple (static batch per tenant, greedy
sampling); the launch-level ``serve.py`` driver adds request queues. Both
prefill and decode are jit'd once per (tenant-group batch shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.arch import ArchConfig
from repro.core.compress import CompressionReport
from repro.models import lm
from repro.utils import tree_bytes


@dataclasses.dataclass
class Tenant:
    name: str
    deltas: Any                       # PackedDelta tree mirroring params
    report: Optional[CompressionReport] = None

    def bytes(self) -> int:
        return tree_bytes(self.deltas)


class DeltaStore:
    """Registry of compressed per-tenant deltas."""

    def __init__(self):
        self._tenants: dict[str, Tenant] = {}

    def register(self, name: str, deltas: Any, report=None) -> Tenant:
        t = Tenant(name, deltas, report)
        self._tenants[name] = t
        return t

    def get(self, name: str) -> Tenant:
        return self._tenants[name]

    def names(self):
        return sorted(self._tenants)

    def total_bytes(self) -> int:
        return sum(t.bytes() for t in self._tenants.values())


class Engine:
    def __init__(self, cfg: ArchConfig, base_params: Any, max_seq: int = 256):
        self.cfg = cfg
        self.base = base_params
        self.max_seq = max_seq
        self.store = DeltaStore()
        self._prefill = jax.jit(lambda p, b, c, d: lm.prefill(cfg, p, b, c, deltas=d))
        self._decode = jax.jit(lambda p, c, t, pos, d: lm.decode_step(cfg, p, c, t, pos, deltas=d))

    def register_tenant(self, name: str, deltas: Any, report=None):
        return self.store.register(name, deltas, report)

    def generate(self, tenant: Optional[str], prompts: np.ndarray,
                 max_new_tokens: int = 16, stop_token: Optional[int] = None,
                 extra_inputs: Optional[dict] = None) -> np.ndarray:
        """Greedy decode for one tenant group. prompts [B, S] int32.

        tenant=None serves the raw base model (control arm).
        """
        deltas = self.store.get(tenant).deltas if tenant else None
        B, S = prompts.shape
        enc_len = 0
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
            if "enc_feats" in batch:
                enc_len = batch["enc_feats"].shape[1]
        cache = lm.init_cache(self.cfg, B, self.max_seq, enc_len=enc_len)
        logits, cache = self._prefill(self.base, batch, cache, deltas)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for t in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.base, cache, tok[:, None],
                                         jnp.int32(S + t), deltas)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = np.stack(out, axis=1)
        if stop_token is not None:
            # mask everything after the first stop token
            stopped = np.cumsum(gen == stop_token, axis=1) > 0
            gen = np.where(np.roll(stopped, 1, axis=1) & stopped, stop_token, gen)
        return gen

    def serve_batch(self, requests: list[tuple[str, np.ndarray]],
                    max_new_tokens: int = 16) -> list[np.ndarray]:
        """Paper's scheme: group requests by tenant, run each group once."""
        by_tenant: dict[str, list[int]] = {}
        for i, (tenant, _) in enumerate(requests):
            by_tenant.setdefault(tenant, []).append(i)
        results: list[Optional[np.ndarray]] = [None] * len(requests)
        for tenant, idxs in by_tenant.items():
            lens = {requests[i][1].shape[-1] for i in idxs}
            for L in lens:  # one jit shape per (tenant, prompt-length) group
                group = [i for i in idxs if requests[i][1].shape[-1] == L]
                prompts = np.stack([requests[i][1] for i in group])
                gen = self.generate(tenant, prompts, max_new_tokens)
                for row, i in enumerate(group):
                    results[i] = gen[row]
        return results  # type: ignore

    def memory_report(self) -> dict:
        base = tree_bytes(self.base)
        deltas = self.store.total_bytes()
        n = max(len(self.store.names()), 1)
        return {
            "base_bytes": base,
            "delta_bytes_total": deltas,
            "n_tenants": n,
            "bytes_vs_n_full_models": (base + deltas) / (base * (n + 1) if n else base),
        }
