"""Multi-tenant serving engines — the paper's deployment scheme (Fig. 2/3).

One **base model** is resident; each *tenant* (fine-tuned model) registers
only its DeltaDQ-compressed delta. Two engines share that model:

* :class:`ContinuousEngine` — the production path. A continuous-batching
  scheduler packs requests from *mixed tenants* into fixed decode slots
  (``serve.scheduler``), a slot-based paged KV cache admits/evicts
  sequences mid-flight (``serve.kv``), and every decode step serves all
  occupied slots at once: a per-slot tenant-id gather over the
  tenant-stacked packed deltas (``core.apply.SlotDelta``) applies each
  row's correction inside one jitted step. Prompt lengths are bucketed
  and left-padded so jit compiles at most once per bucket.

* :class:`Engine` — the original static per-tenant-batch engine, kept as
  the reference path (``generate``) and as a thin compatibility shim:
  ``serve_batch`` now routes through a ContinuousEngine and falls back to
  the legacy per-tenant grouping only where slot dispatch cannot apply
  (heterogeneous compression specs, MoE expert-site deltas, encdec/vlm
  inputs).

Memory stays the paper's point: base + sum(tiny deltas) instead of N
full fine-tuned models.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.arch import ArchConfig
from repro.core.apply import (
    _is_pd,
    combine_slot_deltas,
    dget,
    get_use_pallas,
    stack_tenant_deltas,
    wrap_slot_deltas,
    zero_delta_like,
)
from repro.core.codecs import runtime_delta_tree
from repro.core.compress import CompressionReport
from repro.core.pack import PackedDelta, decode_values
from repro.models import lm
from repro.serve.kv import SlotKVCache
from repro.serve.metrics import Metrics
from repro.serve.trace import EventBus, attribution, path_label
from repro.serve.scheduler import (
    ChunkBudget,
    ChunkQueue,
    LengthBuckets,
    Request,
    RequestQueue,
    Scheduler,
    SlotState,
    tenant_segments,
    tenant_segments_sharded,
)
from repro.utils import tree_bytes


def mask_after_stop(gen: np.ndarray, stop_token: int) -> np.ndarray:
    """Replace every token *after* the first stop token with the stop token.

    ``gen`` [B, T] int. Explicit zero-filled shift: a stop token in the
    final step must not wrap around and corrupt column 0 (the old
    ``np.roll`` implementation did exactly that).
    """
    stopped = np.cumsum(gen == stop_token, axis=1) > 0
    after = np.zeros_like(stopped)
    after[:, 1:] = stopped[:, :-1]
    return np.where(after, stop_token, gen)


@dataclasses.dataclass
class Tenant:
    name: str
    deltas: Any                       # PackedDelta tree mirroring params
    report: Optional[CompressionReport] = None

    def bytes(self) -> int:
        return tree_bytes(self.deltas)

    def codecs(self) -> tuple:
        """Codec names appearing in this tenant's (runtime) delta tree."""
        names = {l.codec for l in jax.tree.leaves(self.deltas, is_leaf=_is_pd)
                 if _is_pd(l)}
        return tuple(sorted(names))


class DeltaStore:
    """Registry of compressed per-tenant deltas.

    ``version`` bumps on every registration so engines can rebuild their
    tenant-stacked dispatch trees lazily; registration order is stable, so
    tenant row indices never shift under a live scheduler. ``unregister``
    DOES shift rows — ContinuousEngine refuses to continue in-flight
    sequences across it (drain first).
    """

    def __init__(self):
        self._tenants: dict[str, Tenant] = {}
        self.version = 0

    def register(self, name: str, deltas: Any, report=None, *,
                 replace: bool = False) -> Tenant:
        if name in self._tenants and not replace:
            # a silent same-name replace keeps the dict insertion order —
            # so the engine's row-shift guard passes — while live
            # sequences of this tenant switch deltas mid-sequence.
            # Callers that really mean "new version" must say so
            # (ContinuousEngine.register_tenant does, after checking the
            # tenant has no in-flight sequences / via the table rollout).
            raise ValueError(
                f"tenant {name!r} is already registered; pass replace=True "
                "(or use ContinuousEngine.register_tenant, which refuses "
                "only while the tenant has in-flight sequences)")
        t = Tenant(name, deltas, report)
        self._tenants[name] = t
        self.version += 1
        return t

    def unregister(self, name: str) -> None:
        self._tenants.pop(name, None)
        self.version += 1

    def snapshot(self) -> tuple:
        """Cheap copy of the registry state (mapping + version cursor),
        so engine mutations can roll back to exactly this state when a
        refresh fails downstream."""
        return (dict(self._tenants), self.version)

    def restore(self, snap: tuple) -> None:
        self._tenants, self.version = dict(snap[0]), snap[1]

    def get(self, name: str) -> Tenant:
        return self._tenants[name]

    def names(self):
        return sorted(self._tenants)

    def ordered(self) -> List[Tenant]:
        """Tenants in registration order (stable stack rows)."""
        return list(self._tenants.values())

    def total_bytes(self) -> int:
        return sum(t.bytes() for t in self._tenants.values())


# ---------------------------------------------------------------------------
# Pre-decoded delta residency (the hot-tenant value cache)
# ---------------------------------------------------------------------------
def residency_bytes_from_mb(mb: float) -> Optional[int]:
    """``--residency-mb``-style knob -> ``residency_budget_bytes=``.

    Decimal MB; 0 (or negative) disables the tier (None). The ONE
    conversion both the launcher and the benches use, so the unit and
    the disable semantics cannot drift between entry points.
    """
    b = int(mb * 1e6)
    return b if b > 0 else None


class DeltaResidency:
    """LRU cache of *dequantized* per-tenant delta values under a byte budget.

    The packed delta stack stays the ground truth; this tier additionally
    keeps, for up to ``capacity`` hot tenant rows, the f32
    ``pack.decode_values`` output of every leaf (shape = the leaf's idx
    shape — ~8x the packed bytes at k=4, still ~10x under dense). A
    decode step whose unique tenant rows are all resident skips the
    per-step code unpack entirely (the values-given path in
    ``core.apply``/``kernels.fallback``); any other step falls back to
    the packed path, which is always correct.

    * **Budget**: ``capacity = budget_bytes // bytes-per-row`` rows
      (capped at the stack height). Below 2 rows the tier disables
      itself — row 0 (the zero delta) is pinned to residency row 0,
      whose zero-initialized buffer IS its decoded value, so at least
      one real tenant must also fit for the tier to ever apply.
    * **Promotion** is a single jitted buffer-row write per missing
      tenant (donated, so it updates in place); values are decoded by
      the same elementwise ``decode_values`` math the packed path runs
      in-step, so resident values are bit-identical to in-step decode
      and the token-identity contract survives.
    * **Demotion** is LRU among rows not referenced by the current
      step; no device work — the row is simply reused.
    * **Mesh**: value buffers place their output-column axis over
      ``model`` wherever it divides (mirroring
      ``delta_shardings(shard_output=True)``), which is the layout the
      shard_map'd values correction consumes natively.
    """

    def __init__(self, stacked: Any, budget_bytes: int, mesh=None):
        leaves = [l for l in jax.tree.leaves(stacked, is_leaf=_is_pd)
                  if _is_pd(l)]
        if not leaves:
            raise ValueError(
                "residency needs a stacked delta tree with PackedDelta "
                f"leaves; got {type(stacked).__name__} with "
                f"{len(jax.tree.leaves(stacked))} non-delta leaves")
        self.n_rows = int(leaves[0].idx.shape[0])
        self.row_bytes = int(sum(
            4 * int(np.prod(l.idx.shape[1:])) for l in leaves))
        self.budget_bytes = int(budget_bytes)
        self.capacity = int(min(self.n_rows,
                                self.budget_bytes // self.row_bytes))
        self.enabled = self.capacity >= 2
        self.hits = self.misses = self.fallback_steps = 0
        self._stacked = stacked
        self._slot_of: dict[int, int] = {}
        self._lru: List[int] = []        # tenant rows, least-recent first
        self._free: List[int] = []
        self.values: Any = None
        if not self.enabled:
            return
        self.values = jax.tree.map(
            lambda d: jnp.zeros((self.capacity, *d.idx.shape[1:]),
                                jnp.float32),
            stacked, is_leaf=_is_pd)
        if mesh is not None and mesh.shape.get("model", 1) > 1:
            from jax.sharding import NamedSharding, PartitionSpec
            n_model = mesh.shape["model"]
            self.values = jax.tree.map(
                lambda v: jax.device_put(v, NamedSharding(
                    mesh, PartitionSpec(*([None] * (v.ndim - 1)
                                          + ["model"]))
                    if v.shape[-1] % n_model == 0 else PartitionSpec())),
                self.values)
        self._slot_of = {0: 0}           # zero delta: decoded values ARE 0
        self._free = list(range(1, self.capacity))
        self._promote = jax.jit(
            lambda vals, stacked_, row, slot: jax.tree.map(
                lambda d, buf: buf.at[slot].set(decode_values(d.index(row))),
                stacked_, vals, is_leaf=_is_pd),
            donate_argnums=0)

    def ensure(self, rows: np.ndarray) -> Optional[np.ndarray]:
        """Make every unique tenant row of ``rows`` resident, promoting
        (and LRU-demoting) as needed; returns the int32 [n_rows]
        tenant-row -> residency-row map, or None when this step must run
        packed (tier disabled, or more unique tenants than capacity)."""
        if not self.enabled:
            return None
        uniq = [int(r) for r in np.unique(np.asarray(rows)) if r != 0]
        if len(uniq) > self.capacity - 1:     # row 0 keeps its pinned slot
            self.fallback_steps += 1
            return None
        missing = [r for r in uniq if r not in self._slot_of]
        self.hits += len(uniq) - len(missing)
        self.misses += len(missing)
        for r in missing:
            if self._free:
                slot = self._free.pop(0)
            else:
                victim = next(v for v in self._lru if v not in uniq)
                self._lru.remove(victim)
                slot = self._slot_of.pop(victim)
            self._slot_of[r] = slot
            self.values = self._promote(self.values, self._stacked,
                                        jnp.int32(r), jnp.int32(slot))
        for r in uniq:                        # refresh recency, MRU last
            if r in self._lru:
                self._lru.remove(r)
            self._lru.append(r)
        res_map = np.zeros(self.n_rows, np.int32)
        for row, slot in self._slot_of.items():
            res_map[row] = slot
        return res_map

    def invalidate(self, rows) -> None:
        """Drop the pre-decoded values of ``rows`` (their packed source
        was rewritten — a tenant-table rollout/retire reused the row);
        the freed residency slots go back to the promotion free list.
        Row 0 stays pinned: the zero delta's values are always zeros."""
        if not self.enabled:
            return
        for r in rows:
            r = int(r)
            if r == 0:
                continue
            slot = self._slot_of.pop(r, None)
            if slot is not None:
                self._free.append(slot)
            if r in self._lru:
                self._lru.remove(r)

    def retarget(self, stacked: Any) -> None:
        """Point promotions at a rewritten stacked tree. Shapes must be
        unchanged (the tenant table guarantees this), so the promote jit
        does not re-trace."""
        self._stacked = stacked

    def reset_counters(self) -> None:
        """Zero the hit/miss/fallback counters; resident rows stay warm."""
        self.hits = self.misses = self.fallback_steps = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "enabled": self.enabled,
            "capacity_rows": self.capacity,
            "row_bytes": self.row_bytes,
            "budget_bytes": self.budget_bytes,
            # the full capacity*row_bytes buffer is committed at
            # construction; resident_bytes is the HOT subset of it
            "allocated_bytes": (self.capacity if self.enabled else 0)
            * self.row_bytes,
            "resident_rows": len(self._slot_of),
            "resident_bytes": len(self._slot_of) * self.row_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else None,
            "fallback_steps": self.fallback_steps,
        }


# ---------------------------------------------------------------------------
# Codec groups: tenants whose runtime packings can share one stack
# ---------------------------------------------------------------------------
def _stack_signature(deltas: Any) -> tuple:
    """Per-leaf packing meta of a runtime delta tree. Two tenants can
    join one tenant stack iff their signatures are equal (same meta the
    ``stack_tenant_deltas`` leaf check enforces, including the codec)."""
    return tuple(
        (l.h_in, l.h_out, l.h_g, l.keep, l.k_bits, l.m, l.codec,
         tuple(l.idx.shape), tuple(l.codes.shape))
        for l in jax.tree.leaves(deltas, is_leaf=_is_pd) if _is_pd(l))


@dataclasses.dataclass
class _CodecGroup:
    """One stack-compatible tenant group of a mixed-codec engine.

    ``stacked`` is the group's tenant-stacked runtime tree with the zero
    delta at its row 0; ``lut`` maps a GLOBAL tenant row (the engine's
    ``_rows`` / scheduler numbering) to this group's local stack row —
    rows the group does not own map to 0, the zero delta, so applying
    every group to every batch row and summing is exact (see
    ``core.apply.MultiSlotDelta``).
    """
    stacked: Any
    lut: np.ndarray                   # int32 [n_global_rows]
    names: List[str]
    codecs: tuple


# ---------------------------------------------------------------------------
# Static tenant table: pre-allocated stack rows for hot registration
# ---------------------------------------------------------------------------
class TenantTable:
    """Pre-allocated tenant-stacked envelope with free rows — the slot
    table's pattern applied to tenants.

    The dynamic path re-stacks the whole tenant dimension on every
    register/unregister, so the stacked tree's leading dim (a jit shape)
    changes and the decode step re-traces. The table instead allocates
    ``capacity + 1`` rows up front (row 0 = the zero delta, as in every
    stack) sized from the FIRST tenant's runtime tree, and lifecycle
    events become row writes:

    * **register** fills a free row via one jitted donated per-leaf row
      write (the ``DeltaResidency`` promote / ``SlotKVCache`` insert
      pattern) — array values change, shapes never do, so the decode jit
      signature is constant and hot registration triggers ZERO decode
      recompiles;
    * **retire** tombstones the row (rewrites it with the zero delta, so
      a stale dispatch of that row decodes to an exact 0.0) and returns
      it to the free list — other tenants' rows never shift;
    * **rollout** writes the new version into a *new* row and the engine
      flips the name→row mapping, so in-flight sequences keep decoding
      against the old row until they drain (new requests only).

    Every tenant must match the template's tree structure AND stack
    signature (``check_compatible``) — the same constraint one
    ``_CodecGroup`` enforces; heterogeneous-codec fleets need the
    dynamic multi-group path.

    Under a mesh the table shards exactly like a dynamic stack
    (``delta_shardings(shard_output=True)`` or replicated) and the row
    write pins ``out_shardings`` so hot registration never drifts the
    layout.
    """

    def __init__(self, template: Any, capacity: int, *, mesh=None,
                 shard_deltas: str = "auto"):
        if capacity < 1:
            raise ValueError(f"tenant_capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.signature = _stack_signature(template)
        self.structure = jax.tree.structure(template, is_leaf=_is_pd)
        self.zero = zero_delta_like(template)
        n = self.capacity + 1

        def alloc(d):
            return PackedDelta(
                jnp.zeros((n, *d.idx.shape), d.idx.dtype),
                jnp.zeros((n, *d.codes.shape), d.codes.dtype),
                jnp.zeros((n, *jnp.shape(d.scale)), jnp.float32),
                jnp.zeros((n, *jnp.shape(d.zero)), jnp.int32),
                d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m,
                d.codec)

        self.stacked = jax.tree.map(alloc, template, is_leaf=_is_pd)
        jit_kw = {}
        if mesh is not None:
            from repro.launch import mesh as mesh_lib
            if shard_deltas == "auto":
                sh = mesh_lib.delta_shardings(self.stacked, mesh,
                                              shard_output=True)
            else:
                from jax.sharding import NamedSharding, PartitionSpec
                repl = NamedSharding(mesh, PartitionSpec())
                sh = jax.tree.map(lambda _: repl, self.stacked)
            self.stacked = mesh_lib.shard_tree(self.stacked, sh)
            jit_kw["out_shardings"] = sh

        def _write(stacked, tree, row):
            return jax.tree.map(
                lambda t, d: PackedDelta(
                    t.idx.at[row].set(d.idx),
                    t.codes.at[row].set(d.codes),
                    t.scale.at[row].set(jnp.asarray(d.scale, jnp.float32)),
                    t.zero.at[row].set(jnp.asarray(d.zero, jnp.int32)),
                    t.h_in, t.h_out, t.h_g, t.keep, t.alpha, t.k_bits,
                    t.m, t.codec),
                stacked, tree, is_leaf=_is_pd)

        # donate the table: registration is an in-place row write, not a
        # copy of every registered tenant's bytes
        self._write_jit = jax.jit(_write, donate_argnums=0, **jit_kw)
        self._free: List[int] = list(range(1, n))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def check_compatible(self, tree: Any) -> None:
        """Raise ValueError unless ``tree`` can fill a row (called BEFORE
        any engine state mutates, so a rejected tenant is a no-op)."""
        got_struct = jax.tree.structure(tree, is_leaf=_is_pd)
        if got_struct != self.structure:
            raise ValueError(
                f"tenant delta tree structure {got_struct} does not match "
                f"the tenant table template {self.structure}; cannot "
                "hot-register")
        got_sig = _stack_signature(tree)
        if got_sig != self.signature:
            raise ValueError(
                f"tenant packing meta signature {got_sig!r} does not "
                f"match the tenant table template {self.signature!r}; "
                "heterogeneous-codec fleets need the dynamic "
                "(tenant_capacity=None) engine")

    def alloc(self) -> int:
        """Claim the lowest free row; ValueError when the table is full."""
        if not self._free:
            raise ValueError(
                f"tenant table full ({self.capacity} rows); retire a "
                "tenant or raise tenant_capacity")
        return self._free.pop(0)

    def free(self, row: int) -> None:
        if row in self._free or not 1 <= row <= self.capacity:
            raise ValueError(f"bad tenant-table row free: {row}")
        self._free.append(row)
        self._free.sort()

    def write(self, row: int, tree: Any) -> None:
        """Fill ``row`` from a runtime delta tree (one jitted row write)."""
        self.stacked = self._write_jit(self.stacked, tree, jnp.int32(row))

    def clear(self, row: int) -> None:
        """Tombstone ``row``: rewrite it with the zero delta (same jit
        shape as ``write``, so retirement adds no compile)."""
        self.write(row, self.zero)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------
class ContinuousEngine:
    """Async continuous-batching server over one base model + N deltas.

    Usage::

        eng = ContinuousEngine(cfg, base_params, n_slots=8, max_seq=256)
        eng.register_tenant("math", deltas)
        req = eng.submit("math", prompt, max_new_tokens=16,
                         on_token=lambda r, tok, done: ...)
        eng.run()                      # drains queue + slots
        req.output()                   # np.ndarray of generated tokens

    jit shape budget: one decode shape (fixed ``n_slots``), one prefill
    shape per length bucket, one cache-insert shape. Mixed tenants share
    all of them.

    ``mesh=`` (a ``(data, model)`` mesh from
    ``launch.mesh.make_serving_mesh``) serves the same loop sharded:
    base weights column-parallel over ``model``, KV rings along
    kv-heads, packed deltas replicated, delta corrections shard_map'd
    per output-column slice — token-identical to the unsharded engine
    (serve/README.md §Mesh serving). Engines with different meshes (or
    none) can coexist in one process; each installs its own mesh before
    stepping.

    ``data=`` (defaulting to the mesh's ``data`` axis extent) splits the
    slot rows into contiguous per-data-shard pools: admission balances
    per-shard occupancy, the decode-step tenant-segment layout is built
    per shard, and KV slot rows live on the shard that admitted them —
    token-identical to ``data=1`` on the same trace (serve/README.md
    §Data-parallel admission).

    ``admission=`` selects the shard-placement policy ("occupancy" —
    the balanced default — or "affinity", which prefers the shard pool
    already hosting the request's tenant within a bounded occupancy
    imbalance, shrinking per-shard unique-tenant counts; or any
    :class:`~repro.serve.scheduler.AdmissionPolicy` instance).

    ``residency_budget_bytes=`` enables the :class:`DeltaResidency`
    tier: hot tenants' dequantized f32 delta values stay resident under
    the byte budget (LRU demotion) and decode steps whose tenants are
    all resident skip the per-step unpack; steps that are not fall back
    to the packed path. Token-identical either way.

    ``chunked_prefill=`` swaps the whole-prompt prefill call for the
    chunk state machine: admission claims the KV slot (reset to the
    clean template) and queues the request on an EDF
    :class:`~repro.serve.scheduler.ChunkQueue`; every step then runs ONE
    combined jit — all decode rows plus at most one ``chunk_size``-token
    prompt chunk threaded through the same tenant-segment delta dispatch
    — so prefilling never preempts in-flight decodes and a burst of
    arrivals amortizes across steps. ``chunk_share`` is the SLO knob
    (:class:`~repro.serve.scheduler.ChunkBudget`): the max fraction of
    steps that may carry chunk work while decodes are active. Token-
    identical to the whole-prompt path (CI-gated at data=1 and the
    (2,4) mesh); serve/README.md §Chunked prefill has the contract.

    ``trace=`` (a :class:`~repro.serve.trace.Tracer`), ``slo=`` (a
    :class:`~repro.serve.telemetry.SLOCounters`) and ``telemetry=`` (a
    :class:`~repro.serve.telemetry.TelemetrySnapshotWriter`) attach
    observability: every hook site emits one typed event on
    ``self.bus`` and all consumers — including ``Metrics`` itself —
    read that same stream. Timestamps come exclusively from the
    injectable clock, so traces are deterministic under
    ``VirtualClock``.
    """

    def __init__(self, cfg: ArchConfig, base_params: Any, *,
                 n_slots: int = 8, max_seq: int = 256, min_bucket: int = 8,
                 store: Optional[DeltaStore] = None, clock=time.monotonic,
                 mesh=None, data: Optional[int] = None,
                 slot_dispatch: str = "segments",
                 shard_deltas: str = "auto",
                 admission="occupancy",
                 residency_budget_bytes: Optional[int] = None,
                 tenant_capacity: Optional[int] = None,
                 chunked_prefill: bool = False, chunk_size: int = 16,
                 chunk_share: float = 1.0,
                 trace=None, slo=None, telemetry=None):
        if cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"continuous batching does not support family={cfg.family!r} "
                "(per-request encoder inputs); use Engine.generate")
        self.cfg = cfg
        self.mesh = mesh
        # data-parallel slot sharding: slot rows split into `data`
        # contiguous shard pools (mesh `data` axis when a mesh is
        # given; a host-side policy shard otherwise — useful for
        # testing the scheduler without devices). Defaults to the
        # mesh's data extent so `mesh=make_serving_mesh(8, data=2)`
        # is sharded end to end with no second knob.
        mesh_data = mesh.shape.get("data", 1) if mesh is not None else 1
        if data is None:
            data = mesh_data
        if mesh is not None and data != mesh_data:
            raise ValueError(
                f"data={data} does not match the mesh's data axis "
                f"({mesh_data}); slot pools must mirror the device shards")
        if data < 1 or n_slots % data:
            raise ValueError(
                f"n_slots={n_slots} must be a positive multiple of "
                f"data={data} (equal contiguous shard pools)")
        self.data = data
        # "segments": unique-tenant decode dispatch (each distinct delta
        # dequantized once per step); "per_row": the legacy per-row
        # gather path, kept as the behavioral fallback.
        if slot_dispatch not in ("segments", "per_row"):
            raise ValueError(f"slot_dispatch={slot_dispatch!r} not in "
                             "('segments', 'per_row')")
        self.slot_dispatch = slot_dispatch
        # "auto": stacked tenant deltas shard their output-column axis
        # over `model` when it divides (delta_shardings(shard_output=True)),
        # replicated otherwise; "replicated": always replicate.
        if shard_deltas not in ("auto", "replicated"):
            raise ValueError(f"shard_deltas={shard_deltas!r} not in "
                             "('auto', 'replicated')")
        self.shard_deltas = shard_deltas
        cache_sh = None
        if mesh is not None:
            # Sharded serving: base weights tensor-parallel over `model`,
            # KV rings along kv-heads, packed deltas replicated; the delta
            # correction runs shard_map'd per output-column slice
            # (core.apply mesh mode; re-installed per step by
            # _install_mesh so mesh and plain engines can coexist).
            from repro.core.apply import set_mesh
            from repro.launch import mesh as mesh_lib
            self._param_sh = mesh_lib.param_shardings(cfg, mesh)
            base_params = mesh_lib.shard_tree(base_params, self._param_sh)
            cache_sh = mesh_lib.cache_shardings(cfg, mesh, n_slots, max_seq)
            set_mesh(mesh)
        self.base = base_params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.store = store if store is not None else DeltaStore()
        # ssm/rec mixers carry sequence state, so left-padding would
        # pollute it: bucket those archs by exact prompt length instead.
        exact = any(k in ("ssm", "rec") for k in cfg.layer_kinds)
        self.buckets = LengthBuckets(min_bucket=min_bucket,
                                     max_bucket=max_seq, exact=exact)
        self.chunked = bool(chunked_prefill)
        self.chunk_size = int(chunk_size)
        self.chunk_share = float(chunk_share)
        if self.chunked:
            # a chunk may not exceed any layer's ring: C tokens scatter
            # into C distinct slots, and duplicate ring slots within one
            # chunk would collide nondeterministically
            min_ring = min((max_seq if w == 0 else min(w, max_seq))
                           for _, _, w in lm.layer_plan(cfg)
                           ) if cfg.n_layers else max_seq
            if not 1 <= self.chunk_size <= min_ring:
                raise ValueError(
                    f"chunk_size={chunk_size} must be in [1, {min_ring}] "
                    f"(the smallest attention ring of this arch/max_seq)")
        # ssm/rec mixers cannot consume right-padded tail chunks (pad
        # tokens would pollute the carried state): exact archs get
        # exact-length tail chunks (one combined shape per distinct tail
        # length), attn-only archs pad every chunk to chunk_size (ONE
        # combined shape; pad K/V writes are dropped in the model)
        self._chunk_pad = not exact
        self._chunks = ChunkQueue(self.chunk_size)
        self._chunk_budget = ChunkBudget(self.chunk_share)
        self._chunk_t0: dict[int, float] = {}    # rid -> admit time
        self.queue = RequestQueue()
        self.sched = Scheduler(n_slots, self.buckets, data_shards=data,
                               admission=admission)
        self.kv = SlotKVCache(cfg, n_slots, max_seq, shardings=cache_sh,
                              data_shards=data)
        self.metrics = Metrics(n_slots, data_shards=data)
        self.clock = clock
        # Observability: every hook site emits one typed event on the
        # bus; Metrics, the Tracer and SLOCounters are all plain
        # consumers of the same stream (serve.trace). `telemetry` is a
        # TelemetrySnapshotWriter driven by engine time in run().
        self.trace = trace
        self.slo = slo
        self.telemetry = telemetry
        self.bus = EventBus([self.metrics, trace, slo])
        # memoised path-attribution notes per jit call signature: the
        # dispatch layers only report while jax traces, so cached
        # executions replay the notes recorded at trace time
        self._path_notes: dict = {}
        # pre-decoded delta residency: built lazily alongside the tenant
        # stack (it mirrors the stacked tree's shapes) and only under the
        # segments dispatch — the per-row path has no values formulation
        self.residency_budget_bytes = residency_budget_bytes
        self.residency: Optional[DeltaResidency] = None
        # tenant_capacity != None switches lifecycle to TABLE mode: a
        # static pre-allocated tenant-table envelope (built lazily from
        # the first tenant's tree) whose rows are filled/tombstoned in
        # place, so register/rollout/retire never re-stack and never
        # change a decode jit shape. None = the dynamic re-stacking path.
        if tenant_capacity is not None:
            if int(tenant_capacity) < 1:
                raise ValueError(
                    f"tenant_capacity must be >= 1, got {tenant_capacity}")
            if len(self.store.names()) > int(tenant_capacity):
                raise ValueError(
                    f"store already holds {len(self.store.names())} tenants "
                    f"> tenant_capacity={tenant_capacity}")
        self.tenant_capacity = (None if tenant_capacity is None
                                else int(tenant_capacity))
        self._table: Optional[TenantTable] = None
        self._retiring: set = set()      # rolled-out rows awaiting drain

        # host mirrors of per-slot decode state (row 0 = zero delta / base)
        self._tok = np.zeros(n_slots, np.int32)
        self._pos = np.zeros(n_slots, np.int32)
        self._row = np.zeros(n_slots, np.int32)

        self._stacked = None          # tenant-stacked deltas tree (1 group)
        self._groups: List[_CodecGroup] = []   # stack-compatible groups
        self._zero_tree = None        # unstacked all-zero tree (base prefill)
        self._rows: dict[str, int] = {}
        self._store_version = -1
        self._t0: Optional[float] = None

        self._prefill = jax.jit(
            lambda p, b, c, d: lm.prefill(cfg, p, b, c, deltas=d))

        def _step(p, c, t, pos, d):
            logits, c = lm.decode_step(cfg, p, c, t, pos, deltas=d)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), c

        # donate the cache: the decode step updates the (dominant) KV
        # allocation in place instead of copying it every token. In mesh
        # mode, pin the outputs (tokens replicated, cache on its layout)
        # so the donated buffers round-trip without resharding.
        jit_kw = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            jit_kw["out_shardings"] = (
                NamedSharding(mesh, PartitionSpec()), cache_sh)
        self._decode = jax.jit(_step, donate_argnums=(1,), **jit_kw)

        # chunked-prefill steps: decode serves ALL slot rows every step
        # (fixed shape), so rows that are free or still mid-prefill get
        # garbage-decoded and then restored from the pre-step cache via
        # the `act` mask — parked rows must keep their (clean or
        # partially prefilled) state bit-exact.
        def _restore(c2, c, act):
            return jax.tree.map(
                lambda new, old: jnp.where(
                    act.reshape(act.shape + (1,) * (new.ndim - 1)), new, old),
                c2, c)

        def _mstep(p, c, t, pos, act, d):
            logits, c2 = lm.decode_step(cfg, p, c, t, pos, deltas=d)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    _restore(c2, c, act))

        def _cstep(p, c, t, pos, act, d, ctok, cpos, cvalid, cslot, cd):
            # slice the chunk row's CLEAN cache before the masked decode
            # garbage-writes it; prefill the chunk against that slice and
            # write the advanced row back after the restore
            row = jax.tree.map(
                lambda l: jax.lax.dynamic_slice_in_dim(l, cslot, 1, axis=0), c)
            logits, c2 = lm.decode_step(cfg, p, c, t, pos, deltas=d)
            c2 = _restore(c2, c, act)
            clog, row2 = lm.prefill_chunk(
                cfg, p, {"tokens": ctok, "positions": cpos, "valid": cvalid},
                row, deltas=cd)
            c2 = jax.tree.map(
                lambda l, r: jax.lax.dynamic_update_slice_in_dim(
                    l, r.astype(l.dtype), cslot, axis=0), c2, row2)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    jnp.argmax(clog, axis=-1).astype(jnp.int32), c2)

        mkw = dict(jit_kw)
        ckw = {}
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            repl = NamedSharding(mesh, PartitionSpec())
            ckw["out_shardings"] = (repl, repl, cache_sh)
        self._decode_masked = jax.jit(_mstep, donate_argnums=(1,), **mkw)
        self._combined = jax.jit(_cstep, donate_argnums=(1,), **ckw)
        self.prefill_shapes: set = set()

        # table mode over a pre-populated store: seed the table with the
        # existing tenants (registration order), exactly as if each had
        # been hot-registered — the identity contract between "all
        # tenants up front" and "registered live" starts here
        if self.tenant_capacity is not None and self.store.names():
            for t in self.store.ordered():
                self._table_admit(t.name, t.deltas)
            self._store_version = self.store.version

    # -- tenants ------------------------------------------------------------
    def register_tenant(self, name: str, deltas: Any, report=None) -> Tenant:
        """Register (or roll out a new version of) a tenant.

        ``deltas`` may be any codec's compressed tree (BitDelta leaves,
        low-rank residual leaves, native PackedDelta); it is lowered to
        the PackedDelta runtime layout here, once, so every downstream
        consumer (prefill, decode, residency) sees one format. A tenant
        whose tree structure cannot join the engine must fail here, not
        mid-run inside a prefill (which would leak the claimed slot) —
        and a rejected registration leaves engine state untouched.

        With ``tenant_capacity=`` (table mode) this is HOT: the new
        tenant fills a pre-allocated table row in place, so a running
        engine picks it up with zero decode-step recompiles; re-register
        of an existing name is the rollout path — the new version lands
        in a fresh row and only NEW requests see it, in-flight sequences
        drain against the old row. In dynamic mode a same-name
        re-register is refused while the tenant has in-flight sequences
        (they would silently switch deltas mid-sequence).
        """
        rt = runtime_delta_tree(deltas)
        if self.tenant_capacity is not None:
            rollout = name in self._rows
            old = self._rows.get(name)
            row, _ = self._table_admit(name, rt)     # raises pre-mutation
            t = self.store.register(name, rt, report, replace=rollout)
            self._store_version = self.store.version
            if self.mesh is not None:
                from repro.launch.mesh import replicate
                t.deltas = replicate(t.deltas, self.mesh)
            if rollout:
                self.bus.emit("tenant_rollout", self._now(), tenant=name,
                              row=row, old_row=old,
                              retiring=len(self._retiring))
            else:
                self.bus.emit("tenant_register", self._now(), tenant=name,
                              row=row, free_rows=self._table.n_free)
            return t
        replace = name in self.store.names()
        if replace and self._tenant_in_flight(name):
            raise RuntimeError(
                f"tenant {name!r} has in-flight sequences; re-registering "
                "would switch their deltas mid-sequence — drain first, or "
                "serve with tenant_capacity= for hot version rollout")
        snap = self.store.snapshot()
        t = self.store.register(name, rt, report, replace=replace)
        try:
            self._refresh_stacked()
        except (ValueError, RuntimeError):
            self.store.restore(snap)
            raise
        if self.mesh is not None:
            from repro.launch.mesh import replicate
            t.deltas = replicate(t.deltas, self.mesh)
        self.bus.emit("tenant_rollout" if replace else "tenant_register",
                      self._now(), tenant=name,
                      row=self._rows.get(name), old_row=None)
        return t

    def unregister_tenant(self, name: str) -> None:
        """Retire a tenant.

        Table mode tombstones its row in place (the row is rewritten
        with the zero delta and returned to the free list — no other
        tenant's row shifts, no recompile). Dynamic mode re-stacks the
        remaining tenants. Both refuse while the tenant has in-flight
        sequences or queued requests, and a refused retire leaves engine
        state untouched.
        """
        self.store.get(name)             # KeyError early for unknown names
        if self._tenant_in_flight(name):
            raise RuntimeError(
                f"tenant {name!r} has in-flight sequences; drain before "
                "retiring")
        if any(r.tenant == name for r in self.queue.pending()):
            raise RuntimeError(
                f"tenant {name!r} has queued requests; drain before "
                "retiring")
        if self.tenant_capacity is not None:
            row = self._rows.pop(name)
            self.store.unregister(name)
            self._store_version = self.store.version
            self._table.clear(row)
            self._table.free(row)
            if self.residency is not None:
                self.residency.invalidate([row])
            self._sync_table_group()
            self.bus.emit("tenant_retire", self._now(), tenant=name,
                          row=row, free_rows=self._table.n_free)
            return
        snap = self.store.snapshot()
        self.store.unregister(name)
        try:
            self._refresh_stacked()
        except (ValueError, RuntimeError):
            self.store.restore(snap)
            raise
        self.bus.emit("tenant_retire", self._now(), tenant=name, row=None)

    def _tenant_in_flight(self, name: str) -> bool:
        return any(self.sched.slots[s].request.tenant == name
                   for s in self.sched.active_slots())

    # -- tenant table (hot lifecycle) ---------------------------------------
    def _table_admit(self, name: str, rt: Any) -> tuple:
        """Fill a tenant-table row for ``name`` (no store writes, no
        events — both seeding and hot registration route here). Returns
        ``(row, old_row)``. Everything fallible happens before the first
        mutation, so a rejected tenant leaves the engine untouched."""
        moe = dget(rt, "moe")
        if moe is not None and any(
                isinstance(dget(moe, k), PackedDelta)
                for k in ("wi", "wg", "wo")):
            raise ValueError(
                "slot dispatch cannot apply deltas at MoE expert "
                "sites; serve MoE tenants via per-tenant grouping")
        if self._table is None:
            # first tenant fixes the template: envelope built once, here
            table = TenantTable(rt, self.tenant_capacity, mesh=self.mesh,
                                shard_deltas=self.shard_deltas)
            zero = table.zero
            if self.mesh is not None:
                from repro.launch import mesh as mesh_lib
                zero = mesh_lib.replicate(zero, self.mesh)
            self._table = table
            self._zero_tree = zero
            # ONE group with an identity LUT for the table's whole life:
            # the decode jit signature (len(_groups), shapes) is fixed at
            # capacity, so later registrations can't change it
            lut = np.arange(self.tenant_capacity + 1, dtype=np.int32)
            codecs = tuple(sorted({sig[6] for sig in table.signature}))
            self._groups = [_CodecGroup(stacked=table.stacked, lut=lut,
                                        names=[], codecs=codecs)]
            self._stacked = table.stacked
            if self.residency_budget_bytes \
                    and self.slot_dispatch == "segments":
                self.residency = DeltaResidency(
                    self._stacked, self.residency_budget_bytes,
                    mesh=self.mesh)
        else:
            self._table.check_compatible(rt)
        self._reclaim_retired()
        row = self._table.alloc()        # ValueError when full, pre-mutation
        old = self._rows.get(name)
        self._table.write(row, rt)
        self._rows[name] = row
        if old is not None:
            # rollout: in-flight sequences keep decoding the old row
            # until they drain; tombstone it now if nothing references it
            live = {int(self.sched.slots[s].tenant_row)
                    for s in self.sched.active_slots()}
            if old in live:
                self._retiring.add(old)
            else:
                self._table.clear(old)
                self._table.free(old)
                if self.residency is not None:
                    self.residency.invalidate([old])
        self._sync_table_group()
        return row, old

    def _sync_table_group(self) -> None:
        """Re-point dispatch at the table's current arrays (row writes
        return fresh buffers) — bookkeeping only, shapes never change."""
        g = self._groups[0]
        g.stacked = self._table.stacked
        g.names = [n for n, _ in
                   sorted(self._rows.items(), key=lambda kv: kv[1])]
        self._stacked = self._table.stacked
        if self.residency is not None:
            self.residency.retarget(self._stacked)

    def _reclaim_retired(self) -> None:
        """Tombstone rolled-out rows once their last in-flight sequence
        drains (lazy: checked at request finish and before row alloc)."""
        if not self._retiring:
            return
        live = {int(self.sched.slots[s].tenant_row)
                for s in self.sched.active_slots()}
        done = sorted(self._retiring - live)
        if not done:
            return
        for row in done:
            self._table.clear(row)
            self._table.free(row)
            self._retiring.discard(row)
            if self.residency is not None:
                self.residency.invalidate([row])
        self._sync_table_group()

    def _refresh_stacked(self) -> None:
        if self.tenant_capacity is not None:
            return   # table mode: dispatch state is maintained per row write
        if self._store_version == self.store.version:
            return
        tenants = self.store.ordered()
        # Stage EVERYTHING into locals, validate, then commit: a failed
        # register/unregister must leave the engine exactly as it was
        # (the old code tore down residency and rebuilt _groups/_rows
        # before the in-flight guard could fire, leaving a half-refreshed
        # engine behind the RuntimeError).
        new_groups: List[_CodecGroup] = []
        new_stacked = None
        new_zero = None
        new_rows: dict[str, int] = {}
        if tenants:
            ref_struct = jax.tree.structure(tenants[0].deltas, is_leaf=_is_pd)
            for t in tenants:
                moe = dget(t.deltas, "moe")
                if moe is not None and any(
                        isinstance(dget(moe, k), PackedDelta)
                        for k in ("wi", "wg", "wo")):
                    raise ValueError(
                        "slot dispatch cannot apply deltas at MoE expert "
                        "sites; serve MoE tenants via per-tenant grouping")
                if jax.tree.structure(t.deltas, is_leaf=_is_pd) != ref_struct:
                    # codec groups relax the *packing* meta, not the tree
                    # shape: combining per-group corrections needs every
                    # group's tree to mirror the same param sites
                    raise ValueError(
                        "tenant delta trees differ in structure; "
                        "cannot stack for slot dispatch")
            new_zero = zero_delta_like(tenants[0].deltas)
            new_rows = {t.name: i + 1 for i, t in enumerate(tenants)}
            # partition tenants into stack-compatible groups (first-fit in
            # registration order, so group membership — and therefore each
            # group's local rows — never reorders under appends). Tenants
            # with one codec/spec land in a single group: the existing
            # single-stack behavior, bit for bit.
            buckets: List[tuple] = []    # (signature, [(global_row, Tenant)])
            for i, t in enumerate(tenants):
                sig = _stack_signature(t.deltas)
                for bsig, members in buckets:
                    if bsig == sig:
                        members.append((i + 1, t))
                        break
                else:
                    buckets.append((sig, [(i + 1, t)]))
            n_global = len(tenants) + 1
            for _, members in buckets:
                # row 0 = zero delta so base requests (and rows owned by
                # OTHER groups) share the decode shape and decode to 0
                zero_g = zero_delta_like(members[0][1].deltas)
                stacked_g = stack_tenant_deltas(
                    [zero_g] + [t.deltas for _, t in members])
                lut = np.zeros(n_global, np.int32)
                for local, (grow, _) in enumerate(members, start=1):
                    lut[grow] = local
                if self.mesh is not None:
                    # compressed deltas are tiny: place them across the
                    # mesh once, at registration, not on every decode
                    # step. The stacked dispatch tree shards its
                    # output-column axis over `model` where it divides
                    # (each shard then holds only its slice of the
                    # compressed bytes — the layout the shard_map'd
                    # correction consumes natively); delta_shardings
                    # falls back to replicated per leaf.
                    from repro.launch import mesh as mesh_lib
                    if self.shard_deltas == "auto":
                        stacked_g = mesh_lib.shard_tree(
                            stacked_g,
                            mesh_lib.delta_shardings(stacked_g, self.mesh,
                                                     shard_output=True))
                    else:
                        stacked_g = mesh_lib.replicate(stacked_g, self.mesh)
                codecs = tuple(sorted(
                    {c for _, t in members for c in t.codecs()}))
                new_groups.append(_CodecGroup(
                    stacked=stacked_g, lut=lut,
                    names=[t.name for _, t in members], codecs=codecs))
            # single group == the classic homogeneous engine: keep the
            # stacked tree on its historical attribute (residency and
            # introspection read it); mixed-codec engines expose _groups
            new_stacked = new_groups[0].stacked \
                if len(new_groups) == 1 else None
            if self.mesh is not None:
                from repro.launch import mesh as mesh_lib
                new_zero = mesh_lib.replicate(new_zero, self.mesh)
        # registration is append-only so rows never shift — but a live
        # unregister would remap rows under in-flight sequences, silently
        # decoding them with another tenant's delta. Refuse instead —
        # BEFORE committing (and before allocating residency buffers).
        for slot in self.sched.active_slots():
            state = self.sched.slots[slot]
            want = new_rows.get(state.request.tenant, 0) \
                if state.request.tenant else 0
            if want != state.tenant_row:
                raise RuntimeError(
                    f"tenant stack rows shifted under in-flight request "
                    f"{state.request.rid} (tenant {state.request.tenant!r}); "
                    "drain the engine before unregistering tenants")
        new_res = None
        if tenants and self.residency_budget_bytes \
                and self.slot_dispatch == "segments" \
                and len(new_groups) == 1:
            # the residency tier keys its value buffers to ONE stack's
            # rows; mixed-codec engines serve packed (still correct)
            new_res = DeltaResidency(
                new_stacked, self.residency_budget_bytes, mesh=self.mesh)
        # commit atomically: nothing above mutated engine state
        self.residency = new_res
        self._groups = new_groups
        self._stacked = new_stacked
        self._zero_tree = new_zero
        self._rows = new_rows
        self._store_version = self.store.version

    # -- request API --------------------------------------------------------
    def submit(self, tenant: Optional[str], prompt: np.ndarray, *,
               max_new_tokens: int = 16, stop_token: Optional[int] = None,
               arrival: float = 0.0, deadline: Optional[float] = None,
               on_token=None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.buckets.bucket(len(prompt))   # raises if no bucket fits
        # live positions are 0..L+new-1; left-pad slots carry invalid
        # positions and may be overwritten, so they don't count against
        # the ring capacity
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_seq={self.max_seq}")
        if tenant is not None:
            self.store.get(tenant)   # KeyError early for unknown tenants
        req = self.queue.submit(tenant, prompt, max_new_tokens=max_new_tokens,
                                stop_token=stop_token, arrival=arrival,
                                deadline=deadline, on_token=on_token)
        self.bus.emit("submit", req.arrival, rid=req.rid, tenant=tenant,
                      prompt_len=len(prompt), max_new_tokens=max_new_tokens,
                      deadline=deadline)
        return req

    # -- scheduling core ----------------------------------------------------
    def _now(self) -> float:
        """Engine-relative time; the timebase of Request.arrival/deadline."""
        if self._t0 is None:
            self._t0 = self.clock()
        return self.clock() - self._t0

    def _install_mesh(self) -> None:
        """Install THIS engine's mesh (or None) and slot-dispatch mode as
        the process-global apply-mode before any call that may trace —
        engines with different modes can then coexist in one process
        (each jit traces at most once per shape, under its owner's
        modes)."""
        from repro.core.apply import set_mesh, set_slot_dispatch
        set_mesh(self.mesh)
        set_slot_dispatch(self.slot_dispatch)

    def _prefill_into(self, slot: int, req: Request, now: float) -> None:
        self._install_mesh()
        self._refresh_stacked()
        L = req.prompt_len
        bucket = self.buckets.bucket(L)
        pad = bucket - L
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, pad:] = req.prompt
        positions = (np.arange(bucket, dtype=np.int32) - pad)[None]
        if req.tenant is not None:
            deltas = self.store.get(req.tenant).deltas
        else:
            deltas = self._zero_tree    # None when no tenants registered
        row_cache = lm.init_cache(self.cfg, 1, self.max_seq)
        self.prefill_shapes.add(bucket)
        sig = ("prefill", bucket)
        with attribution() as notes:
            logits, row_cache = self._prefill(
                self.base, {"tokens": jnp.asarray(tokens),
                            "positions": jnp.asarray(positions)},
                row_cache, deltas)
        if notes:   # dispatch sites only report while jax traces
            self.bus.emit("jit_trace", now, signature=sig, site="prefill",
                          first=sig not in self._path_notes,
                          notes=list(notes))
            self._path_notes[sig] = list(notes)
        self.kv.insert(slot, row_cache)

        first = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
        t_first = self._now()
        slack = None if req.deadline is None else req.deadline - now
        self.bus.emit("admit", now, rid=req.rid, tenant=req.tenant, slot=slot,
                      wait=now - req.arrival, deadline_slack=slack,
                      prompt_len=L, bucket=bucket)
        self.bus.emit("prefill", t_first, rid=req.rid, tenant=req.tenant,
                      t_start=now, prompt_len=L, bucket=bucket, slot=slot)
        self.bus.emit("first_token", t_first, rid=req.rid, tenant=req.tenant,
                      ttft=t_first - req.arrival)
        self.bus.emit("token", t_first, rid=req.rid, tenant=req.tenant)
        if self.data > 1:
            self.bus.emit("shard_token", t_first,
                          shard=self.sched.shard_of(slot))
        req.t_first_token = t_first
        fin = req.emit(first)

        self._tok[slot] = first
        self._pos[slot] = L
        self._row[slot] = self._rows.get(req.tenant, 0) if req.tenant else 0
        self.sched.place(slot, SlotState(request=req, next_token=first,
                                         pos=L, tenant_row=self._row[slot]))
        if fin:
            self._finish(slot, t_first)

    def _finish(self, slot: int, now: float) -> None:
        state = self.sched.slots[slot]
        req = state.request
        req.t_done = now
        ttft = None if req.t_first_token is None \
            else req.t_first_token - req.arrival
        slack = None if req.deadline is None else req.deadline - now
        self.bus.emit("done", now, rid=req.rid, tenant=req.tenant,
                      latency=now - req.arrival, ttft=ttft,
                      n_tokens=len(req.tokens), deadline_slack=slack)
        self.sched.release(slot)
        self.kv.release(slot)
        # park the freed slot on tenant row 0 so stale rows don't inflate
        # the unique-tenant segment count of subsequent decode steps
        self._row[slot] = 0
        if self._retiring:
            # a rollout's old row may just have lost its last reference
            self._reclaim_retired()

    # -- chunked prefill ----------------------------------------------------
    def _admit_chunked(self, slot: int, req: Request, now: float) -> None:
        """Claim a slot for chunked prefill: no device prefill happens
        here — the request joins the EDF chunk queue and the combined
        step streams its prompt in ``chunk_size``-token chunks."""
        self._install_mesh()
        self._refresh_stacked()
        # the previous occupant's ring pos markers / ssm state would be
        # attended as valid context by mid-sequence appends: reset first
        self.kv.reset(slot)
        row = self._rows.get(req.tenant, 0) if req.tenant else 0
        self._row[slot] = row
        self._tok[slot] = 0
        self._pos[slot] = 0
        self.sched.place(slot, SlotState(request=req, next_token=0, pos=0,
                                         tenant_row=row, prefilling=True))
        self._chunks.add(slot, req)
        self._chunk_t0[req.rid] = now
        slack = None if req.deadline is None else req.deadline - now
        self.bus.emit("admit", now, rid=req.rid, tenant=req.tenant, slot=slot,
                      wait=now - req.arrival, deadline_slack=slack,
                      prompt_len=req.prompt_len, bucket=None)

    def _combined_step(self, now: float) -> bool:
        """One chunked-mode step: all decode rows + at most one prompt
        chunk, inside ONE jit call. Returns False when idle."""
        active = self.sched.active_slots()
        decode_slots = [s for s in active
                        if not self.sched.slots[s].prefilling]
        task = None
        if self._chunk_budget.grant(len(decode_slots), len(self._chunks)):
            task = self._chunks.next_task()
        if task is None and not decode_slots:
            return False
        self._install_mesh()
        self._refresh_stacked()
        act = np.zeros(self.n_slots, bool)
        act[decode_slots] = True
        # parked slots (free, or mid-prefill) are masked to tenant row 0
        # so their tenants are not dequantized and don't inflate the
        # unique-tenant segment count
        rows_eff = np.where(act, self._row, 0)
        sd, res_used = self._slot_delta(rows_eff)
        if task is None:
            sig = ("decode_masked", len(self._groups), bool(res_used))
            with attribution() as notes:
                nxt, new_cache = self._decode_masked(
                    self.base, self.kv.cache,
                    jnp.asarray(self._tok[:, None]), jnp.asarray(self._pos),
                    jnp.asarray(act), sd)
            cn = None
            site = "decode_masked"
        else:
            req = task.request
            C = self.chunk_size if self._chunk_pad else task.length
            ctok = np.zeros((1, C), np.int32)
            ctok[0, :task.length] = req.prompt[task.start:
                                               task.start + task.length]
            # pad positions run past every real query position, so the
            # padded keys are causally masked; their K/V ring writes are
            # dropped by the model's valid mask
            cpos = (task.start + np.arange(C, dtype=np.int32))[None]
            cvalid = np.zeros((1, C), bool)
            cvalid[0, :task.length] = True
            cd = self._chunk_delta(int(self._row[task.slot]))
            sig = ("combined", C, len(self._groups), bool(res_used))
            with attribution() as notes:
                nxt, cn, new_cache = self._combined(
                    self.base, self.kv.cache,
                    jnp.asarray(self._tok[:, None]), jnp.asarray(self._pos),
                    jnp.asarray(act), sd, jnp.asarray(ctok),
                    jnp.asarray(cpos), jnp.asarray(cvalid),
                    jnp.int32(task.slot), cd)
            site = "combined"
        if notes:   # non-empty notes == this call (re)traced under jit
            self.bus.emit("jit_trace", now, signature=sig, site=site,
                          first=sig not in self._path_notes,
                          notes=list(notes))
            self._path_notes[sig] = list(notes)
        path_notes = self._path_notes.get(sig, [])
        self.kv.update(new_cache)
        nxt = np.asarray(nxt)
        t = self._now()
        self.bus.emit(
            "step", t, t_start=now, n_active=len(decode_slots),
            chunk_tokens=task.length if task is not None else 0,
            shard_active=self.sched.shard_occupancy() if self.data > 1
            else None,
            shard_unique=self.sched.shard_unique_tenants(rows_eff),
            residency_used=res_used,
            path="base" if sd is None else path_label(path_notes),
            notes=path_notes, recompiled=bool(notes))
        for slot in decode_slots:
            state = self.sched.slots[slot]
            req = state.request
            tok = int(nxt[slot])
            self._tok[slot] = tok
            self._pos[slot] += 1
            state.next_token = tok
            state.pos = int(self._pos[slot])
            fin = req.emit(tok)
            self.bus.emit("token", t, rid=req.rid, tenant=req.tenant)
            if self.data > 1:
                self.bus.emit("shard_token", t,
                              shard=self.sched.shard_of(slot))
            if fin:
                self._finish(slot, t)
        if task is not None:
            req = task.request
            self._chunks.advance(task)
            state = self.sched.slots[task.slot]
            state.pos = task.start + task.length
            self.bus.emit("prefill_chunk", t, rid=req.rid, tenant=req.tenant,
                          slot=task.slot, t_start=now, start=task.start,
                          length=task.length, last=task.last,
                          n_decode=len(decode_slots))
            if task.last:
                # the final chunk's last real position predicts the first
                # generated token — exactly what whole-prompt prefill's
                # h[:, -1:] unembed returns
                first = int(np.asarray(cn)[0, task.length - 1])
                L = req.prompt_len
                self.bus.emit("prefill", t, rid=req.rid, tenant=req.tenant,
                              t_start=self._chunk_t0.pop(req.rid, now),
                              prompt_len=L, bucket=None, slot=task.slot)
                self.bus.emit("first_token", t, rid=req.rid,
                              tenant=req.tenant, ttft=t - req.arrival)
                self.bus.emit("token", t, rid=req.rid, tenant=req.tenant)
                if self.data > 1:
                    self.bus.emit("shard_token", t,
                                  shard=self.sched.shard_of(task.slot))
                req.t_first_token = t
                self._tok[task.slot] = first
                self._pos[task.slot] = L
                state.prefilling = False
                state.next_token = first
                state.pos = L
                fin = req.emit(first)
                if fin:
                    self._finish(task.slot, t)
        return True

    def _slot_delta(self, rows: np.ndarray):
        """Per-slot delta dispatch tree for one decode step.

        ``rows`` is the [n_slots] GLOBAL tenant-row vector the step should
        serve (the chunked path masks parked slots to row 0 so their
        tenants are not dequantized). Returns ``(sd, res_used)``.
        """
        sd = None
        res_used = None
        parts = []
        for g in self._groups:
            # group-local rows: slots owned by another group's tenants map
            # to this group's row 0 (the zero delta) and contribute an
            # exact 0.0 to the summed correction — which is what keeps
            # mixed-codec decode token-identical to serving each tenant
            # alone
            rows_g = g.lut[rows]
            seg = None
            values = res_map = None
            if self.slot_dispatch == "segments":
                # host-side layout: rows grouped by tenant, static
                # shapes — the decode jit still compiles exactly once.
                # With data>1 the per-shard [D, B_s] form is built
                # instead: the sort stays within each shard pool and the
                # shard_map'd correction hands every data shard its own
                # pool's rows + segments, so each shard dequantizes only
                # the tenants it actually hosts.
                if self.data > 1:
                    seg = tenant_segments_sharded(rows_g, self.data)
                else:
                    seg = tenant_segments(rows_g)
                seg = jax.tree.map(jnp.asarray, seg)
                # the residency tier targets the XLA host path (it
                # removes the per-step code unpack); under the Pallas
                # backend the segments kernel already decodes each tile
                # once per segment, so attaching values would demote
                # decode to the XLA fallback — checked per step, like
                # the other apply-mode globals in _install_mesh
                if self.residency is not None and not get_use_pallas():
                    # promote this step's tenants into the value cache;
                    # None (over capacity) -> packed path, still correct.
                    # Attaching values changes the SlotDelta pytree
                    # structure, so a residency engine compiles at most
                    # TWO decode shapes (values + packed), not per step.
                    # (Residency only exists when len(_groups) == 1, so
                    # rows_g here is the identity map over `rows`.)
                    rm = self.residency.ensure(rows_g)
                    res_used = rm is not None
                    if res_used:
                        values = self.residency.values
                        res_map = jnp.asarray(rm)
            parts.append(wrap_slot_deltas(g.stacked, jnp.asarray(rows_g),
                                          segments=seg, values=values,
                                          res_map=res_map))
        if parts:
            sd = combine_slot_deltas(parts)
        return sd, res_used

    def _chunk_delta(self, row: int):
        """Batch-1 slot-delta tree for one prefill chunk's tenant row.

        The chunk threads the SAME segment dispatch as decode (one-row
        segment layout), so its per-tenant correction stays token-
        identical to the whole-prompt path's per-tenant prefill.
        """
        if not self._groups:
            return None
        parts = []
        for g in self._groups:
            rows_g = np.asarray([g.lut[row]], np.int32)
            seg = None
            if self.slot_dispatch == "segments":
                seg = jax.tree.map(jnp.asarray, tenant_segments(rows_g))
            parts.append(wrap_slot_deltas(g.stacked, jnp.asarray(rows_g),
                                          segments=seg))
        return combine_slot_deltas(parts)

    def _decode_all(self, now: float) -> None:
        active = self.sched.active_slots()
        if not active:
            return
        self._install_mesh()
        self._refresh_stacked()
        sd, res_used = self._slot_delta(self._row)
        sig = ("decode", len(self._groups), bool(res_used))
        with attribution() as notes:
            nxt, new_cache = self._decode(
                self.base, self.kv.cache, jnp.asarray(self._tok[:, None]),
                jnp.asarray(self._pos), sd)
        if notes:   # non-empty notes == this call (re)traced under jit
            self.bus.emit("jit_trace", now, signature=sig, site="decode",
                          first=sig not in self._path_notes,
                          notes=list(notes))
            self._path_notes[sig] = list(notes)
        path_notes = self._path_notes.get(sig, [])
        self.kv.update(new_cache)
        nxt = np.asarray(nxt)
        t = self._now()
        self.bus.emit(
            "step", t, t_start=now, n_active=len(active),
            shard_active=self.sched.shard_occupancy() if self.data > 1
            else None,
            shard_unique=self.sched.shard_unique_tenants(self._row),
            residency_used=res_used,
            path="base" if sd is None else path_label(path_notes),
            notes=path_notes, recompiled=bool(notes))
        for slot in active:
            state = self.sched.slots[slot]
            req = state.request
            tok = int(nxt[slot])
            self._tok[slot] = tok
            self._pos[slot] += 1
            state.next_token = tok
            state.pos = int(self._pos[slot])
            fin = req.emit(tok)
            self.bus.emit("token", t, rid=req.rid, tenant=req.tenant)
            if self.data > 1:
                self.bus.emit("shard_token", t,
                              shard=self.sched.shard_of(slot))
            if fin:
                self._finish(slot, t)

    def step(self, now: float) -> bool:
        """One scheduler iteration: admit into free slots, then decode."""
        worked = False
        for slot, req in self.sched.admit(self.queue, now):
            self.kv.claim(slot)      # kv free list mirrors the slot table
            if self.chunked:
                self._admit_chunked(slot, req, now)
            else:
                self._prefill_into(slot, req, now)
            worked = True
        if self.chunked:
            worked = self._combined_step(now) or worked
        elif self.sched.n_active:
            self._decode_all(now)
            worked = True
        return worked

    def run(self, max_steps: int = 1_000_000) -> Metrics:
        """Drain the queue and all slots; returns the metrics collector."""
        self.bus.emit("start", self._now())
        for _ in range(max_steps):
            if not len(self.queue) and not self.sched.n_active:
                break
            now = self._now()
            worked = self.step(now)
            if self.telemetry is not None:
                # driven by the same `now` as the step: zero extra clock
                # reads, deterministic snapshot times under VirtualClock
                self.telemetry.maybe_write(now, self._telemetry_payload)
            if not worked:
                # nothing active and no arrived request: jump (virtual
                # clock) or sleep (real clock) to the next arrival
                nxt = self.queue.next_arrival()
                if nxt is None:
                    break
                if hasattr(self.clock, "advance"):
                    self.clock.advance(max(0.0, nxt - self._now()))
                else:
                    time.sleep(max(0.0, min(0.01, nxt - self._now())))
        else:
            raise RuntimeError(f"serve loop did not drain in {max_steps} steps")
        self.bus.emit("stop", self._now())
        if self.residency is not None:
            self.metrics.residency = self.residency.stats()
        return self.metrics

    def _telemetry_payload(self) -> dict:
        """Snapshot body for the periodic telemetry writer."""
        if self.residency is not None:
            self.metrics.residency = self.residency.stats()
        payload = {"metrics": self.metrics.report()}
        if self.slo is not None:
            payload["slo"] = self.slo.report()
        return payload

    def reset_metrics(self) -> None:
        """Fresh metrics collector (e.g. after jit warmup), same engine.

        Residency *counters* reset with the metrics window; resident
        rows stay warm (they are engine state, like compiled jits). The
        event bus is rebuilt around the new collector; an attached
        tracer/SLO consumer keeps its history (a trace spans the whole
        engine lifetime, like the compiled jits do)."""
        self.metrics = Metrics(self.n_slots, data_shards=self.data)
        self.bus = EventBus([self.metrics, self.trace, self.slo])
        if self.residency is not None:
            self.residency.reset_counters()
        self._t0 = None

    def serve(self, requests: List[tuple], max_new_tokens: int = 16) -> List[np.ndarray]:
        """Convenience: submit (tenant, prompt) pairs, run, return outputs."""
        reqs = [self.submit(t, p, max_new_tokens=max_new_tokens)
                for t, p in requests]
        self.run()
        return [r.output() for r in reqs]


# ---------------------------------------------------------------------------
# Static engine (reference path + compatibility shim)
# ---------------------------------------------------------------------------
class Engine:
    def __init__(self, cfg: ArchConfig, base_params: Any, max_seq: int = 256,
                 clock=time.monotonic):
        self.cfg = cfg
        self.base = base_params
        self.max_seq = max_seq
        self.clock = clock           # forwarded to the serve_batch shim so
        self.store = DeltaStore()    # tests can inject a VirtualClock
        self._prefill = jax.jit(lambda p, b, c, d: lm.prefill(cfg, p, b, c, deltas=d))
        self._decode = jax.jit(lambda p, c, t, pos, d: lm.decode_step(cfg, p, c, t, pos, deltas=d))
        self._cont: Optional[ContinuousEngine] = None

    def register_tenant(self, name: str, deltas: Any, report=None):
        # lower any codec's compressed tree to the PackedDelta runtime
        # layout once here; generate() reads store.get(...).deltas directly
        return self.store.register(name, runtime_delta_tree(deltas), report)

    def generate(self, tenant: Optional[str], prompts: np.ndarray,
                 max_new_tokens: int = 16, stop_token: Optional[int] = None,
                 extra_inputs: Optional[dict] = None) -> np.ndarray:
        """Greedy decode for one tenant group. prompts [B, S] int32.

        tenant=None serves the raw base model (control arm).
        """
        deltas = self.store.get(tenant).deltas if tenant else None
        B, S = prompts.shape
        enc_len = 0
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
            if "enc_feats" in batch:
                enc_len = batch["enc_feats"].shape[1]
        cache = lm.init_cache(self.cfg, B, self.max_seq, enc_len=enc_len)
        logits, cache = self._prefill(self.base, batch, cache, deltas)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for t in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.base, cache, tok[:, None],
                                         jnp.int32(S + t), deltas)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = np.stack(out, axis=1)
        if stop_token is not None:
            gen = mask_after_stop(gen, stop_token)
        return gen

    # -- continuous-batching shim -------------------------------------------
    def _continuous(self) -> ContinuousEngine:
        if self._cont is None:
            self._cont = ContinuousEngine(
                self.cfg, self.base, n_slots=8, max_seq=self.max_seq,
                store=self.store, clock=self.clock)
        return self._cont

    def serve_batch(self, requests: list[tuple[str, np.ndarray]],
                    max_new_tokens: int = 16) -> list[np.ndarray]:
        """Serve a mixed request batch.

        Thin shim over :class:`ContinuousEngine`; falls back to the legacy
        per-tenant static grouping when slot dispatch cannot apply to this
        arch/delta combination. Heterogeneous packing specs and mixed
        codecs are NOT a fallback case anymore: the continuous engine
        partitions tenants into stack-compatible codec groups and sums
        the per-group corrections.
        """
        try:
            eng = self._continuous()
            eng._refresh_stacked()   # raises for non-stackable tenant sets
        except (ValueError, NotImplementedError):
            # slot dispatch inapplicable (MoE deltas, mismatched tree
            # structure, encdec/vlm): legacy per-tenant grouping serves
            return self._serve_batch_grouped(requests, max_new_tokens)
        for tenant, prompt in requests:
            # capacity errors must NOT fall back: the grouped path would
            # silently ring-wrap the cache and truncate context
            L = len(np.asarray(prompt).reshape(-1))
            eng.buckets.bucket(L)
            if L + max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request (prompt {L} + max_new {max_new_tokens}) "
                    f"exceeds max_seq={self.max_seq}")
        return eng.serve(requests, max_new_tokens=max_new_tokens)

    def _serve_batch_grouped(self, requests, max_new_tokens: int = 16):
        """Legacy static path: group requests by tenant, run each group."""
        by_tenant: dict[str, list[int]] = {}
        for i, (tenant, _) in enumerate(requests):
            by_tenant.setdefault(tenant, []).append(i)
        results: list[Optional[np.ndarray]] = [None] * len(requests)
        for tenant, idxs in by_tenant.items():
            lens = {requests[i][1].shape[-1] for i in idxs}
            for L in lens:  # one jit shape per (tenant, prompt-length) group
                group = [i for i in idxs if requests[i][1].shape[-1] == L]
                prompts = np.stack([requests[i][1] for i in group])
                gen = self.generate(tenant, prompts, max_new_tokens)
                for row, i in enumerate(group):
                    results[i] = gen[row]
        return results  # type: ignore

    def memory_report(self) -> dict:
        """Deployment memory ledger.

        Baselines are explicit (the old ``bytes_vs_n_full_models`` divided
        by ``base * (n + 1)``, silently comparing against base + n full
        models):

        * ``bytes_vs_n_full_models``      — ours / (n full fine-tuned
          models), the paper's Fig. 2 comparison: without delta
          compression each tenant ships a full copy.
        * ``bytes_vs_base_plus_n_full``   — ours / (base + n full models),
          for deployments that must also keep the control-arm base.
        """
        base = tree_bytes(self.base)
        deltas = self.store.total_bytes()
        n = len(self.store.names())
        ours = base + deltas
        return {
            "base_bytes": base,
            "delta_bytes_total": deltas,
            "n_tenants": n,
            "bytes_vs_n_full_models": ours / (base * n) if n else 1.0,
            "bytes_vs_base_plus_n_full": ours / (base * (n + 1)),
        }
