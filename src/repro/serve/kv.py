"""Slot-based paged KV cache for continuous batching.

One persistent decode cache of ``n_slots`` rows (the "pages") lives on
device. A freshly prefilled sequence (batch-1 cache) is *inserted* into a
free slot mid-flight without touching the other rows; a finished sequence
just releases its slot index — no device work, the row is garbage until
the next insert overwrites it.

This works because every leaf of the model cache leads with the batch
dim (``models.lm.cache_specs``): attention k/v/pos rings, SSM conv/state,
RG-LRU conv/h, and cross-attention memories all slice per row.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.models import lm


class SlotKVCache:
    """Fixed-slot device cache with mid-flight row insertion.

    ``shardings`` (optional NamedSharding tree matching
    ``lm.cache_specs``, e.g. from ``launch.mesh.cache_shardings``) pins
    the persistent cache to a mesh layout — KV rings sharded along
    kv-heads, slot rows along ``data`` — and every insert/update is
    forced back onto it via ``out_shardings`` so mid-flight row writes
    never drift the layout.
    """

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq: int,
                 enc_len: int = 0, shardings: Optional[Any] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.shardings = shardings
        cache = lm.init_cache(cfg, n_slots, max_seq, enc_len=enc_len)
        if shardings is not None:
            from repro.launch.mesh import shard_tree
            cache = shard_tree(cache, shardings)
        self.cache: Any = cache
        self._free: List[int] = list(range(n_slots))
        # donate the old cache buffers: insertion is an in-place row write
        jit_kw = {} if shardings is None else {"out_shardings": shardings}
        self._insert = jax.jit(self._insert_impl, donate_argnums=0, **jit_kw)

    @staticmethod
    def _insert_impl(cache, row_cache, slot):
        # some mixers keep prefill state in f32; the persistent ring is the
        # cache-spec dtype, so cast like decode's own cache writes do
        return jax.tree.map(lambda g, r: g.at[slot].set(r[0].astype(g.dtype)),
                            cache, row_cache)

    # -- slot accounting ----------------------------------------------------
    def claim(self, slot: int) -> None:
        """Mark a specific slot occupied (scheduler-chosen slot id)."""
        assert slot in self._free, f"slot {slot} is not free"
        self._free.remove(slot)

    def release(self, slot: int) -> None:
        assert slot not in self._free, f"slot {slot} double-freed"
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    # -- device ops ---------------------------------------------------------
    def insert(self, slot: int, row_cache: Any) -> None:
        """Copy a batch-1 cache into row ``slot`` of the shared cache."""
        self.cache = self._insert(self.cache, row_cache, jnp.int32(slot))

    def update(self, new_cache: Any) -> None:
        """Swap in the post-decode-step cache."""
        self.cache = new_cache
