"""Slot-based paged KV cache for continuous batching.

One persistent decode cache of ``n_slots`` rows (the "pages") lives on
device. A freshly prefilled sequence (batch-1 cache) is *inserted* into a
free slot mid-flight without touching the other rows; a finished sequence
just releases its slot index — no device work, the row is garbage until
the next insert overwrites it.

This works because every leaf of the model cache leads with the batch
dim (``models.lm.cache_specs``): attention k/v/pos rings, SSM conv/state,
RG-LRU conv/h, and cross-attention memories all slice per row.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.models import lm


class SlotKVCache:
    """Fixed-slot device cache with mid-flight row insertion.

    ``shardings`` (optional NamedSharding tree matching
    ``lm.cache_specs``, e.g. from ``launch.mesh.cache_shardings``) pins
    the persistent cache to a mesh layout — KV rings sharded along
    kv-heads, slot rows along ``data`` — and every insert/update is
    forced back onto it via ``out_shardings`` so mid-flight row writes
    never drift the layout.

    ``data_shards`` mirrors the scheduler's contiguous shard pools:
    slot ``i`` lives on data shard ``i // (n_slots / data_shards)``,
    which under the serve cache layout is the device shard that
    physically owns row ``i``. Inserts and releases are accounted per
    pool (``n_free_shard``) and an insert is pinned to the owning shard
    by construction — the jitted row write runs under ``out_shardings``,
    so the freshly prefilled row lands on (only) the devices of the
    shard whose pool the scheduler admitted into.
    """

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq: int,
                 enc_len: int = 0, shardings: Optional[Any] = None,
                 data_shards: int = 1):
        from repro.serve.scheduler import shard_pool_size
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.enc_len = enc_len
        self.shardings = shardings
        self._zero_row: Optional[Any] = None
        self.data_shards = data_shards
        self.shard_size = shard_pool_size(n_slots, data_shards)
        cache = lm.init_cache(cfg, n_slots, max_seq, enc_len=enc_len)
        if shardings is not None:
            from repro.launch.mesh import shard_tree
            cache = shard_tree(cache, shardings)
        self.cache: Any = cache
        self._free: List[int] = list(range(n_slots))
        # donate the old cache buffers: insertion is an in-place row write
        jit_kw = {} if shardings is None else {"out_shardings": shardings}
        self._insert = jax.jit(self._insert_impl, donate_argnums=0, **jit_kw)

    @staticmethod
    def _insert_impl(cache, row_cache, slot):
        # some mixers keep prefill state in f32; the persistent ring is the
        # cache-spec dtype, so cast like decode's own cache writes do
        return jax.tree.map(lambda g, r: g.at[slot].set(r[0].astype(g.dtype)),
                            cache, row_cache)

    # -- slot accounting ----------------------------------------------------
    def claim(self, slot: int) -> None:
        """Mark a specific slot occupied (scheduler-chosen slot id).

        ValueError (not assert): a double-claim means the scheduler's
        slot table and this free list disagree — that must fail loudly
        even under ``python -O``, or the next insert would overwrite a
        live sequence's cache row.
        """
        if slot not in self._free:
            raise ValueError(f"slot {slot} is not free")
        self._free.remove(slot)

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def shard_of(self, slot: int) -> int:
        """Data shard owning ``slot`` (contiguous pools, scheduler layout)."""
        return slot // self.shard_size

    def n_free_shard(self, shard: int) -> int:
        return sum(1 for s in self._free if self.shard_of(s) == shard)

    def shard_occupancy(self) -> List[float]:
        """Occupied fraction of each data shard's slot pool."""
        return [1.0 - self.n_free_shard(s) / self.shard_size
                for s in range(self.data_shards)]

    # -- device ops ---------------------------------------------------------
    def insert(self, slot: int, row_cache: Any) -> None:
        """Copy a batch-1 cache into row ``slot`` of the shared cache."""
        self.cache = self._insert(self.cache, row_cache, jnp.int32(slot))

    def reset(self, slot: int) -> None:
        """Reset row ``slot`` to the ``init_cache`` template (pos = -1).

        Whole-prompt prefill overwrites the entire row at insert time, so
        stale state never matters; chunked prefill instead APPENDS into
        the claimed row mid-sequence, and the previous occupant's ring
        ``pos`` markers (valid, causally attendable positions) and ssm/
        rec states would leak into the new sequence. Reuses the insert
        jit (the zero template is a batch-1 cache like any prefilled
        row), so this adds no compile shape.
        """
        if self._zero_row is None:
            self._zero_row = lm.init_cache(self.cfg, 1, self.max_seq,
                                           enc_len=self.enc_len)
        self.insert(slot, self._zero_row)

    def update(self, new_cache: Any) -> None:
        """Swap in the post-decode-step cache."""
        self.cache = new_cache
