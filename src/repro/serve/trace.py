"""Request-lifecycle tracing and decode-path attribution for the engine.

Three pieces:

* **Event bus** — the engine emits typed :class:`ServeEvent`\\ s at every
  hook site (submit → admit → prefill → first-token → token → done, plus
  per-decode-step and jit-trace events). ``Metrics``, :class:`Tracer`
  and ``SLOCounters`` all consume the *same* stream, so there is one
  source of truth for what happened during a run.

* **Path attribution** — the dispatch layers (``kernels/ops.py``,
  ``kernels/fallback.py``, ``core/apply.py``) decide silently between
  formulations (segments-pallas vs gather vs dense, values vs packed
  residency, autotune tiles). :func:`note_path` lets them report that
  decision into a thread-local context the engine opens around each
  jitted call. Because those code paths only run while jax traces, a
  non-empty note list doubles as a jit (re)compile detector; on cached
  executions the engine replays the notes it memoised per call
  signature. Cost when no context is open: one ``getattr`` returning
  ``None``.

* **Chrome-trace export** — :meth:`Tracer.export` writes Chrome/Perfetto
  "trace event" JSON (open at https://ui.perfetto.dev). Track layout:
  pid 1 = one tid per request (queue_wait / prefill / decode child
  spans under a root request span, first-token instant; chunked-prefill
  engines additionally emit one ``prefill_chunk`` span per chunk, so
  chunk scheduling is visible per request); pid 2 = the engine
  (decode_step spans with path-attribution args, jit_trace instants).

The tracer holds **no clock**: every timestamp comes from events, which
carry the engine's injectable clock — traces are deterministic under
``VirtualClock`` and this module performs zero wall-clock reads.

``python -m repro.serve.trace --validate trace.json`` checks an emitted
file (JSON parses, ≥1 request span with child prefill+decode spans,
monotonic non-negative timestamps) — CI runs it on the serve smoke job.
"""
from __future__ import annotations

import json
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = [
    "EVENT_SCHEMA",
    "ServeEvent", "EventBus", "Tracer",
    "attribution", "note_path", "path_label",
    "validate_chrome_trace",
]


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------
# The closed set of event names any ``bus.emit`` site may use. Adding an
# event means adding it here FIRST — deltalint rule DL004 cross-checks
# every emit site against this dict (and flags entries nothing emits),
# because a typo'd kind silently falls through every ``_on_<kind>``
# consumer dispatch: no metrics, no trace span, no SLO accounting, no
# error. Keys are event kinds; values say who emits it and what it marks.
EVENT_SCHEMA: Dict[str, str] = {
    "submit": "engine: request entered the queue (rid, tenant, deadline)",
    "admit": "engine: request won a slot (rid, slot, wait, deadline_slack)",
    "prefill": "engine: whole-prompt prefill span closed (rid, t_start)",
    "prefill_chunk": "engine: one chunk of a chunked prefill (rid, start, "
                     "length, last)",
    "first_token": "engine: first token surfaced for a request (rid, ttft)",
    "token": "engine: one generated token (rid, tenant)",
    "shard_token": "engine: token attributed to a data shard (data>1 only)",
    "step": "engine: one batched decode step span (n_active, path, notes)",
    "done": "engine: request finished (rid, latency, ttft, n_tokens)",
    "start": "engine: run loop started",
    "stop": "engine: run loop stopped",
    "jit_trace": "engine: a jitted entry (re)traced (signature, site, "
                 "first) — first=False is a recompile; CompileGuard "
                 "strict mode raises on these outside warmup",
    "tenant_register": "engine: new tenant delta installed (tenant, row)",
    "tenant_rollout": "engine: existing tenant's delta replaced in place",
    "tenant_retire": "engine: tenant removed from the serving table",
    "tenant_ready": "registry: compressed artifact ready to serve (tier)",
    "tenant_promote": "registry: tenant promoted cold->warm on demand",
    "tenant_evict": "registry: tenant demoted/evicted by traffic pressure",
}
@dataclass
class ServeEvent:
    """One engine event. ``t`` is engine time (injectable clock); span-like
    kinds (prefill, step) carry their start in ``attrs["t_start"]``."""
    kind: str
    t: float
    attrs: Dict[str, Any] = field(default_factory=dict)


class EventBus:
    """Fans engine events out to consumers (duck-typed ``consume(ev)``)."""

    def __init__(self, consumers: Optional[List[Any]] = None):
        self.consumers: List[Any] = [c for c in (consumers or [])
                                     if c is not None]

    def attach(self, consumer: Any) -> None:
        if consumer is not None:
            self.consumers.append(consumer)

    def emit(self, kind: str, t: float, **attrs) -> None:
        ev = ServeEvent(kind, t, attrs)
        for c in self.consumers:
            c.consume(ev)


# ---------------------------------------------------------------------------
# Thread-local path attribution
# ---------------------------------------------------------------------------
_tls = threading.local()


@contextmanager
def attribution():
    """Open a note-collection context on this thread.

    The engine wraps each jitted dispatch call in one of these; dispatch
    code inside (which only executes while jax traces) reports decisions
    via :func:`note_path`. Yields the (mutable) note list. Nesting
    restores the outer context on exit.
    """
    prev = getattr(_tls, "notes", None)
    _tls.notes = []
    try:
        yield _tls.notes
    finally:
        _tls.notes = prev


def note_path(site: str, **attrs) -> None:
    """Report a dispatch decision (no-op unless a context is open).

    ``site`` names the decision point (e.g. ``"correction_nd"``,
    ``"segments"``); attrs carry what was chosen (formulation, tiles,
    shapes). Duplicate notes within one context are dropped so loops
    over layers don't balloon the record.
    """
    notes = getattr(_tls, "notes", None)
    if notes is None:
        return
    entry = {"site": site, **attrs}
    if entry not in notes:
        notes.append(entry)


def path_label(notes: List[dict]) -> str:
    """Compact human label for a note set, e.g. ``"segments-pallas+values"``.

    Used for the per-step ``path`` attribute and the ``decode_paths``
    counters in ``Metrics`` — coarse by design (formulation + residency
    path), with the full notes preserved in trace span args.
    """
    if not notes:
        return "unknown"
    forms = []
    residency = None
    for n in notes:
        f = n.get("formulation")
        if f and f not in forms:
            forms.append(f)
        if "residency" in n and n["residency"] is not None:
            residency = n["residency"]
    label = "+".join(forms) if forms else "unknown"
    if residency is not None:
        label += f"+{residency}"
    return label


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
class Tracer:
    """Builds Chrome-trace spans from the serve event stream.

    ``step_sample=N`` keeps every Nth decode-step span (request
    lifecycle spans are always kept — they are bounded by request count,
    step spans are not). ``max_events`` hard-caps stored events; once
    hit, further decode-step spans are dropped (counted in
    ``dropped_events``) while request spans still record.
    """

    _PID_REQ = 1
    _PID_ENGINE = 2

    def __init__(self, step_sample: int = 1, max_events: int = 200_000):
        if step_sample < 1:
            raise ValueError(f"step_sample={step_sample} must be >= 1")
        self.step_sample = step_sample
        self.max_events = max_events
        self.events: List[dict] = []       # chrome-trace event dicts
        self.dropped_events = 0
        self._arrival: Dict[int, float] = {}      # rid -> submit time
        self._admit_end: Dict[int, float] = {}    # rid -> prefill span end
        self._tenant: Dict[int, Optional[str]] = {}
        self._open_rids: set = set()
        self._n_steps_seen = 0
        self.n_request_spans = 0

    # -- event-bus consumer -------------------------------------------------
    def consume(self, ev: ServeEvent) -> None:
        fn = getattr(self, f"_on_{ev.kind}", None)
        if fn is not None:
            fn(ev)

    # -- helpers ------------------------------------------------------------
    @staticmethod
    def _us(t: float) -> float:
        return t * 1e6

    def _span(self, name: str, pid: int, tid: int,
              t0: float, t1: float, args: Optional[dict] = None,
              _always: bool = True) -> None:
        if not _always and len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        self.events.append({
            "name": name, "ph": "X", "pid": pid, "tid": tid,
            "ts": self._us(t0), "dur": max(0.0, self._us(t1) - self._us(t0)),
            "args": args or {},
        })

    def _instant(self, name: str, pid: int, tid: int, t: float,
                 args: Optional[dict] = None) -> None:
        self.events.append({
            "name": name, "ph": "i", "pid": pid, "tid": tid,
            "ts": self._us(t), "s": "t", "args": args or {},
        })

    # -- lifecycle handlers -------------------------------------------------
    def _on_submit(self, ev: ServeEvent) -> None:
        rid = ev.attrs["rid"]
        self._arrival[rid] = ev.t
        self._tenant[rid] = ev.attrs.get("tenant")
        self._open_rids.add(rid)

    def _on_admit(self, ev: ServeEvent) -> None:
        rid = ev.attrs["rid"]
        arrival = self._arrival.get(rid, ev.t - ev.attrs.get("wait", 0.0))
        self._arrival.setdefault(rid, arrival)
        self._open_rids.add(rid)
        self._span("queue_wait", self._PID_REQ, rid, arrival, ev.t, {
            "tenant": ev.attrs.get("tenant"),
            "queue_wait_s": ev.attrs.get("wait"),
            "deadline_slack_s": ev.attrs.get("deadline_slack"),
            "slot": ev.attrs.get("slot"),
        })

    def _on_prefill(self, ev: ServeEvent) -> None:
        rid = ev.attrs["rid"]
        t0 = ev.attrs.get("t_start", ev.t)
        self._admit_end[rid] = ev.t
        self._span("prefill", self._PID_REQ, rid, t0, ev.t, {
            "tenant": ev.attrs.get("tenant"),
            "prompt_len": ev.attrs.get("prompt_len"),
            "bucket": ev.attrs.get("bucket"),
            "slot": ev.attrs.get("slot"),
        })

    def _on_prefill_chunk(self, ev: ServeEvent) -> None:
        """One chunk of a chunked prefill: a child span on the request
        track. The whole-prompt ``prefill`` span still closes the
        lifecycle when the LAST chunk lands (emitted by the engine), so
        chunk spans are pure detail under it."""
        rid = ev.attrs["rid"]
        t0 = ev.attrs.get("t_start", ev.t)
        self._span("prefill_chunk", self._PID_REQ, rid, t0, ev.t, {
            "tenant": ev.attrs.get("tenant"),
            "start": ev.attrs.get("start"),
            "length": ev.attrs.get("length"),
            "last": ev.attrs.get("last"),
            "slot": ev.attrs.get("slot"),
            "n_decode": ev.attrs.get("n_decode"),
        })

    def _on_first_token(self, ev: ServeEvent) -> None:
        self._instant("first_token", self._PID_REQ, ev.attrs["rid"], ev.t, {
            "ttft_s": ev.attrs.get("ttft"),
        })

    def _on_done(self, ev: ServeEvent) -> None:
        rid = ev.attrs["rid"]
        arrival = self._arrival.pop(rid, None)
        decode_t0 = self._admit_end.pop(rid, None)
        self._open_rids.discard(rid)
        self._tenant.pop(rid, None)
        if decode_t0 is not None and ev.t >= decode_t0:
            self._span("decode", self._PID_REQ, rid, decode_t0, ev.t, {
                "tokens": ev.attrs.get("n_tokens"),
            })
        if arrival is not None:
            self.n_request_spans += 1
            self._span("request", self._PID_REQ, rid, arrival, ev.t, {
                "tenant": ev.attrs.get("tenant"),
                "latency_s": ev.attrs.get("latency"),
                "ttft_s": ev.attrs.get("ttft"),
                "tokens": ev.attrs.get("n_tokens"),
                "deadline_slack_s": ev.attrs.get("deadline_slack"),
            })

    # -- engine handlers ----------------------------------------------------
    def _on_step(self, ev: ServeEvent) -> None:
        self._n_steps_seen += 1
        if (self._n_steps_seen - 1) % self.step_sample:
            return
        t0 = ev.attrs.get("t_start", ev.t)
        self._span("decode_step", self._PID_ENGINE, 0, t0, ev.t, {
            "n_active": ev.attrs.get("n_active"),
            "path": ev.attrs.get("path"),
            "residency_used": ev.attrs.get("residency_used"),
            "shard_active": ev.attrs.get("shard_active"),
            "shard_unique": ev.attrs.get("shard_unique"),
            "notes": ev.attrs.get("notes"),
            "recompiled": ev.attrs.get("recompiled"),
        }, _always=False)

    def _on_jit_trace(self, ev: ServeEvent) -> None:
        self._instant("jit_recompile" if not ev.attrs.get("first")
                      else "jit_compile",
                      self._PID_ENGINE, 0, ev.t, {
                          "signature": str(ev.attrs.get("signature")),
                          "site": ev.attrs.get("site"),
                          "notes": ev.attrs.get("notes"),
                      })

    # -- tenant lifecycle handlers ------------------------------------------
    # register/rollout/retire come from the engine; ready/promote/evict
    # from the delta registry. All land as instants on the engine
    # process's "lifecycle" track, so a rollout's timing reads directly
    # against the decode steps it must not perturb.
    def _on_lifecycle(self, ev: ServeEvent) -> None:
        self._instant(ev.kind, self._PID_ENGINE, 1, ev.t, dict(ev.attrs))

    def _on_tenant_register(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    def _on_tenant_rollout(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    def _on_tenant_retire(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    def _on_tenant_ready(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    def _on_tenant_promote(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    def _on_tenant_evict(self, ev: ServeEvent) -> None:
        self._on_lifecycle(ev)

    # -- export -------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome "JSON object format" trace; events sorted by ts."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": self._PID_REQ,
             "args": {"name": "requests"}},
            {"name": "process_name", "ph": "M", "pid": self._PID_ENGINE,
             "args": {"name": "engine"}},
            {"name": "thread_name", "ph": "M", "pid": self._PID_ENGINE,
             "tid": 0, "args": {"name": "decode"}},
            {"name": "thread_name", "ph": "M", "pid": self._PID_ENGINE,
             "tid": 1, "args": {"name": "lifecycle"}},
        ]
        events = sorted(self.events, key=lambda e: (e["ts"], e.get("tid", 0)))
        trace = {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {
                "generator": "repro.serve.trace",
                "step_sample": self.step_sample,
                "dropped_events": self.dropped_events,
                "unfinished_requests": sorted(self._open_rids),
            },
        }
        return trace

    def export(self, path: str) -> dict:
        trace = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace


# ---------------------------------------------------------------------------
# Validation (used by CI serve-smoke and tests)
# ---------------------------------------------------------------------------
def validate_chrome_trace(trace: dict) -> List[str]:
    """Structural checks on an exported trace; returns problem strings
    (empty list = valid). Checks: trace shape, non-negative monotonic
    timestamps, and ≥1 request span with child prefill+decode spans on
    its track inside its interval."""
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]

    spans = [e for e in events if e.get("ph") == "X"]
    last_ts = -1.0
    for e in events:
        ts = e.get("ts")
        if e.get("ph") == "M":
            continue
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"bad ts on event {e.get('name')!r}: {ts!r}")
            continue
        if ts < last_ts:
            problems.append(
                f"timestamps not monotonic at {e.get('name')!r}: "
                f"{ts} < {last_ts}")
        last_ts = ts
        if e.get("ph") == "X" and e.get("dur", 0) < 0:
            problems.append(f"negative dur on {e.get('name')!r}")

    requests = [e for e in spans if e["name"] == "request"]
    if not requests:
        problems.append("no request spans")
    ok_lifecycle = 0
    for r in requests:
        tid, t0 = r["tid"], r["ts"]
        t1 = t0 + r.get("dur", 0)
        kids = {e["name"] for e in spans
                if e["tid"] == tid and e["name"] != "request"
                and e["ts"] >= t0 - 1e-6
                and e["ts"] + e.get("dur", 0) <= t1 + 1e-6}
        if {"prefill", "decode"} <= kids:
            ok_lifecycle += 1
    if requests and not ok_lifecycle:
        problems.append(
            "no request span has child prefill+decode spans on its track")

    # chunked-prefill traces: every prefill_chunk span must sit on a
    # request track, inside that request's interval, and the chunk
    # cursors on one track must be contiguous (start_{i+1} = start_i +
    # length_i) ending in exactly one last=True chunk
    req_by_tid = {r["tid"]: (r["ts"], r["ts"] + r.get("dur", 0))
                  for r in requests}
    chunks_by_tid: dict = {}
    for e in spans:
        if e["name"] != "prefill_chunk":
            continue
        tid = e["tid"]
        if tid not in req_by_tid:
            problems.append(f"prefill_chunk span on tid {tid} "
                            "with no request span")
            continue
        t0, t1 = req_by_tid[tid]
        if not (e["ts"] >= t0 - 1e-6
                and e["ts"] + e.get("dur", 0) <= t1 + 1e-6):
            problems.append(
                f"prefill_chunk span on tid {tid} outside its request")
        chunks_by_tid.setdefault(tid, []).append(e["args"])
    for tid, chunks in chunks_by_tid.items():
        chunks.sort(key=lambda a: a.get("start", 0))
        cursor = 0
        for a in chunks:
            if a.get("start") != cursor:
                problems.append(
                    f"prefill_chunk cursor gap on tid {tid}: "
                    f"start={a.get('start')} expected {cursor}")
                break
            cursor += a.get("length", 0)
        if sum(1 for a in chunks if a.get("last")) != 1:
            problems.append(
                f"tid {tid} does not end in exactly one last=True chunk")
    return problems


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="Validate a Chrome-trace JSON emitted by "
                    "launch/serve.py --trace-out")
    p.add_argument("--validate", metavar="FILE", required=True)
    a = p.parse_args(argv)
    try:
        with open(a.validate) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"INVALID: cannot load {a.validate}: {e}")
        return 1
    problems = validate_chrome_trace(trace)
    n_spans = sum(1 for e in trace.get("traceEvents", [])
                  if e.get("ph") == "X")
    n_req = sum(1 for e in trace.get("traceEvents", [])
                if e.get("ph") == "X" and e.get("name") == "request")
    if problems:
        for msg in problems:
            print(f"INVALID: {msg}")
        return 1
    print(f"OK: {n_spans} spans ({n_req} requests), "
          f"{len(trace['traceEvents'])} events")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
