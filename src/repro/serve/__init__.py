from repro.serve.engine import (
    ContinuousEngine,
    DeltaStore,
    Engine,
    Tenant,
    mask_after_stop,
)
from repro.serve.kv import SlotKVCache
from repro.serve.metrics import Metrics, TenantStats
from repro.serve.scheduler import (
    LengthBuckets,
    Request,
    RequestQueue,
    Scheduler,
    SlotState,
    VirtualClock,
    tenant_segments,
    tenant_segments_sharded,
)

__all__ = [
    "ContinuousEngine",
    "DeltaStore",
    "Engine",
    "LengthBuckets",
    "Metrics",
    "Request",
    "RequestQueue",
    "Scheduler",
    "SlotKVCache",
    "SlotState",
    "Tenant",
    "TenantStats",
    "VirtualClock",
    "mask_after_stop",
    "tenant_segments",
    "tenant_segments_sharded",
]
