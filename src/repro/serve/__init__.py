from repro.serve.engine import DeltaStore, Engine, Tenant

__all__ = ["DeltaStore", "Engine", "Tenant"]
