"""Continuous-batching scheduler: requests, length buckets, slot packing.

The scheduler owns *admission policy only* — which pending request goes
into which free KV slot, and when. All jax work (prefill, batched decode)
stays in ``serve.engine``; all cache storage in ``serve.kv``. This keeps
the policy unit-testable without compiling anything.

Design points (serve/README.md has the full picture):

* Requests arrive with ``(arrival, deadline)`` metadata; admission order
  is earliest-deadline-first, ties broken by arrival then id — a simple,
  deterministic policy that later PRs can swap out.
* Prompt lengths are rounded up to a small set of **buckets** (powers of
  two by default) and left-padded, so jit compiles at most once per
  bucket instead of once per distinct prompt length. Archs whose mixers
  carry sequence state (ssm/rec) cannot be left-padded without polluting
  the state, so they use ``exact=True`` buckets (one shape per distinct
  length — still bounded by the number of distinct lengths seen).
* A slot is freed **only** when its sequence finishes (stop token or
  token budget). Unfinished sequences are never evicted; under slot
  pressure new requests simply wait in the queue.
* With ``data_shards > 1`` the slot table is partitioned into
  ``data_shards`` **contiguous shard pools** (slot rows shard over the
  mesh ``data`` axis in the serve layout, so pool ``s`` is exactly the
  rows device-shard ``s`` owns). *Which* pool a popped request lands in
  is a pluggable :class:`AdmissionPolicy`:

  - :class:`BalancedAdmission` (default): the least-occupied shard with
    a free slot, ties broken by the lowest slot id — placement is a
    pure function of the slot table, so a replayed trace lands every
    request on the same shard.
  - :class:`AffinityAdmission`: prefer a shard already hosting the
    request's *tenant* (so each data shard sees fewer unique tenants
    per decode step and dequantizes fewer deltas), but only while that
    shard stays within ``max_imbalance`` of the least-occupied shard;
    otherwise fall back to the balanced rule. A policy only picks
    *among* open shards — it can never decline a placement — so the
    capacity / EDF / no-starvation guarantees are policy-independent.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------
@dataclass
class Request:
    """One generation request plus its lifecycle bookkeeping."""
    rid: int
    tenant: Optional[str]            # None = raw base model
    prompt: np.ndarray               # [L] int32
    max_new_tokens: int = 16
    stop_token: Optional[int] = None
    arrival: float = 0.0
    deadline: Optional[float] = None
    on_token: Optional[Callable[["Request", int, bool], None]] = None

    # -- filled in by the engine --------------------------------------------
    tokens: List[int] = field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])

    def output(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    def emit(self, token: int) -> bool:
        """Record one generated token, fire the streaming callback, and
        return whether the sequence just finished (single source of the
        stop condition)."""
        self.tokens.append(int(token))
        fin = self.should_stop()
        if self.on_token is not None:
            self.on_token(self, int(token), fin)
        return fin

    def should_stop(self) -> bool:
        if self.stop_token is not None and self.tokens \
                and self.tokens[-1] == self.stop_token:
            return True
        return len(self.tokens) >= self.max_new_tokens


class RequestQueue:
    """Arrival-ordered queue with deadline-aware pop.

    Two heaps instead of the old linear best-scan + ``list.remove``
    (which made draining n requests O(n^2) — measurable at
    registry-scale queue depths): ``_future`` orders not-yet-arrived
    requests by arrival, ``_ready`` orders arrived ones by the EDF key
    ``(deadline-or-inf, arrival, rid)``. ``pop_ready`` migrates arrived
    requests future->ready, then pops the heap head — the exact request
    the old scan's ``min()`` picked, so pop order is unchanged (the EDF
    property suite pins it). Each request is pushed/popped O(log n)
    once per heap.
    """

    def __init__(self):
        self._future: List[tuple] = []    # (arrival, rid, Request)
        self._ready: List[tuple] = []     # (deadline|inf, arrival, rid, Req)
        self._ids = itertools.count()

    def submit(self, tenant: Optional[str], prompt: np.ndarray, *,
               max_new_tokens: int = 16, stop_token: Optional[int] = None,
               arrival: float = 0.0, deadline: Optional[float] = None,
               on_token=None) -> Request:
        req = Request(rid=next(self._ids), tenant=tenant,
                      prompt=np.asarray(prompt, np.int32).reshape(-1),
                      max_new_tokens=max_new_tokens, stop_token=stop_token,
                      arrival=arrival, deadline=deadline, on_token=on_token)
        heapq.heappush(self._future, (req.arrival, req.rid, req))
        return req

    def _migrate(self, now: float) -> None:
        """Move every arrived request onto the EDF-keyed ready heap."""
        while self._future and self._future[0][0] <= now:
            _, rid, req = heapq.heappop(self._future)
            heapq.heappush(self._ready, (
                req.deadline if req.deadline is not None else float("inf"),
                req.arrival, rid, req))

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)

    def ready(self, now: float) -> List[Request]:
        """Arrived-but-unpopped requests, in submission (rid) order —
        introspection only, never consulted by the pop path."""
        out = [r for _, _, r in self._future if r.arrival <= now]
        out += [r for _, _, _, r in self._ready]
        return sorted(out, key=lambda r: r.rid)

    def pending(self) -> List[Request]:
        """ALL queued requests (arrived or not), in submission (rid)
        order — lifecycle guards scan this before retiring a tenant."""
        out = [r for _, _, r in self._future]
        out += [r for _, _, _, r in self._ready]
        return sorted(out, key=lambda r: r.rid)

    def next_arrival(self) -> Optional[float]:
        if self._ready:
            # already-arrived requests are waiting (e.g. on slots): the
            # earliest pending arrival is theirs, not a future one's
            return min(r.arrival for _, _, _, r in self._ready)
        return self._future[0][0] if self._future else None

    def pop_ready(self, now: float) -> Optional[Request]:
        """Earliest deadline first among arrived requests; FIFO otherwise."""
        self._migrate(now)
        if not self._ready:
            return None
        return heapq.heappop(self._ready)[3]


# ---------------------------------------------------------------------------
# Length buckets
# ---------------------------------------------------------------------------
class LengthBuckets:
    """Round prompt lengths up to a bounded set of jit shapes."""

    def __init__(self, min_bucket: int = 8, max_bucket: int = 4096,
                 exact: bool = False):
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.exact = exact
        self.seen: set[int] = set()

    def bucket(self, length: int) -> int:
        if length > self.max_bucket:
            raise ValueError(f"prompt length {length} exceeds max bucket "
                             f"{self.max_bucket}")
        if self.exact:
            b = length
        else:
            b = self.min_bucket
            while b < length:
                b *= 2
            # a non-power-of-two max_bucket must still admit prompts that
            # fit: clamp instead of overshooting past the cap
            b = min(b, self.max_bucket)
        self.seen.add(b)
        return b


# ---------------------------------------------------------------------------
# Tenant-segment layout (unique-tenant decode dispatch)
# ---------------------------------------------------------------------------
def tenant_segments(rows: np.ndarray):
    """Build the static-shape tenant-segment layout for one decode step.

    ``rows`` int [B] is the per-slot tenant row (0 = base/zero delta).
    Returns a :class:`repro.core.apply.TenantSegments` of numpy arrays:
    batch rows stably sorted by tenant so each unique tenant occupies
    one contiguous segment; segment arrays are padded to B entries
    (empty segments carry ``seg_offsets[s] == seg_offsets[s+1]`` and
    tenant row 0) so every decode step shares ONE jit shape regardless
    of how many distinct tenants happen to share the batch.

    For ``data > 1`` serving the engine uses
    :func:`tenant_segments_sharded` instead (per-shard pools); its
    ``global_segments()`` flattening is the single-pool equivalent of
    this layout, so both forms share the decode jit signature.
    """
    from repro.core.apply import TenantSegments
    rows = np.asarray(rows, np.int32)
    B = rows.shape[0]
    order = np.argsort(rows, kind="stable").astype(np.int32)
    inv_order = np.argsort(order, kind="stable").astype(np.int32)
    uniq, starts = np.unique(rows[order], return_index=True)
    seg_rows = np.zeros(B, np.int32)
    seg_rows[:len(uniq)] = uniq
    seg_offsets = np.full(B + 1, B, np.int32)
    seg_offsets[:len(uniq)] = starts
    return TenantSegments(order=order, inv_order=inv_order,
                          seg_rows=seg_rows, seg_offsets=seg_offsets)


def tenant_segments_sharded(rows: np.ndarray, data_shards: int):
    """Per-data-shard tenant-segment layout for one decode step.

    The ``data > 1`` companion of :func:`tenant_segments`: returns a
    :class:`repro.core.apply.ShardedTenantSegments` of [D, B_s] /
    [D, B_s+1] numpy arrays — each contiguous shard pool's own stable
    sort, pool-LOCAL permutation and pool-local segment list. Rows sort
    by tenant only *within* a pool (the permutation never crosses a
    pool boundary, so the sorted batch partitions over the mesh
    ``data`` axis exactly like the unsorted slot rows) and each pool
    contributes its own segments — a tenant hosted by two shards gets
    two segments, so each device shard dequantizes exactly the tenants
    its pool hosts. This is the form the shard_map'd sharded
    correction consumes natively; unsharded execution paths flatten it
    with ``global_order()`` / ``global_segments()``.
    """
    from repro.core.apply import ShardedTenantSegments
    rows = np.asarray(rows, np.int32)
    B = rows.shape[0]
    # ValueError (not assert): a bad split must fail loudly even under
    # python -O, or np.empty garbage would flow into gather indices
    per = shard_pool_size(B, data_shards)
    order = np.empty((data_shards, per), np.int32)
    inv_order = np.empty((data_shards, per), np.int32)
    seg_rows = np.zeros((data_shards, per), np.int32)
    seg_offsets = np.full((data_shards, per + 1), per, np.int32)
    for s in range(data_shards):
        pool = rows[s * per:(s + 1) * per]
        local = np.argsort(pool, kind="stable").astype(np.int32)
        order[s] = local
        inv_order[s] = np.argsort(local, kind="stable")
        uniq, starts = np.unique(pool[local], return_index=True)
        seg_rows[s, :len(uniq)] = uniq
        seg_offsets[s, :len(uniq)] = starts
    return ShardedTenantSegments(order=order, inv_order=inv_order,
                                 seg_rows=seg_rows, seg_offsets=seg_offsets)


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------
class AdmissionPolicy:
    """Chooses the shard pool for one popped request.

    The contract every policy must honor (and the property suite pins):
    ``choose`` is called only when at least one shard has a free slot,
    and must return a member of ``open_shards`` — a policy decides
    *where*, never *whether*, so admission always fills free slots from
    the ready queue (no starvation) and the EDF pop order is untouched.
    All inputs are host-side state, so placement stays a deterministic
    pure function of the slot table and the popped request.

    ``max_imbalance`` is the policy's occupancy bound: immediately after
    any admission round, every shard the policy placed into is within
    ``max_imbalance`` of the least-occupied shard.
    """

    name = "base"
    max_imbalance = 1

    def choose(self, req: "Request", open_shards: List[int], occ: List[int],
               free: List[List[int]], hosted: List[set]) -> int:
        """Pick a shard for ``req``.

        ``open_shards``: shards with >= 1 free slot (ascending).
        ``occ``: per-shard active count (including slots claimed earlier
        in this round). ``free``: per-shard free slot ids (ascending).
        ``hosted``: per-shard set of tenant names currently hosted
        (active slots plus this round's claims).
        """
        raise NotImplementedError


class BalancedAdmission(AdmissionPolicy):
    """Occupancy-balanced placement (the default, PR 4 behavior):
    least-occupied open shard, ties broken by the lowest free slot id."""

    name = "occupancy"
    max_imbalance = 1

    def choose(self, req, open_shards, occ, free, hosted) -> int:
        return min(open_shards, key=lambda s: (occ[s], free[s][0]))


class AffinityAdmission(BalancedAdmission):
    """Tenant-affinity placement with a bounded-imbalance guardrail.

    Prefer an open shard that already hosts the request's tenant — the
    per-shard unique-tenant count then grows only when it must, so each
    ``(data, model)`` device dequantizes fewer distinct deltas per
    decode step. Affinity never overrides balance unboundedly: a hosting
    shard is eligible only while its occupancy stays strictly below
    ``min(occ) + max_imbalance`` (occupancy over *all* shards), so after
    placement it is within ``max_imbalance`` of the least-occupied
    shard. Base requests (``tenant=None``) and requests whose tenant is
    hosted nowhere eligible fall back to the balanced rule.
    """

    name = "affinity"

    def __init__(self, max_imbalance: int = 2):
        if max_imbalance < 1:
            raise ValueError(f"max_imbalance={max_imbalance} must be >= 1")
        self.max_imbalance = int(max_imbalance)

    def choose(self, req, open_shards, occ, free, hosted) -> int:
        if req.tenant is not None:
            floor = min(occ)
            aff = [s for s in open_shards
                   if req.tenant in hosted[s]
                   and occ[s] - floor < self.max_imbalance]
            if aff:
                return min(aff, key=lambda s: (occ[s], free[s][0]))
        return super().choose(req, open_shards, occ, free, hosted)


def make_admission(policy) -> AdmissionPolicy:
    """Resolve an admission policy from a name or pass an instance through."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    if policy in (None, "occupancy", "balanced"):
        return BalancedAdmission()
    if policy == "affinity":
        return AffinityAdmission()
    raise ValueError(f"unknown admission policy {policy!r} "
                     "(expected 'occupancy' | 'affinity' | AdmissionPolicy)")


# ---------------------------------------------------------------------------
# Slot table
# ---------------------------------------------------------------------------
def shard_pool_size(n_slots: int, data_shards: int) -> int:
    """Validate the contiguous equal shard-pool partition and return the
    pool size.

    The ONE definition of the slot->shard mapping every serve component
    (Scheduler, SlotKVCache, Metrics) derives from:
    ``shard_of(slot) = slot // shard_pool_size(n_slots, data_shards)``.
    Pool ``s`` is exactly the slot rows mesh data-shard ``s`` owns under
    the serve cache layout (jax partitions an axis into contiguous equal
    blocks), so host bookkeeping and device layout agree by construction.
    """
    if data_shards < 1 or n_slots % data_shards:
        raise ValueError(
            f"n_slots={n_slots} must be a positive multiple of "
            f"data_shards={data_shards} (contiguous equal shard pools)")
    return n_slots // data_shards


@dataclass
class SlotState:
    """Runtime state of one occupied decode slot."""
    request: Request
    next_token: int                  # last sampled token (decode input)
    pos: int                         # next decode position (= tokens so far)
    tenant_row: int                  # row in the tenant-stacked delta tree
    # chunked prefill: the slot is claimed (KV row reserved, mid-prefill)
    # but not yet decoding — the combined step masks it out of the decode
    # rows and restores its cache row untouched
    prefilling: bool = False


class Scheduler:
    """Packs mixed-tenant requests into fixed decode slots.

    ``data_shards > 1`` partitions the ``n_slots`` slot rows into
    contiguous shard pools of ``n_slots / data_shards`` (the rows each
    mesh ``data`` shard owns in the serve cache layout); ``admission``
    (an :class:`AdmissionPolicy`, or its name) picks the pool for each
    popped request — occupancy-balanced by default — see :meth:`admit`.
    """

    def __init__(self, n_slots: int, buckets: LengthBuckets,
                 data_shards: int = 1, admission=None):
        self.n_slots = n_slots
        self.buckets = buckets
        self.data_shards = data_shards
        self.shard_size = shard_pool_size(n_slots, data_shards)
        self.admission = make_admission(admission)
        self.slots: List[Optional[SlotState]] = [None] * n_slots

    # -- introspection ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    @property
    def n_active(self) -> int:
        return len(self.active_slots())

    def shard_of(self, slot: int) -> int:
        """Data shard owning ``slot`` (pools are contiguous slot ranges)."""
        return slot // self.shard_size

    def shard_slots(self, shard: int) -> range:
        return range(shard * self.shard_size, (shard + 1) * self.shard_size)

    def shard_occupancy(self) -> List[int]:
        """Active-slot count per data shard."""
        occ = [0] * self.data_shards
        for i, s in enumerate(self.slots):
            if s is not None:
                occ[self.shard_of(i)] += 1
        return occ

    def hosted_tenants(self) -> List[set]:
        """Per-shard set of tenant names currently hosted (base requests,
        ``tenant=None``, are not tracked — they carry no delta)."""
        hosted: List[set] = [set() for _ in range(self.data_shards)]
        for i, s in enumerate(self.slots):
            if s is not None and s.request.tenant is not None:
                hosted[self.shard_of(i)].add(s.request.tenant)
        return hosted

    def shard_unique_tenants(self, rows) -> List[int]:
        """Distinct non-base tenant rows per shard pool of ``rows`` [B] —
        the number of distinct deltas each data shard dequantizes in a
        decode step over those slot rows (row 0, the zero delta, is not
        counted). The observable affinity admission tries to shrink."""
        rows = np.asarray(rows)
        return [int(np.unique(pool[pool > 0]).size)
                for s in range(self.data_shards)
                for pool in [rows[s * self.shard_size:
                                  (s + 1) * self.shard_size]]]

    # -- transitions --------------------------------------------------------
    def admit(self, queue: RequestQueue, now: float) -> List[tuple]:
        """Fill free slots from the queue; returns [(slot, request)].

        Placement is **deterministic** and delegated to the admission
        policy: each popped request goes to the shard
        ``self.admission.choose(...)`` picks among those that still
        have a free slot (occupancy and hosted-tenant sets count both
        active slots and slots already claimed earlier in this round),
        and takes that shard's lowest free slot id. Guarantees pinned
        by the property tests, for every policy: admission fills
        ``min(free, ready)`` slots in EDF pop order, and every shard
        the policy placed into ends within ``policy.max_imbalance`` of
        the least-occupied shard (1 for the balanced default). (A shard
        left imbalanced by earlier finishes stays imbalanced if the
        queue drains first — admission balances what it admits, it does
        not migrate active sequences.) With data_shards=1 every policy
        degrades to exactly the old lowest-free-slot-first behavior.
        """
        occ = self.shard_occupancy()
        hosted = self.hosted_tenants()
        # pool ranges ascend, so each free list is born sorted by slot id
        free = [[i for i in self.shard_slots(s) if self.slots[i] is None]
                for s in range(self.data_shards)]
        admitted = []
        while True:
            open_shards = [s for s in range(self.data_shards) if free[s]]
            if not open_shards:
                break
            req = queue.pop_ready(now)
            if req is None:
                break
            shard = self.admission.choose(req, open_shards, occ, free, hosted)
            if shard not in open_shards:
                # ValueError (not assert): a policy returning a full shard
                # must fail loudly, not pop from an empty free list
                raise ValueError(
                    f"admission policy {self.admission.name!r} chose shard "
                    f"{shard} with no free slot (open: {open_shards})")
            slot = free[shard].pop(0)
            occ[shard] += 1
            if req.tenant is not None:
                hosted[shard].add(req.tenant)
            req.t_admitted = now
            admitted.append((slot, req))
        return admitted

    def place(self, slot: int, state: SlotState) -> None:
        if self.slots[slot] is not None:
            raise RuntimeError(
                f"slot {slot} already occupied by rid "
                f"{self.slots[slot].request.rid}")
        self.slots[slot] = state

    def release(self, slot: int) -> Request:
        """Free a slot. Refuses to drop an unfinished sequence."""
        state = self.slots[slot]
        if state is None:
            raise RuntimeError(f"slot {slot} already free")
        if not state.request.done:
            raise RuntimeError(
                f"refusing to evict unfinished request {state.request.rid} "
                f"from slot {slot}")
        self.slots[slot] = None
        return state.request


@dataclass
class ChunkTask:
    """One prompt chunk picked for the next combined step."""
    slot: int
    request: Request
    start: int                       # cursor: prompt tokens already consumed
    length: int                      # tokens in this chunk (<= chunk_size)
    last: bool                       # final chunk -> first token after this


class ChunkQueue:
    """EDF-ordered queue of admitted, mid-prefill requests.

    Chunked prefill admits a request by claiming its KV slot, then feeds
    the prompt through the combined decode step ``chunk_size`` tokens at
    a time. This queue owns the **resumable per-request chunk cursors**:
    ``next_task`` peeks the head request's next chunk (earliest deadline
    first, ties by arrival then rid — the same order ``RequestQueue.
    pop_ready`` admits in), and ``advance`` moves the cursor only after
    the engine actually processed the chunk, so a step that skips chunk
    work (budget denied) repicks the identical task later. Cursors are
    strictly monotone and a request leaves the queue exactly when its
    cursor reaches the prompt length — the property suite pins both.
    """

    def __init__(self, chunk_size: int):
        if chunk_size < 1:
            raise ValueError(f"chunk_size={chunk_size} must be >= 1")
        self.chunk_size = chunk_size
        self._entries: dict[int, tuple] = {}     # rid -> (slot, Request)
        self._cursors: dict[int, int] = {}       # rid -> tokens consumed

    def add(self, slot: int, req: Request) -> None:
        if req.rid in self._entries:
            raise RuntimeError(
                f"rid {req.rid} already queued for chunked prefill")
        self._entries[req.rid] = (slot, req)
        self._cursors[req.rid] = 0

    def __len__(self) -> int:
        return len(self._entries)

    def cursor(self, rid: int) -> int:
        return self._cursors[rid]

    def pending_tokens(self) -> int:
        """Prompt tokens not yet consumed across all queued requests."""
        return sum(req.prompt_len - self._cursors[rid]
                   for rid, (_, req) in self._entries.items())

    def next_task(self) -> Optional[ChunkTask]:
        """The EDF-head request's next chunk; does NOT advance the cursor."""
        if not self._entries:
            return None
        rid = min(self._entries, key=lambda r: (
            self._entries[r][1].deadline
            if self._entries[r][1].deadline is not None else float("inf"),
            self._entries[r][1].arrival, r))
        slot, req = self._entries[rid]
        start = self._cursors[rid]
        length = min(self.chunk_size, req.prompt_len - start)
        return ChunkTask(slot=slot, request=req, start=start, length=length,
                         last=start + length >= req.prompt_len)

    def advance(self, task: ChunkTask) -> None:
        """Move the cursor past a processed chunk; pop the request when
        its whole prompt has been consumed."""
        rid = task.request.rid
        if self._cursors.get(rid) != task.start:
            raise ValueError(
                f"stale chunk task for rid {rid}: cursor is "
                f"{self._cursors.get(rid)}, task starts at {task.start}")
        self._cursors[rid] = task.start + task.length
        if task.last:
            del self._entries[rid]
            del self._cursors[rid]


class ChunkBudget:
    """Per-step chunk-budget policy under the decode-SLO knob.

    ``share`` in (0, 1] is the maximum fraction of combined steps that
    may carry prefill-chunk work while decode rows are active — the knob
    trading TTFT (chunks land sooner) against ITL (every chunk-carrying
    step is a little slower for the in-flight decodes). Implemented as a
    deterministic token bucket: each ``grant`` call with active decode
    rows accrues ``share`` credit (capped at 1, so idle stretches never
    bank a burst) and a granted chunk spends 1, so over any window of n
    such steps at most ``ceil(share * n)`` chunks run, and with
    share=1.0 (the TTFT-first default) every step may carry one. Steps
    with NO active decode rows always grant — there is no ITL left to
    protect, and refusing would deadlock the drain loop.
    """

    def __init__(self, share: float = 1.0):
        if not 0.0 < share <= 1.0:
            raise ValueError(f"chunk share={share} must be in (0, 1]")
        self.share = float(share)
        self._credit = 0.0

    def grant(self, n_decode_active: int, n_pending: int) -> bool:
        """Decide whether THIS step may process one prefill chunk."""
        if n_pending == 0:
            return False
        if n_decode_active == 0:
            return True
        self._credit = min(1.0, self._credit + self.share)
        if self._credit >= 1.0:
            self._credit -= 1.0
            return True
        return False


class VirtualClock:
    """Deterministic clock for tests/benchmarks: advances only on demand."""

    def __init__(self, t0: float = 0.0, tick: float = 0.0):
        self.t = t0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t
