"""Bounded serving telemetry: streaming histograms, SLO counters, exports.

The metrics layer used to keep every TTFT/queue-wait/latency sample in
an unbounded python list — a million-request run would OOM the host just
to answer a p95 question at drain time. This module replaces those lists
with **streaming histograms**: exact samples below a small cap (so short
runs and unit tests keep exact percentiles), fixed log-spaced bucket
counts above it (bounded memory forever after).

Everything here is clock-free by construction: values arrive already
measured (the engine's injectable clock is the only time source), so the
whole telemetry path is deterministic under ``VirtualClock`` — no
wall-clock read ever happens in this module.

Exports:

* :class:`StreamingHistogram` — the bounded sample sink.
* :class:`SLOCounters`       — deadline-miss / TTFT / ITL objective
  violations per tenant, fed from the engine's event stream
  (``serve.trace.EventBus``); the deadline comes from the scheduler's
  existing per-request ``deadline`` field.
* :func:`prometheus_text`    — Prometheus-style text exposition of a
  :class:`~repro.serve.metrics.Metrics` collector (+ optional SLO
  counters).
* :class:`TelemetrySnapshotWriter` — periodic JSON snapshot file driven
  by engine time, for scraping a live serve process.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional

import numpy as np

# Default bucket layout: log-spaced from 1us to 10_000s, 5 buckets per
# decade (ratio ~1.58x) — 50 buckets + underflow + overflow. Wide enough
# for TTFT (ms) and whole-run latencies (s) alike; relative error of a
# bucketed percentile is bounded by the bucket ratio (~26% midpoint),
# which only applies past the exact cap.
DEFAULT_LO = 1e-6
DEFAULT_DECADES = 10
DEFAULT_PER_DECADE = 5

# Exact samples kept before spilling to buckets. Below this, percentiles
# are exact (backward-compatible with the old list-based metrics for
# every test/bench workload); above it, memory stays O(buckets).
DEFAULT_EXACT_CAP = 1024


class StreamingHistogram:
    """Fixed log-bucket histogram with an exact-sample fast path.

    ``record`` keeps raw samples in a list until ``exact_cap``; crossing
    the cap spills them into the bucket counts once and the list is
    dropped — memory is bounded by the (fixed) bucket count from then
    on. ``percentile`` is exact in the first regime and
    bucket-interpolated (geometric bucket midpoint) in the second.
    """

    __slots__ = ("lo", "per_decade", "n_buckets", "counts", "n", "total",
                 "vmin", "vmax", "exact_cap", "_exact")

    def __init__(self, lo: float = DEFAULT_LO,
                 decades: int = DEFAULT_DECADES,
                 per_decade: int = DEFAULT_PER_DECADE,
                 exact_cap: int = DEFAULT_EXACT_CAP):
        self.lo = float(lo)
        self.per_decade = int(per_decade)
        self.n_buckets = int(decades) * int(per_decade)
        # [underflow, n_buckets log buckets, overflow]
        self.counts = np.zeros(self.n_buckets + 2, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.exact_cap = int(exact_cap)
        self._exact: Optional[List[float]] = []

    # -- layout -------------------------------------------------------------
    def bucket_index(self, x: float) -> int:
        """Index into ``counts`` (0 = underflow, last = overflow)."""
        if x <= self.lo:
            return 0
        i = int(math.floor(math.log10(x / self.lo) * self.per_decade))
        return min(i, self.n_buckets) + 1

    def bucket_le(self, i: int) -> float:
        """Inclusive upper bound of counts[i] (+inf for the overflow)."""
        if i <= 0:
            return self.lo
        if i > self.n_buckets:
            return math.inf
        return self.lo * 10.0 ** (i / self.per_decade)

    @property
    def exact(self) -> bool:
        """True while percentiles are computed from raw samples."""
        return self._exact is not None

    # -- recording ----------------------------------------------------------
    def record(self, x: float) -> None:
        x = float(x)
        self.n += 1
        self.total += x
        self.vmin = min(self.vmin, x)
        self.vmax = max(self.vmax, x)
        if self._exact is not None:
            self._exact.append(x)
            if len(self._exact) > self.exact_cap:
                for v in self._exact:      # spill once, then bucket-only
                    self.counts[self.bucket_index(v)] += 1
                self._exact = None
            return
        self.counts[self.bucket_index(x)] += 1

    # -- queries ------------------------------------------------------------
    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]; None when empty (matches the old list ``_pct``)."""
        if self.n == 0:
            return None
        if self._exact is not None:
            return float(np.percentile(np.asarray(self._exact, np.float64), q))
        # bucketed: first bucket whose cumulative count crosses the rank
        rank = (q / 100.0) * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            cum += int(c)
            if cum >= rank and c:
                if i == 0:
                    return self.lo
                if i > self.n_buckets:
                    return self.vmax       # overflow: best bound we have
                # geometric midpoint of the bucket
                hi = self.bucket_le(i)
                lo = self.bucket_le(i - 1)
                return math.sqrt(lo * hi)
        return self.vmax

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.n if self.n else None

    def bucket_counts(self) -> np.ndarray:
        """Bucket counts including any still-exact samples (non-destructive)."""
        counts = self.counts.copy()
        if self._exact is not None:
            for v in self._exact:
                counts[self.bucket_index(v)] += 1
        return counts

    def cumulative(self) -> List[tuple]:
        """[(le_bound, cumulative_count)] over non-trivial buckets plus the
        +Inf terminal — the Prometheus histogram exposition shape."""
        counts = self.bucket_counts()
        out = []
        cum = 0
        for i, c in enumerate(counts):
            cum += int(c)
            if c and i <= self.n_buckets:
                out.append((self.bucket_le(i), cum))
        out.append((math.inf, self.n))
        return out

    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Pooled histogram (e.g. all-tenant TTFT). Same layout required."""
        if (self.lo, self.per_decade, self.n_buckets) != \
                (other.lo, other.per_decade, other.n_buckets):
            raise ValueError(
                "cannot merge histograms with different layouts: "
                f"(lo, per_decade, n_buckets)="
                f"{(self.lo, self.per_decade, self.n_buckets)} vs "
                f"{(other.lo, other.per_decade, other.n_buckets)}")
        out = StreamingHistogram(self.lo, self.n_buckets // self.per_decade,
                                 self.per_decade, self.exact_cap)
        out.n = self.n + other.n
        out.total = self.total + other.total
        out.vmin = min(self.vmin, other.vmin)
        out.vmax = max(self.vmax, other.vmax)
        if self._exact is not None and other._exact is not None:
            # pooled report stays exact (transient object; cap not enforced
            # so pooling never loses precision the parts still have)
            out._exact = self._exact + other._exact
        else:
            out._exact = None
            out.counts = self.bucket_counts() + other.bucket_counts()
        return out

    @staticmethod
    def merged(hists: List["StreamingHistogram"]) -> "StreamingHistogram":
        if not hists:
            return StreamingHistogram()
        out = hists[0]
        for h in hists[1:]:
            out = out.merge(h)
        return out

    def to_dict(self) -> dict:
        """JSON-able summary (snapshot/export form)."""
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.vmin if self.n else None,
            "max": self.vmax if self.n else None,
            "exact": self.exact,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# SLO counters
# ---------------------------------------------------------------------------
class SLOCounters:
    """Per-tenant SLO violation counters fed from the serve event stream.

    * **deadline misses** — a request finished after its scheduler
      ``deadline`` (the field admission already sorts by; no new
      plumbing). Requests without a deadline never count.
    * **TTFT violations** — first token later than ``ttft_target_s``
      after arrival.
    * **ITL violations** — mean inter-token latency
      ``(latency - ttft) / (tokens - 1)`` above ``itl_target_s``
      (single-token requests have no ITL and never count).

    Consumes the same :class:`~repro.serve.trace.ServeEvent` stream as
    ``Metrics``/``Tracer`` (duck-typed ``consume``), so it can ride the
    engine's event bus with zero engine-side special cases.
    """

    def __init__(self, ttft_target_s: Optional[float] = None,
                 itl_target_s: Optional[float] = None):
        self.ttft_target_s = ttft_target_s
        self.itl_target_s = itl_target_s
        self.deadline_misses: Dict[str, int] = {}
        self.ttft_violations: Dict[str, int] = {}
        self.itl_violations: Dict[str, int] = {}
        self.n_done = 0

    @staticmethod
    def _bump(d: Dict[str, int], tenant: Optional[str]) -> None:
        key = tenant if tenant is not None else "__base__"
        d[key] = d.get(key, 0) + 1

    def consume(self, ev) -> None:
        if ev.kind == "first_token":
            if self.ttft_target_s is not None \
                    and ev.attrs["ttft"] > self.ttft_target_s:
                self._bump(self.ttft_violations, ev.attrs.get("tenant"))
        elif ev.kind == "done":
            self.n_done += 1
            tenant = ev.attrs.get("tenant")
            slack = ev.attrs.get("deadline_slack")
            if slack is not None and slack < 0:
                self._bump(self.deadline_misses, tenant)
            if self.itl_target_s is not None:
                n_tok = ev.attrs.get("n_tokens") or 0
                ttft = ev.attrs.get("ttft")
                if n_tok > 1 and ttft is not None:
                    itl = (ev.attrs["latency"] - ttft) / (n_tok - 1)
                    if itl > self.itl_target_s:
                        self._bump(self.itl_violations, tenant)

    def report(self) -> dict:
        return {
            "requests_done": self.n_done,
            "ttft_target_s": self.ttft_target_s,
            "itl_target_s": self.itl_target_s,
            "deadline_misses": dict(sorted(self.deadline_misses.items())),
            "ttft_violations": dict(sorted(self.ttft_violations.items())),
            "itl_violations": dict(sorted(self.itl_violations.items())),
        }


# ---------------------------------------------------------------------------
# Prometheus-style text exposition
# ---------------------------------------------------------------------------
def _fmt_le(le: float) -> str:
    return "+Inf" if math.isinf(le) else f"{le:.6g}"


def prometheus_text(metrics, slo: Optional[SLOCounters] = None,
                    namespace: str = "repro_serve") -> str:
    """Render a ``Metrics`` collector as Prometheus text exposition.

    Counters for requests/tokens/steps (per tenant and per decode path),
    histograms (cumulative log buckets + _sum/_count) for TTFT, queue
    wait and latency. Pure function of the collector — safe to call any
    time, including from the snapshot writer.
    """
    lines: List[str] = []

    def counter(name: str, value, labels: str = "", help_: str = ""):
        if help_:
            lines.append(f"# HELP {namespace}_{name} {help_}")
        lines.append(f"# TYPE {namespace}_{name} counter")
        lines.append(f"{namespace}_{name}{labels} {value}")

    lines.append(f"# TYPE {namespace}_requests_total counter")
    lines.append(f"# TYPE {namespace}_tokens_total counter")
    for tenant, st in sorted(metrics.tenants.items()):
        lab = f'{{tenant="{tenant}"}}'
        lines.append(f"{namespace}_requests_total{lab} {st.n_requests}")
        lines.append(f"{namespace}_tokens_total{lab} {st.n_tokens}")
    counter("decode_steps_total", metrics.n_decode_steps)
    counter("prefills_total", metrics.n_prefills)
    if getattr(metrics, "decode_paths", None):
        lines.append(f"# TYPE {namespace}_decode_path_steps_total counter")
        for path, n in sorted(metrics.decode_paths.items()):
            lines.append(f"{namespace}_decode_path_steps_total"
                         f'{{path="{path}"}} {n}')

    for hist_name, attr in (("ttft_seconds", "ttfts"),
                            ("queue_wait_seconds", "queue_waits"),
                            ("latency_seconds", "latencies")):
        lines.append(f"# TYPE {namespace}_{hist_name} histogram")
        for tenant, st in sorted(metrics.tenants.items()):
            h: StreamingHistogram = getattr(st, attr)
            for le, cum in h.cumulative():
                lines.append(
                    f'{namespace}_{hist_name}_bucket{{tenant="{tenant}",'
                    f'le="{_fmt_le(le)}"}} {cum}')
            lines.append(f'{namespace}_{hist_name}_sum{{tenant="{tenant}"}} '
                         f"{h.total:.9g}")
            lines.append(f'{namespace}_{hist_name}_count'
                         f'{{tenant="{tenant}"}} {h.n}')

    if slo is not None:
        for name, d in (("deadline_misses_total", slo.deadline_misses),
                        ("ttft_violations_total", slo.ttft_violations),
                        ("itl_violations_total", slo.itl_violations)):
            lines.append(f"# TYPE {namespace}_{name} counter")
            for tenant, n in sorted(d.items()):
                lines.append(f'{namespace}_{name}{{tenant="{tenant}"}} {n}')
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Periodic JSON snapshots
# ---------------------------------------------------------------------------
class TelemetrySnapshotWriter:
    """Write a JSON telemetry snapshot every ``interval_s`` of engine time.

    Driven entirely by the ``now`` values the engine passes in (its
    injectable clock), so snapshots are deterministic under
    ``VirtualClock`` and the writer itself never reads a clock. Files
    are written atomically (tmp + rename) so a scraper never sees a
    torn snapshot.
    """

    def __init__(self, path: str, interval_s: float):
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be > 0")
        self.path = path
        self.interval_s = float(interval_s)
        self.last_write_t: Optional[float] = None
        self.n_written = 0

    def maybe_write(self, now: float, payload_fn) -> bool:
        """Write if the interval elapsed; ``payload_fn()`` builds the body
        lazily (only called when actually writing). Returns True on write."""
        if self.last_write_t is not None \
                and now - self.last_write_t < self.interval_s:
            return False
        self.write(now, payload_fn())
        return True

    def write(self, now: float, payload: dict) -> None:
        body = {"t": now, "seq": self.n_written, **payload}
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump(body, f, indent=2, default=_json_default)
        os.replace(tmp, self.path)
        self.last_write_t = now
        self.n_written += 1


def _json_default(o):
    if isinstance(o, StreamingHistogram):
        return o.to_dict()
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o)!r}")
