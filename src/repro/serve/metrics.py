"""Per-tenant serving metrics: throughput, TTFT, latency, occupancy.

Collected host-side by the continuous engine with an injectable clock so
tests and benchmarks get deterministic numbers. ``report()`` returns a
plain-dict snapshot suitable for JSON (BENCH_serve.json).

Since the telemetry PR, ``Metrics`` is a **consumer of the engine's
event stream** (``serve.trace.EventBus``): the engine emits one typed
event per hook site and metrics, tracing and SLO counters all read the
same events — one source of truth. The ``record_*`` methods remain the
public surface (and are what ``consume`` dispatches to), so direct
callers keep working.

Per-tenant samples (TTFT, queue wait, latency) are held in
:class:`~repro.serve.telemetry.StreamingHistogram`\\ s: exact percentiles
below the histogram's cap, fixed log-bucket counts above it — a
million-request run is bounded memory instead of three unbounded lists
per tenant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serve.telemetry import StreamingHistogram


@dataclass
class TenantStats:
    n_requests: int = 0
    n_tokens: int = 0
    # arrival -> first token / arrival -> admit / arrival -> done
    ttfts: StreamingHistogram = field(default_factory=StreamingHistogram)
    queue_waits: StreamingHistogram = field(default_factory=StreamingHistogram)
    latencies: StreamingHistogram = field(default_factory=StreamingHistogram)

    def report(self, wall: float) -> dict:
        return {
            "requests": self.n_requests,
            "tokens": self.n_tokens,
            "tokens_per_sec": self.n_tokens / wall if wall > 0 else None,
            "ttft_p50": self.ttfts.percentile(50),
            "ttft_p95": self.ttfts.percentile(95),
            "queue_wait_p50": self.queue_waits.percentile(50),
            "latency_p50": self.latencies.percentile(50),
            "latency_p95": self.latencies.percentile(95),
        }


class Metrics:
    """Aggregates per-tenant and whole-engine serving statistics.

    With ``data_shards > 1`` the engine also reports per-data-shard
    occupancy and throughput (slot rows shard over the mesh ``data``
    axis in contiguous pools; the balanced-admission policy is judged
    by exactly these numbers) plus per-shard **unique-tenant counts**
    per decode step — the number of distinct deltas each shard
    dequantizes, the observable the tenant-affinity admission policy
    exists to shrink. ``residency`` (set by the engine at drain time)
    carries the pre-decoded value-cache stats, and the per-step
    value-path/packed-path split is tallied here. ``decode_paths``
    counts decode steps per attributed dispatch path (see
    ``serve.trace.path_label``).
    """

    def __init__(self, n_slots: int, data_shards: int = 1):
        from repro.serve.scheduler import shard_pool_size
        self.n_slots = n_slots
        self.data_shards = data_shards
        self.shard_size = shard_pool_size(n_slots, data_shards)
        self.tenants: Dict[str, TenantStats] = {}
        self.step_active: List[int] = []     # active slots at each decode step
        # per-shard active counts at each decode step, [steps][data_shards]
        self.step_shard_active: List[List[int]] = []
        # per-shard distinct non-base tenant rows at each decode step
        self.step_shard_unique: List[List[int]] = []
        self.shard_tokens: List[int] = [0] * data_shards
        self.n_decode_steps = 0
        self.n_prefills = 0
        # decode steps served from the pre-decoded value cache vs packed
        self.residency_value_steps = 0
        self.residency_packed_steps = 0
        self.residency: Optional[dict] = None   # DeltaResidency.stats()
        # decode steps per attributed dispatch path label
        self.decode_paths: Dict[str, int] = {}
        self.jit_traces = 0
        # tenant lifecycle transitions (register/rollout/retire from the
        # engine; ready/promote/evict from the registry), by event kind
        self.lifecycle: Dict[str, int] = {}
        # inter-token latency: gap between consecutive "token" events of
        # one request, pooled across requests. The observable chunked
        # prefill's SLO knob protects — a prefill that preempts decode
        # shows up as an ITL spike on every in-flight request.
        self.itls = StreamingHistogram()
        self._last_token_t: Dict[int, float] = {}   # rid -> last token time
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None

    def _tenant(self, name: Optional[str]) -> TenantStats:
        key = name if name is not None else "__base__"
        return self.tenants.setdefault(key, TenantStats())

    # -- event-stream consumer ----------------------------------------------
    def consume(self, ev) -> None:
        """Apply one ``serve.trace.ServeEvent`` — the engine's event bus
        calls this; each kind maps onto the record hook below."""
        kind, a = ev.kind, ev.attrs
        if kind == "step":
            self.record_step(a["n_active"], a.get("shard_active"),
                             a.get("shard_unique"), a.get("residency_used"))
            path = a.get("path")
            if path is not None:
                self.decode_paths[path] = self.decode_paths.get(path, 0) + 1
        elif kind == "token":
            self.record_token(a.get("tenant"), a.get("n", 1))
            rid = a.get("rid")
            if rid is not None:
                last = self._last_token_t.get(rid)
                if last is not None:
                    self.itls.record(max(0.0, ev.t - last))
                self._last_token_t[rid] = ev.t
        elif kind == "admit":
            self.record_admit(a.get("tenant"), a["wait"])
        elif kind == "first_token":
            self.record_first_token(a.get("tenant"), a["ttft"])
        elif kind == "done":
            self.record_done(a.get("tenant"), a["latency"])
            self._last_token_t.pop(a.get("rid"), None)
        elif kind == "shard_token":
            self.record_shard_token(a["shard"], a.get("n", 1))
        elif kind == "start":
            self.start(ev.t)
        elif kind == "stop":
            self.stop(ev.t)
        elif kind == "jit_trace":
            self.jit_traces += 1
        elif kind in ("tenant_register", "tenant_rollout", "tenant_retire",
                      "tenant_ready", "tenant_promote", "tenant_evict"):
            self.lifecycle[kind] = self.lifecycle.get(kind, 0) + 1

    # -- recording hooks ----------------------------------------------------
    def start(self, now: float) -> None:
        if self.t_start is None:
            self.t_start = now

    def stop(self, now: float) -> None:
        self.t_end = now

    def record_admit(self, tenant: Optional[str], wait: float) -> None:
        t = self._tenant(tenant)
        t.n_requests += 1
        t.queue_waits.record(wait)
        self.n_prefills += 1

    def record_first_token(self, tenant: Optional[str], ttft: float) -> None:
        self._tenant(tenant).ttfts.record(ttft)

    def record_token(self, tenant: Optional[str], n: int = 1) -> None:
        self._tenant(tenant).n_tokens += n

    def record_done(self, tenant: Optional[str], latency: float) -> None:
        self._tenant(tenant).latencies.record(latency)

    def record_step(self, n_active: int,
                    shard_active: Optional[List[int]] = None,
                    shard_unique: Optional[List[int]] = None,
                    residency_used: Optional[bool] = None) -> None:
        self.n_decode_steps += 1
        self.step_active.append(n_active)
        if shard_active is not None:
            if len(shard_active) != self.data_shards:
                # ValueError (not assert): a ragged row must fail loudly
                # even under python -O, not corrupt the step matrix
                raise ValueError(
                    f"shard_active has {len(shard_active)} entries for "
                    f"{self.data_shards} data shards")
            self.step_shard_active.append(list(shard_active))
        if shard_unique is not None:
            if len(shard_unique) != self.data_shards:
                raise ValueError(
                    f"shard_unique has {len(shard_unique)} entries for "
                    f"{self.data_shards} data shards")
            self.step_shard_unique.append(list(shard_unique))
        if residency_used is not None:
            if residency_used:
                self.residency_value_steps += 1
            else:
                self.residency_packed_steps += 1

    def record_shard_token(self, shard: int, n: int = 1) -> None:
        if not 0 <= shard < self.data_shards:
            raise ValueError(
                f"shard {shard} out of range for {self.data_shards} "
                f"data shards")
        self.shard_tokens[shard] += n

    # -- reporting ----------------------------------------------------------
    @property
    def occupancy(self) -> Optional[float]:
        if not self.step_active:
            return None
        return float(np.mean(self.step_active)) / self.n_slots

    def shard_report(self, wall: float) -> Optional[list]:
        """Per-data-shard occupancy / throughput rows (None when data=1)."""
        if self.data_shards <= 1:
            return None
        if self.step_shard_active:
            per_step = np.asarray(self.step_shard_active, np.float64)
            occ = (per_step.mean(axis=0) / self.shard_size).tolist()
        else:
            occ = [None] * self.data_shards
        uniq = self.unique_tenants_per_shard_mean
        return [{
            "shard": s,
            "slots": [s * self.shard_size, (s + 1) * self.shard_size],
            "occupancy": occ[s],
            "unique_tenants_mean": None if uniq is None else uniq[s],
            "tokens": self.shard_tokens[s],
            "tokens_per_sec": self.shard_tokens[s] / wall if wall > 0 else None,
        } for s in range(self.data_shards)]

    @property
    def unique_tenants_per_shard_mean(self) -> Optional[List[float]]:
        """Mean (over decode steps) distinct non-base tenants per shard —
        the per-device dequantization load affinity admission shrinks."""
        if not self.step_shard_unique:
            return None
        per_step = np.asarray(self.step_shard_unique, np.float64)
        return per_step.mean(axis=0).tolist()

    @property
    def shard_imbalance_max(self) -> Optional[int]:
        """Max over decode steps of (most - least active shard). Balanced
        admission keeps this small; decode-time finishes can widen it."""
        if not self.step_shard_active:
            return None
        per_step = np.asarray(self.step_shard_active, np.int64)
        return int(np.max(per_step.max(axis=1) - per_step.min(axis=1)))

    def report(self) -> dict:
        wall = 0.0
        if self.t_start is not None and self.t_end is not None:
            # clamp: stop() never called after a reset leaves t_end from
            # a previous epoch; 0.0 beats a negative wall time downstream
            wall = max(0.0, self.t_end - self.t_start)
        total_tokens = sum(t.n_tokens for t in self.tenants.values())
        pooled_ttft = StreamingHistogram.merged(
            [t.ttfts for t in self.tenants.values() if t.ttfts.n])
        uniq = self.unique_tenants_per_shard_mean
        residency = None
        if self.residency is not None \
                or self.residency_value_steps or self.residency_packed_steps:
            residency = dict(self.residency or {})
            residency["value_steps"] = self.residency_value_steps
            residency["packed_steps"] = self.residency_packed_steps
        return {
            "data_shards": self.data_shards,
            "shards": self.shard_report(wall),
            "shard_imbalance_max": self.shard_imbalance_max,
            "unique_tenants_per_shard_mean": uniq,
            "unique_tenants_mean": None if uniq is None
            else float(np.mean(uniq)),
            "residency": residency,
            "wall_time_s": wall,
            "n_slots": self.n_slots,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "batch_occupancy": self.occupancy,
            "total_tokens": total_tokens,
            "tokens_per_sec": total_tokens / wall if wall > 0 else None,
            # pooled across all requests (a median of per-tenant medians
            # is not a p50)
            "ttft_p50": pooled_ttft.percentile(50),
            "ttft_p95": pooled_ttft.percentile(95),
            "itl_p50": self.itls.percentile(50),
            "itl_p95": self.itls.percentile(95),
            "decode_paths": dict(sorted(self.decode_paths.items())) or None,
            "tenant_lifecycle": dict(sorted(self.lifecycle.items())) or None,
            "tenants": {k: t.report(wall) for k, t in sorted(self.tenants.items())},
        }
