"""Per-tenant serving metrics: throughput, TTFT, latency, occupancy.

Collected host-side by the continuous engine with an injectable clock so
tests and benchmarks get deterministic numbers. ``report()`` returns a
plain-dict snapshot suitable for JSON (BENCH_serve.json).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def _pct(xs: List[float], q: float) -> Optional[float]:
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclass
class TenantStats:
    n_requests: int = 0
    n_tokens: int = 0
    ttfts: List[float] = field(default_factory=list)      # arrival -> first token
    queue_waits: List[float] = field(default_factory=list)  # arrival -> admit
    latencies: List[float] = field(default_factory=list)  # arrival -> done

    def report(self, wall_time: float) -> dict:
        return {
            "requests": self.n_requests,
            "tokens": self.n_tokens,
            "tokens_per_sec": self.n_tokens / wall_time if wall_time > 0 else None,
            "ttft_p50": _pct(self.ttfts, 50), "ttft_p95": _pct(self.ttfts, 95),
            "queue_wait_p50": _pct(self.queue_waits, 50),
            "latency_p50": _pct(self.latencies, 50),
            "latency_p95": _pct(self.latencies, 95),
        }


class Metrics:
    """Aggregates per-tenant and whole-engine serving statistics.

    With ``data_shards > 1`` the engine also reports per-data-shard
    occupancy and throughput (slot rows shard over the mesh ``data``
    axis in contiguous pools; the balanced-admission policy is judged
    by exactly these numbers) plus per-shard **unique-tenant counts**
    per decode step — the number of distinct deltas each shard
    dequantizes, the observable the tenant-affinity admission policy
    exists to shrink. ``residency`` (set by the engine at drain time)
    carries the pre-decoded value-cache stats, and the per-step
    value-path/packed-path split is tallied here.
    """

    def __init__(self, n_slots: int, data_shards: int = 1):
        from repro.serve.scheduler import shard_pool_size
        self.n_slots = n_slots
        self.data_shards = data_shards
        self.shard_size = shard_pool_size(n_slots, data_shards)
        self.tenants: Dict[str, TenantStats] = {}
        self.step_active: List[int] = []     # active slots at each decode step
        # per-shard active counts at each decode step, [steps][data_shards]
        self.step_shard_active: List[List[int]] = []
        # per-shard distinct non-base tenant rows at each decode step
        self.step_shard_unique: List[List[int]] = []
        self.shard_tokens: List[int] = [0] * data_shards
        self.n_decode_steps = 0
        self.n_prefills = 0
        # decode steps served from the pre-decoded value cache vs packed
        self.residency_value_steps = 0
        self.residency_packed_steps = 0
        self.residency: Optional[dict] = None   # DeltaResidency.stats()
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None

    def _tenant(self, name: Optional[str]) -> TenantStats:
        key = name if name is not None else "__base__"
        return self.tenants.setdefault(key, TenantStats())

    # -- recording hooks (driven by the engine) -----------------------------
    def start(self, now: float) -> None:
        if self.t_start is None:
            self.t_start = now

    def stop(self, now: float) -> None:
        self.t_end = now

    def record_admit(self, tenant: Optional[str], wait: float) -> None:
        t = self._tenant(tenant)
        t.n_requests += 1
        t.queue_waits.append(wait)
        self.n_prefills += 1

    def record_first_token(self, tenant: Optional[str], ttft: float) -> None:
        self._tenant(tenant).ttfts.append(ttft)

    def record_token(self, tenant: Optional[str], n: int = 1) -> None:
        self._tenant(tenant).n_tokens += n

    def record_done(self, tenant: Optional[str], latency: float) -> None:
        self._tenant(tenant).latencies.append(latency)

    def record_step(self, n_active: int,
                    shard_active: Optional[List[int]] = None,
                    shard_unique: Optional[List[int]] = None,
                    residency_used: Optional[bool] = None) -> None:
        self.n_decode_steps += 1
        self.step_active.append(n_active)
        if shard_active is not None:
            if len(shard_active) != self.data_shards:
                # ValueError (not assert): a ragged row must fail loudly
                # even under python -O, not corrupt the step matrix
                raise ValueError(
                    f"shard_active has {len(shard_active)} entries for "
                    f"{self.data_shards} data shards")
            self.step_shard_active.append(list(shard_active))
        if shard_unique is not None:
            if len(shard_unique) != self.data_shards:
                raise ValueError(
                    f"shard_unique has {len(shard_unique)} entries for "
                    f"{self.data_shards} data shards")
            self.step_shard_unique.append(list(shard_unique))
        if residency_used is not None:
            if residency_used:
                self.residency_value_steps += 1
            else:
                self.residency_packed_steps += 1

    def record_shard_token(self, shard: int, n: int = 1) -> None:
        self.shard_tokens[shard] += n

    # -- reporting ----------------------------------------------------------
    @property
    def occupancy(self) -> Optional[float]:
        if not self.step_active:
            return None
        return float(np.mean(self.step_active)) / self.n_slots

    def shard_report(self, wall: float) -> Optional[list]:
        """Per-data-shard occupancy / throughput rows (None when data=1)."""
        if self.data_shards <= 1:
            return None
        if self.step_shard_active:
            per_step = np.asarray(self.step_shard_active, np.float64)
            occ = (per_step.mean(axis=0) / self.shard_size).tolist()
        else:
            occ = [None] * self.data_shards
        uniq = self.unique_tenants_per_shard_mean
        return [{
            "shard": s,
            "slots": [s * self.shard_size, (s + 1) * self.shard_size],
            "occupancy": occ[s],
            "unique_tenants_mean": None if uniq is None else uniq[s],
            "tokens": self.shard_tokens[s],
            "tokens_per_sec": self.shard_tokens[s] / wall if wall > 0 else None,
        } for s in range(self.data_shards)]

    @property
    def unique_tenants_per_shard_mean(self) -> Optional[List[float]]:
        """Mean (over decode steps) distinct non-base tenants per shard —
        the per-device dequantization load affinity admission shrinks."""
        if not self.step_shard_unique:
            return None
        per_step = np.asarray(self.step_shard_unique, np.float64)
        return per_step.mean(axis=0).tolist()

    @property
    def shard_imbalance_max(self) -> Optional[int]:
        """Max over decode steps of (most - least active shard). Balanced
        admission keeps this small; decode-time finishes can widen it."""
        if not self.step_shard_active:
            return None
        per_step = np.asarray(self.step_shard_active, np.int64)
        return int(np.max(per_step.max(axis=1) - per_step.min(axis=1)))

    def report(self) -> dict:
        wall = 0.0
        if self.t_start is not None and self.t_end is not None:
            wall = self.t_end - self.t_start
        total_tokens = sum(t.n_tokens for t in self.tenants.values())
        all_ttfts = [x for t in self.tenants.values() for x in t.ttfts]
        uniq = self.unique_tenants_per_shard_mean
        residency = None
        if self.residency is not None \
                or self.residency_value_steps or self.residency_packed_steps:
            residency = dict(self.residency or {})
            residency["value_steps"] = self.residency_value_steps
            residency["packed_steps"] = self.residency_packed_steps
        return {
            "data_shards": self.data_shards,
            "shards": self.shard_report(wall),
            "shard_imbalance_max": self.shard_imbalance_max,
            "unique_tenants_per_shard_mean": uniq,
            "unique_tenants_mean": None if uniq is None
            else float(np.mean(uniq)),
            "residency": residency,
            "wall_time_s": wall,
            "n_slots": self.n_slots,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "batch_occupancy": self.occupancy,
            "total_tokens": total_tokens,
            "tokens_per_sec": total_tokens / wall if wall > 0 else None,
            # pooled across all requests (a median of per-tenant medians
            # is not a p50)
            "ttft_p50": _pct(all_ttfts, 50),
            "ttft_p95": _pct(all_ttfts, 95),
            "tenants": {k: t.report(wall) for k, t in sorted(self.tenants.items())},
        }
