"""Unified model zoo: one functional LM covering all assigned families.

Families: dense / moe / ssm / hybrid (decoder-only), encdec (seamless-m4t),
vlm (gated cross-attention). A model is (ArchConfig, params pytree); every
entry point takes an optional ``deltas`` pytree mirroring params (None at
uncompressed leaves) implementing the paper's separate computation.

Param layout: per-kind stacks with a leading layer dim. Uniform archs
(dense/moe/ssm with one layer kind) train via ``lax.scan`` over the stack
(compact HLO, per-layer remat); heterogeneous archs (hybrid/vlm/encdec) and
all cached serving paths walk the layers in a Python loop slicing stacks.

Entry points
    param_specs / param_axes / init_params
    forward(cfg, params, batch, deltas)            -> logits  [train path]
    loss_fn(cfg, params, batch, deltas)            -> (loss, metrics)
    cache_specs / init_cache
    prefill(cfg, params, batch, cache, deltas)     -> (last logits, cache)
    decode_step(cfg, params, cache, tokens, pos, deltas) -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.core.apply import apply_linear, dget, dindex
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    attention,
    cross_attention,
    glu_mlp,
    qkv_project,
    rmsnorm,
    softcap,
)


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------
def layer_plan(cfg: ArchConfig):
    """[(kind, index_within_kind_stack, window)] for the decoder stack."""
    counters: dict[str, int] = {}
    plan = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kinds[i]
        j = counters.get(kind, 0)
        counters[kind] = j + 1
        plan.append((kind, j, int(cfg.layer_windows[i])))
    return plan


def kind_counts(cfg: ArchConfig) -> dict[str, int]:
    c: dict[str, int] = {}
    for k in cfg.layer_kinds:
        c[k] = c.get(k, 0) + 1
    return c


def n_cross_blocks(cfg: ArchConfig) -> int:
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return len(range(cfg.cross_attn_every - 1, cfg.n_layers, cfg.cross_attn_every))
    return 0


def n_mlp_layers(cfg: ArchConfig) -> int:
    base = sum(1 for k in cfg.layer_kinds if k in ("attn", "rec"))
    return base + n_cross_blocks(cfg)


# When True, the train path unrolls layers instead of lax.scan. Used by the
# roofline dry-run: SPMD partitioning hides scan trip counts from XLA's
# cost analysis, so unrolled lowering gives truthful per-step FLOP counts
# (EXPERIMENTS.md §Perf, measurement-fix M1).
_FORCE_LOOP = False


def set_force_loop(v: bool) -> None:
    global _FORCE_LOOP
    _FORCE_LOOP = v


def uniform_kind(cfg: ArchConfig) -> Optional[str]:
    """The single layer kind if the arch can use the scan train path."""
    if _FORCE_LOOP:
        return None
    kinds = set(cfg.layer_kinds)
    if len(kinds) == 1 and cfg.family in ("dense", "moe", "ssm"):
        return next(iter(kinds))
    return None


# ---------------------------------------------------------------------------
# Param tables: (name, shape, logical_axes, init)
# ---------------------------------------------------------------------------
def _attn_table(cfg: ArchConfig):
    d, q, kv, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    t = [
        ("ln1", (d,), (None,), "zeros"),
        ("wq", (d, q), ("embed", "heads"), "normal"),
        ("wk", (d, kv), ("embed", "kv_heads"), "normal"),
        ("wv", (d, kv), ("embed", "kv_heads"), "normal"),
        ("wo", (q, d), ("heads", "embed"), "normal"),
    ]
    if cfg.qk_norm:
        t += [("q_norm", (hd,), (None,), "zeros"), ("k_norm", (hd,), (None,), "zeros")]
    return t


def _mlp_table(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("ln", (d,), (None,), "zeros"),
        ("wi", (d, f), ("embed", "mlp"), "normal"),
        ("wg", (d, f), ("embed", "mlp"), "normal"),
        ("wo", (f, d), ("mlp", "embed"), "normal"),
    ]


def _moe_table(cfg: ArchConfig):
    d, m = cfg.d_model, cfg.moe
    t = [
        ("ln", (d,), (None,), "zeros"),
        ("router", (d, m.n_experts), ("embed", None), "normal"),
        ("wi", (m.n_experts, d, m.d_expert), ("experts", "embed", "expert_ff"), "normal"),
        ("wg", (m.n_experts, d, m.d_expert), ("experts", "embed", "expert_ff"), "normal"),
        ("wo", (m.n_experts, m.d_expert, d), ("experts", "expert_ff", "embed"), "normal"),
    ]
    if m.shared_expert:
        t += [
            ("shared/wi", (d, m.d_expert), ("embed", "mlp"), "normal"),
            ("shared/wg", (d, m.d_expert), ("embed", "mlp"), "normal"),
            ("shared/wo", (m.d_expert, d), ("mlp", "embed"), "normal"),
        ]
    return t


def _ssm_table(cfg: ArchConfig):
    d = cfg.d_model
    d_inner, H, P, N = ssm_mod.dims(cfg)[:4]
    G = cfg.ssm.n_groups
    W = cfg.ssm.conv_width
    bc = 2 * G * N
    return [
        ("norm", (d,), (None,), "zeros"),
        ("wz", (d, d_inner), ("embed", "inner"), "normal"),
        ("wx", (d, d_inner), ("embed", "inner"), "normal"),
        ("wbc", (d, bc), ("embed", None), "normal"),
        ("wdt", (d, H), ("embed", None), "normal"),
        ("conv_x_w", (W, d_inner), (None, "inner"), "normal"),
        ("conv_x_b", (d_inner,), (None,), "zeros"),
        ("conv_bc_w", (W, bc), (None, None), "normal"),
        ("conv_bc_b", (bc,), (None,), "zeros"),
        ("a_log", (H,), (None,),
         lambda r, s: jnp.log(jax.random.uniform(r, s, minval=1.0, maxval=16.0))),
        ("d_skip", (H,), (None,), "ones"),
        ("dt_bias", (H,), (None,), lambda r, s: jnp.log(jnp.expm1(
            jax.random.uniform(r, s, minval=1e-3, maxval=0.1)))),
        ("out_norm", (d_inner,), (None,), "zeros"),
        ("wout", (d_inner, d), ("inner", "embed"), "normal"),
    ]


def _rec_table(cfg: ArchConfig):
    d = cfg.d_model
    lru = cfg.rglru.lru_width or d
    W = cfg.rglru.conv_width
    return [
        ("norm", (d,), (None,), "zeros"),
        ("linear_x", (d, lru), ("embed", "lru"), "normal"),
        ("linear_y", (d, lru), ("embed", "lru"), "normal"),
        ("linear_out", (lru, d), ("lru", "embed"), "normal"),
        ("conv_w", (W, lru), (None, "lru"), "normal"),
        ("conv_b", (lru,), (None,), "zeros"),
        ("a_param", (lru,), (None,), lambda r, s: jax.random.uniform(r, s, minval=2.0, maxval=6.0)),
        ("a_gate_w", (lru,), (None,), "normal_vec"),
        ("a_gate_b", (lru,), (None,), "zeros"),
        ("i_gate_w", (lru,), (None,), "normal_vec"),
        ("i_gate_b", (lru,), (None,), "zeros"),
    ]


def _cross_table(cfg: ArchConfig):
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return [
        ("ln1", (d,), (None,), "zeros"),
        ("wq", (d, q), ("embed", "heads"), "normal"),
        ("wk", (d, kv), ("embed", "kv_heads"), "normal"),
        ("wv", (d, kv), ("embed", "kv_heads"), "normal"),
        ("wo", (q, d), ("heads", "embed"), "normal"),
        ("gate_attn", (), (), "zeros"),
        ("gate_mlp", (), (), "zeros"),
    ]


def _build_stack(table, n, make):
    out: dict[str, Any] = {}
    for name, shape, axes, init in table:
        fan_in = shape[-2] if len(shape) >= 2 else (shape[0] if shape else 1)
        leaf = make((n, *shape), axes=("layers", *axes), init=init, fan_in=fan_in)
        node = out
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return out


def _structure(cfg: ArchConfig, make) -> dict:
    counts = kind_counts(cfg)
    tree: dict[str, Any] = {
        "embed": {"tok": make((cfg.vocab, cfg.d_model), axes=("vocab", "embed"),
                              init="embed", fan_in=cfg.d_model)},
        "final_norm": {"scale": make((cfg.d_model,), axes=(None,), init="zeros", fan_in=1)},
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = {"w": make((cfg.d_model, cfg.vocab), axes=("embed", "vocab"),
                                     init="normal", fan_in=cfg.d_model)}
    n_attn = counts.get("attn", 0) + counts.get("moe", 0)
    if n_attn:
        tree["attn"] = _build_stack(_attn_table(cfg), n_attn, make)
    nm = n_mlp_layers(cfg)
    if nm and cfg.d_ff:
        tree["mlp"] = _build_stack(_mlp_table(cfg), nm, make)
    if counts.get("moe"):
        tree["moe"] = _build_stack(_moe_table(cfg), counts["moe"], make)
    if counts.get("ssm"):
        tree["ssm"] = _build_stack(_ssm_table(cfg), counts["ssm"], make)
    if counts.get("rec"):
        tree["rec"] = _build_stack(_rec_table(cfg), counts["rec"], make)
        # pre-FFN norm for rec layers lives in the mlp stack's "ln"
    if cfg.family == "vlm":
        tree["cross"] = _build_stack(_cross_table(cfg), n_cross_blocks(cfg), make)
    if cfg.family == "encdec":
        tree["enc"] = {
            "attn": _build_stack(_attn_table(cfg), cfg.n_enc_layers, make),
            "mlp": _build_stack(_mlp_table(cfg), cfg.n_enc_layers, make),
            "final_norm": {"scale": make((cfg.d_model,), axes=(None,), init="zeros", fan_in=1)},
        }
        tree["dec_cross"] = _build_stack(_cross_table(cfg), cfg.n_layers, make)
    return tree


def param_specs(cfg: ArchConfig):
    def make(shape, *, axes, init, fan_in):
        dtype = jnp.dtype(cfg.param_dtype) if len(shape) >= 3 else jnp.float32
        return jax.ShapeDtypeStruct(shape, dtype)
    return _structure(cfg, make)


def param_axes(cfg: ArchConfig):
    def make(shape, *, axes, init, fan_in):
        return tuple(axes)
    return _structure(cfg, make)


def init_params(cfg: ArchConfig, rng, scale: float = 1.0):
    cnt = [0]

    def make(shape, *, axes, init, fan_in):
        cnt[0] += 1
        r = jax.random.fold_in(rng, cnt[0])
        # stacked leaves: (layers, *shape); >=3 dims = weight matrices -> bf16
        dtype = jnp.dtype(cfg.param_dtype) if len(shape) >= 3 else jnp.float32
        if callable(init):
            return init(r, shape).astype(jnp.float32)
        if init == "zeros":
            return jnp.zeros(shape, dtype if len(shape) >= 3 else jnp.float32)
        if init == "ones":
            return jnp.ones(shape, jnp.float32)
        if init == "normal_vec":
            return (jax.random.normal(r, shape) * 0.1).astype(jnp.float32)
        if init == "embed":
            return (jax.random.normal(r, shape) * scale).astype(dtype)
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(r, shape) * std).astype(dtype)

    return _structure(cfg, make)


# ---------------------------------------------------------------------------
# Sub-blocks
# ---------------------------------------------------------------------------
def _attn_block_train(cfg, p, d, x, positions, window):
    """Self-attention sub-block, no cache (train/prefill compute)."""
    u = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(u, p, d, cfg, positions)
    out = attention(q, k, v, positions, positions, window=window, causal=True,
                    cap=cfg.attn_softcap)
    out = apply_linear(out.reshape(*x.shape[:-1], cfg.q_dim), p["wo"], dget(d, "wo"))
    return x + out


def _attn_block_prefill(cfg, p, d, x, positions, window, cache):
    """Train-style attention + cache write of the last S_c tokens.

    ``positions`` is [S] (shared) or [B, S] (per-row, continuous batching:
    left-padded prompts carry negative positions at pad slots, which the
    cache marks invalid so they are never attended).
    """
    u = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(u, p, d, cfg, positions)
    out = attention(q, k, v, positions, positions, window=window, causal=True,
                    cap=cfg.attn_softcap)
    S = k.shape[1]
    S_c = cache["k"].shape[1]
    n_write = min(S, S_c)
    if positions.ndim == 1:
        pos_w = positions[-n_write:]
        slots = pos_w % S_c
        new_cache = dict(
            k=cache["k"].at[:, slots].set(k[:, -n_write:].astype(cache["k"].dtype)),
            v=cache["v"].at[:, slots].set(v[:, -n_write:].astype(cache["v"].dtype)),
            pos=cache["pos"].at[:, slots].set(pos_w[None]),
        )
    else:
        B = x.shape[0]
        pos_w = positions[:, -n_write:]                   # [B, n_write]
        slots = pos_w % S_c
        bi = jnp.arange(B)[:, None]
        new_cache = dict(
            k=cache["k"].at[bi, slots].set(k[:, -n_write:].astype(cache["k"].dtype)),
            v=cache["v"].at[bi, slots].set(v[:, -n_write:].astype(cache["v"].dtype)),
            pos=cache["pos"].at[bi, slots].set(pos_w),
        )
    out = apply_linear(out.reshape(*x.shape[:-1], cfg.q_dim), p["wo"], dget(d, "wo"))
    return x + out, new_cache


def _attn_block_chunk(cfg, p, d, x, positions, window, cache, valid):
    """Multi-token ring attention for one chunked-prefill row.

    The chunk analogue of ``_attn_block_decode``'s per-row branch:
    ``positions`` [B, C] are absolute prompt positions (a resumable
    cursor offset, NOT starting at 0). Queries attend the pre-write
    ring concatenated with the chunk's own K/V (position-masked, so a
    token sees earlier chunks plus its own prefix), THEN every token's
    K/V is scattered into its ring slot for the chunks/decodes that
    follow. ``valid`` [B, C] bool (or None) marks real tokens in a
    right-padded chunk: pad entries scatter to an out-of-range slot and
    are dropped, so they can never shadow live ring keys (windowed
    layers included), their keys sit at positions past every real
    query (causally masked), and their query outputs are garbage the
    caller discards.
    """
    u = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(u, p, d, cfg, positions)
    B = x.shape[0]
    S_c = cache["k"].shape[1]
    # Attend BEFORE the ring write, over (old ring ++ this chunk): a
    # windowed layer's ring keeps only the LAST token's window, so
    # writing all C tokens first would evict up to C-1 keys that the
    # chunk's earlier queries still need. The pre-write ring holds every
    # key older than the chunk; the appended segment holds the chunk
    # itself (causally masked by position). Pad keys carry positions
    # past every real query, so the causal mask excludes them.
    k_all = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
    v_all = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
    kp_all = jnp.concatenate([cache["pos"], positions], axis=1)
    out = attention(q, k_all, v_all, positions, kp_all, window=window,
                    causal=True, cap=cfg.attn_softcap)
    slots = positions % S_c                               # [B, C]
    if valid is not None:
        slots = jnp.where(valid, slots, S_c)              # pad -> dropped
    bi = jnp.arange(B)[:, None]
    ck = cache["k"].at[bi, slots].set(k.astype(cache["k"].dtype), mode="drop")
    cv = cache["v"].at[bi, slots].set(v.astype(cache["v"].dtype), mode="drop")
    cp = cache["pos"].at[bi, slots].set(positions, mode="drop")
    out = apply_linear(out.reshape(*x.shape[:-1], cfg.q_dim), p["wo"], dget(d, "wo"))
    return x + out, dict(k=ck, v=cv, pos=cp)


def _attn_block_decode(cfg, p, d, x, pos, window, cache):
    """Single-token attention over the (ring-buffer) cache.

    ``pos`` scalar: all rows decode at the same position (static batch).
    ``pos`` [B]: per-slot positions (continuous batching) — each row
    writes its own ring slot.
    """
    u = rmsnorm(x, p["ln1"], cfg.norm_eps)
    S_c = cache["k"].shape[1]
    if jnp.ndim(pos) == 1:
        B = x.shape[0]
        positions = pos[:, None]                          # [B, 1]
        q, k, v = qkv_project(u, p, d, cfg, positions)
        slot = pos % S_c                                  # [B]
        bi = jnp.arange(B)
        ck = cache["k"].at[bi, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bi, slot].set(v[:, 0].astype(cache["v"].dtype))
        cp = cache["pos"].at[bi, slot].set(pos)
    else:
        positions = pos[None] if jnp.ndim(pos) == 0 else pos
        q, k, v = qkv_project(u, p, d, cfg, positions)
        slot = pos % S_c
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cp = cache["pos"].at[:, slot].set(positions[0])
    out = attention(q, ck, cv, positions, cp, window=window, causal=True,
                    cap=cfg.attn_softcap)
    out = apply_linear(out.reshape(*x.shape[:-1], cfg.q_dim), p["wo"], dget(d, "wo"))
    return x + out, dict(k=ck, v=cv, pos=cp)


def _mlp_block(cfg, p, d, x):
    u = rmsnorm(x, p["ln"], cfg.norm_eps)
    return x + glu_mlp(u, p, d, cfg.act)


def _moe_block(cfg, p, d, x):
    u = rmsnorm(x, p["ln"], cfg.norm_eps)
    return x + moe_mod.moe_ffn(u, p, d, cfg)


def _mem_kv(cfg, p, d, memory):
    B, S, _ = memory.shape
    k = apply_linear(memory, p["wk"], dget(d, "wk")).reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = apply_linear(memory, p["wv"], dget(d, "wv")).reshape(B, S, cfg.n_kv, cfg.head_dim)
    return k, v


def _cross_block(cfg, p, d, x, mem_kv, gated: bool):
    u = rmsnorm(x, p["ln1"], cfg.norm_eps)
    B, S, _ = u.shape
    q = apply_linear(u, p["wq"], dget(d, "wq")).reshape(B, S, cfg.n_heads, cfg.head_dim)
    out = cross_attention(q, *mem_kv, cap=cfg.attn_softcap)
    out = apply_linear(out.reshape(B, S, cfg.q_dim), p["wo"], dget(d, "wo"))
    if gated:
        out = out * jnp.tanh(p["gate_attn"].astype(out.dtype))
    return x + out


# ---------------------------------------------------------------------------
# Encoder (encdec family)
# ---------------------------------------------------------------------------
def encode(cfg: ArchConfig, params, feats, deltas=None):
    """Bidirectional encoder over precomputed frontend features [B,S,d]."""
    enc = params["enc"]
    denc = dget(deltas, "enc")
    x = feats.astype(jnp.dtype(cfg.param_dtype))
    S = x.shape[1]
    positions = jnp.arange(S)
    for i in range(cfg.n_enc_layers):
        p_a = _slice(enc["attn"], i)
        d_a = dindex(dget(denc, "attn"), i)
        u = rmsnorm(x, p_a["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(u, p_a, d_a, cfg, positions)
        out = attention(q, k, v, positions, positions, window=0, causal=False,
                        cap=cfg.attn_softcap)
        x = x + apply_linear(out.reshape(*x.shape[:-1], cfg.q_dim), p_a["wo"], dget(d_a, "wo"))
        x = _mlp_block(cfg, _slice(enc["mlp"], i), dindex(dget(denc, "mlp"), i), x)
    return rmsnorm(x, enc["final_norm"]["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Indices into per-kind stacks
# ---------------------------------------------------------------------------
def _slice(tree, i):
    if tree is None:
        return None
    return jax.tree.map(lambda a: a[i], tree)


def _attn_index(cfg, li):
    return sum(1 for k in cfg.layer_kinds[:li] if k in ("attn", "moe"))


def _mlp_index(cfg, li):
    return sum(1 for k in cfg.layer_kinds[:li] if k in ("attn", "rec"))


def _cross_mlp_index(cfg, cross_i):
    n_self = sum(1 for k in cfg.layer_kinds if k in ("attn", "rec"))
    return n_self + cross_i


def _cross_after(cfg) -> set:
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return set(range(cfg.cross_attn_every - 1, cfg.n_layers, cfg.cross_attn_every))
    return set()


# ---------------------------------------------------------------------------
# Layer walk (loop path): used by prefill/decode and heterogeneous training
# ---------------------------------------------------------------------------
def _walk(cfg: ArchConfig, params, x, positions, deltas=None, caches=None,
          memory=None, decode_pos=None, remat=False, chunk=False,
          chunk_valid=None):
    plan = layer_plan(cfg)
    cross_after = _cross_after(cfg)
    has_cache = caches is not None
    new_caches = [None] * (len(caches) if has_cache else 0)
    decode = decode_pos is not None
    ci = cfg.n_layers  # cross caches sit after the self-layer slots

    def mr(fn):
        return jax.checkpoint(fn) if remat else fn

    cross_i = 0
    for li, (kind, j, window) in enumerate(plan):
        cache_l = caches[li] if has_cache else None
        if kind in ("attn", "moe"):
            ai = _attn_index(cfg, li)
            p_a = _slice(params["attn"], ai)
            d_a = dindex(dget(deltas, "attn"), ai)
            if decode:
                x, new_caches[li] = _attn_block_decode(
                    cfg, p_a, d_a, x, decode_pos, window, cache_l)
            elif cache_l is not None and chunk:
                x, new_caches[li] = _attn_block_chunk(
                    cfg, p_a, d_a, x, positions, window, cache_l, chunk_valid)
            elif cache_l is not None:
                x, new_caches[li] = _attn_block_prefill(
                    cfg, p_a, d_a, x, positions, window, cache_l)
            else:
                x = mr(lambda x, p, d: _attn_block_train(
                    cfg, p, d, x, positions, window))(x, p_a, d_a)
            if kind == "moe":
                p_m = _slice(params["moe"], j)
                d_m = dindex(dget(deltas, "moe"), j)
                x = mr(lambda x, p, d: _moe_block(cfg, p, d, x))(x, p_m, d_m)
            else:
                mi = _mlp_index(cfg, li)
                p_m = _slice(params["mlp"], mi)
                d_m = dindex(dget(deltas, "mlp"), mi)
                x = mr(lambda x, p, d: _mlp_block(cfg, p, d, x))(x, p_m, d_m)
        elif kind == "ssm":
            p_s = _slice(params["ssm"], j)
            d_s = dindex(dget(deltas, "ssm"), j)
            fn = lambda x, p, d: ssm_mod.mamba_block(x, p, d, cfg, state=cache_l, decode=decode)
            out, new_st = mr(fn)(x, p_s, d_s) if not has_cache else fn(x, p_s, d_s)
            x = x + out
            if has_cache:
                new_caches[li] = new_st
        elif kind == "rec":
            p_r = _slice(params["rec"], j)
            d_r = dindex(dget(deltas, "rec"), j)
            fn = lambda x, p, d: rec_mod.rglru_block(x, p, d, cfg, state=cache_l, decode=decode)
            out, new_st = mr(fn)(x, p_r, d_r) if not has_cache else fn(x, p_r, d_r)
            x = x + out
            if has_cache:
                new_caches[li] = new_st
            mi = _mlp_index(cfg, li)
            p_m = _slice(params["mlp"], mi)
            d_m = dindex(dget(deltas, "mlp"), mi)
            x = mr(lambda x, p, d: _mlp_block(cfg, p, d, x))(x, p_m, d_m)
        else:
            raise ValueError(f"unknown layer kind {kind}")

        # vlm: gated cross block after every cross_attn_every-th layer
        if li in cross_after:
            p_c = _slice(params["cross"], cross_i)
            d_c = dindex(dget(deltas, "cross"), cross_i)
            if has_cache and decode:
                mem_kv = (caches[ci + cross_i]["k"], caches[ci + cross_i]["v"])
            else:
                mem_kv = _mem_kv(cfg, p_c, d_c, memory)
            if has_cache:
                new_caches[ci + cross_i] = dict(k=mem_kv[0], v=mem_kv[1])
            x = _cross_block(cfg, p_c, d_c, x, mem_kv, gated=True)
            cmi = _cross_mlp_index(cfg, cross_i)
            p_m = _slice(params["mlp"], cmi)
            d_m = dindex(dget(deltas, "mlp"), cmi)
            u = rmsnorm(x, p_m["ln"], cfg.norm_eps)
            x = x + glu_mlp(u, p_m, d_m, cfg.act) * jnp.tanh(p_c["gate_mlp"].astype(x.dtype))
            cross_i += 1

        # encdec: ungated cross-attention into encoder memory, every layer
        if cfg.family == "encdec":
            p_c = _slice(params["dec_cross"], li)
            d_c = dindex(dget(deltas, "dec_cross"), li)
            if has_cache and decode:
                mem_kv = (caches[ci + li]["k"], caches[ci + li]["v"])
            else:
                mem_kv = _mem_kv(cfg, p_c, d_c, memory)
            if has_cache:
                new_caches[ci + li] = dict(k=mem_kv[0], v=mem_kv[1])
            x = _cross_block(cfg, p_c, d_c, x, mem_kv, gated=False)
    return x, new_caches


# ---------------------------------------------------------------------------
# Scan walk (train path for uniform archs)
# ---------------------------------------------------------------------------
def _scan_walk(cfg: ArchConfig, params, x, positions, deltas=None, remat=False):
    kind = uniform_kind(cfg)
    if kind is None:
        raise ValueError(
            f"scan walk needs a uniform layer arch; {cfg.name!r} mixes "
            f"layer_kinds {sorted(set(cfg.layer_kinds))}")
    windows = jnp.asarray(cfg.layer_windows, jnp.int32)

    if kind == "attn":
        xs = {"a": params["attn"], "m": params["mlp"], "w": windows,
              "da": dget(deltas, "attn"), "dm": dget(deltas, "mlp")}

        def body(x, s):
            x = _attn_block_train(cfg, s["a"], s["da"], x, positions, s["w"])
            x = _mlp_block(cfg, s["m"], s["dm"], x)
            return x, None
    elif kind == "moe":
        xs = {"a": params["attn"], "m": params["moe"], "w": windows,
              "da": dget(deltas, "attn"), "dm": dget(deltas, "moe")}

        def body(x, s):
            x = _attn_block_train(cfg, s["a"], s["da"], x, positions, s["w"])
            x = _moe_block(cfg, s["m"], s["dm"], x)
            return x, None
    elif kind == "ssm":
        xs = {"s": params["ssm"], "ds": dget(deltas, "ssm")}

        def body(x, s):
            out, _ = ssm_mod.mamba_block(x, s["s"], s["ds"], cfg, state=None, decode=False)
            return x + out, None
    else:
        raise ValueError(kind)

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, xs)
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
_EMBED_GATHER_RESHARD = False


def set_embed_gather_reshard(v: bool) -> None:
    """Reshard the embedding table to d@model for the lookup: the gather
    then composes cleanly under SPMD (indices sharded on batch@data, table
    on d@model) instead of triggering involuntary full rematerialization.
    Enabled by mesh-aware launchers; off for single-device tests."""
    global _EMBED_GATHER_RESHARD
    _EMBED_GATHER_RESHARD = v


def embed_tokens(cfg, params, tokens):
    tok = params["embed"]["tok"]
    if _EMBED_GATHER_RESHARD:
        from jax.sharding import PartitionSpec as P
        tok = jax.lax.with_sharding_constraint(tok, P(None, "model"))
    return tok[tokens]


def unembed(cfg, params, h, deltas=None):
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["tok"].T
    else:
        logits = apply_linear(h, params["unembed"]["w"], dget(dget(deltas, "unembed"), "w"))
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def forward(cfg: ArchConfig, params, batch: dict, deltas=None, remat: bool = False):
    """Training/scoring forward: full-sequence causal logits [B,S,V]."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    if uniform_kind(cfg) is not None:
        h = _scan_walk(cfg, params, x, positions, deltas=deltas, remat=remat)
    else:
        memory = None
        if cfg.family == "encdec":
            memory = encode(cfg, params, batch["enc_feats"], deltas)
        elif cfg.family == "vlm":
            memory = batch["image_embeds"].astype(x.dtype)
        h, _ = _walk(cfg, params, x, positions, deltas=deltas, memory=memory, remat=remat)
    return unembed(cfg, params, h, deltas)


def loss_fn(cfg: ArchConfig, params, batch: dict, deltas=None, remat: bool = False):
    logits = forward(cfg, params, batch, deltas, remat=remat)
    labels = batch.get("labels")
    mask = batch.get("loss_mask")
    if labels is None:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)), constant_values=0)
        if mask is None:
            mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, batch: int, max_seq: int, enc_len: int = 0):
    """ShapeDtypeStruct tree for the serving cache (dry-run friendly)."""
    dtype = jnp.dtype(cfg.param_dtype)

    def attn_spec(window):
        S_c = max_seq if window == 0 else min(window, max_seq)
        return {
            "k": jax.ShapeDtypeStruct((batch, S_c, cfg.n_kv, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, S_c, cfg.n_kv, cfg.head_dim), dtype),
            # per-row slot positions: rows advance independently under
            # continuous batching (every cache leaf leads with batch)
            "pos": jax.ShapeDtypeStruct((batch, S_c), jnp.int32),
        }

    out = []
    for kind, j, window in layer_plan(cfg):
        if kind in ("attn", "moe"):
            out.append(attn_spec(window))
        elif kind == "ssm":
            d_inner, H, P, N = ssm_mod.dims(cfg)[:4]
            G = cfg.ssm.n_groups
            W = cfg.ssm.conv_width
            out.append(ssm_mod.SsmState(
                conv_x=jax.ShapeDtypeStruct((batch, W - 1, d_inner), dtype),
                conv_bc=jax.ShapeDtypeStruct((batch, W - 1, 2 * G * N), dtype),
                state=jax.ShapeDtypeStruct((batch, H, P, N), jnp.float32),
            ))
        elif kind == "rec":
            lru = cfg.rglru.lru_width or cfg.d_model
            W = cfg.rglru.conv_width
            out.append(rec_mod.RecState(
                conv=jax.ShapeDtypeStruct((batch, W - 1, lru), dtype),
                h=jax.ShapeDtypeStruct((batch, lru), jnp.float32),
            ))
    if cfg.family == "vlm":
        S_mem = cfg.n_frontend_tokens
        for _ in range(n_cross_blocks(cfg)):
            out.append({
                "k": jax.ShapeDtypeStruct((batch, S_mem, cfg.n_kv, cfg.head_dim), dtype),
                "v": jax.ShapeDtypeStruct((batch, S_mem, cfg.n_kv, cfg.head_dim), dtype),
            })
    if cfg.family == "encdec":
        for _ in range(cfg.n_layers):
            out.append({
                "k": jax.ShapeDtypeStruct((batch, enc_len, cfg.n_kv, cfg.head_dim), dtype),
                "v": jax.ShapeDtypeStruct((batch, enc_len, cfg.n_kv, cfg.head_dim), dtype),
            })
    return out


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, enc_len: int = 0):
    """Zero-initialized serving cache. ``pos`` starts at -1 (invalid)."""
    specs = cache_specs(cfg, batch, max_seq, enc_len)
    out = []
    for spec in specs:
        c = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        if isinstance(c, dict) and "pos" in c:
            c["pos"] = jnp.full(c["pos"].shape, -1, jnp.int32)
        out.append(c)
    return out


def prefill(cfg: ArchConfig, params, batch: dict, cache, deltas=None):
    """Run the prompt through the model, filling caches.

    Returns (logits for the LAST position [B,V], cache).

    ``batch["positions"]`` ([B, S] int32, optional) overrides the default
    ``arange(S)``: the continuous-batching engine left-pads prompts to a
    length bucket and passes negative positions at pad slots, so one jit
    shape serves every prompt length in the bucket.
    """
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    memory = None
    if cfg.family == "encdec":
        memory = encode(cfg, params, batch["enc_feats"], deltas)
    elif cfg.family == "vlm":
        memory = batch["image_embeds"].astype(x.dtype)
    h, new_caches = _walk(cfg, params, x, positions, deltas=deltas, caches=cache,
                          memory=memory)
    logits = unembed(cfg, params, h[:, -1:], deltas)
    return logits[:, 0], new_caches


def prefill_chunk(cfg: ArchConfig, params, batch: dict, cache, deltas=None):
    """Consume one position-offset prompt chunk against an existing cache.

    The resumable middle of chunked prefill: ``batch["tokens"]`` [B, C]
    is a slice of the prompt, ``batch["positions"]`` [B, C] its absolute
    positions (cursor offset — NOT restarting at 0), and ``cache`` the
    row's cache as earlier chunks left it. No left-padding anywhere:
    attention layers ring-append the chunk's K/V and attend the whole
    ring (``_attn_block_chunk``), while ssm/rec mixers continue from
    their carried state exactly like the exact-bucket prefill path (the
    train-mode blocks already thread ``state=`` through). An optional
    ``batch["valid"]`` [B, C] bool marks real tokens when the engine
    right-pads the tail chunk to a fixed width (attention-only archs:
    one jit signature per chunk size; pad K/V writes are dropped, pad
    logits are garbage the caller ignores). Stateful mixers are never
    padded — the engine sends exact-length tail chunks instead.

    Returns (logits [B, C, V] for EVERY chunk position, new cache): the
    caller picks the last real position's logits from the final chunk
    for the first generated token; intermediate chunks' logits are
    compute-and-discard.
    """
    if cfg.family in ("encdec", "vlm"):
        raise ValueError(
            f"chunked prefill does not support family={cfg.family!r} "
            "(per-request encoder inputs); use the whole-prompt path")
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    h, new_caches = _walk(cfg, params, x, batch["positions"], deltas=deltas,
                          caches=cache, chunk=True,
                          chunk_valid=batch.get("valid"))
    return unembed(cfg, params, h, deltas), new_caches


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, deltas=None):
    """One decode step. tokens [B,1] int32; pos scalar int32 (all rows at
    the same position) or [B] int32 (per-slot positions, continuous
    batching — ``deltas`` may then be a slot-dispatched tree).

    Returns (logits [B,V], new cache).
    """
    x = embed_tokens(cfg, params, tokens)
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((1,), pos, jnp.int32)
    h, new_caches = _walk(cfg, params, x, positions, deltas=deltas, caches=cache,
                          memory=None, decode_pos=pos)
    logits = unembed(cfg, params, h, deltas)
    return logits[:, 0], new_caches
