"""Mixture-of-Experts FFN with capacity-based top-k dispatch.

TPU-idiomatic dense dispatch (GSPMD-style): tokens are scattered into a
fixed-capacity per-expert buffer ``[E, C, d]``, every expert runs one dense
einsum (MXU-friendly; experts sharded on the ``model``/EP axis), and results
are gathered back with the router weights. Overflowing assignments are
dropped (standard capacity-factor semantics).

Expert weights are stacked ``[E, d_in, d_out]`` — the DeltaDQ pipeline
compresses them per expert through the same PackedDelta machinery (the
stacked leading dim is carried through pack/reconstruct).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.core.apply import apply_linear_batched, dget


def router_topk(logits: jnp.ndarray, top_k: int):
    """logits [T, E] -> (weights [T, K], idx [T, K]); softmax over the top-k."""
    gates, idx = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return weights, idx


def moe_ffn(x: jnp.ndarray, p: dict, d: Optional[dict], cfg: ArchConfig,
            capacity_factor: Optional[float] = None) -> jnp.ndarray:
    """x [B,S,d_model] -> [B,S,d_model]."""
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, dm = x.shape
    T, E, K = B * S, m.n_experts, m.top_k
    xt = x.reshape(T, dm)

    logits = xt @ p["router"]                       # router stays uncompressed
    weights, eidx = router_topk(logits, K)          # [T,K]

    C = max(int(T * K / E * capacity_factor), 1)

    flat_e = eidx.reshape(-1)                       # [T*K]
    # position-in-expert via sort, O(TK log TK) time and O(TK) memory.
    # (The textbook one-hot cumsum is O(TK*E) memory and is counted as
    # O((TK)^2)-ish flops by XLA's reduce-window model — see EXPERIMENTS.md
    # §Perf iteration P1.)
    order = jnp.argsort(flat_e)                     # stable
    inv = jnp.argsort(order)                        # rank of each assignment
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E))  # first slot per expert
    pos = inv - first[flat_e]                       # position within expert run
    keep = pos < C
    slot_e = jnp.where(keep, flat_e, E)             # overflow -> dummy expert E
    slot_c = jnp.where(keep, pos, 0)

    tok_of_assign = jnp.repeat(jnp.arange(T), K)    # [T*K]
    buf = jnp.zeros((E + 1, C, dm), x.dtype)
    buf = buf.at[slot_e, slot_c].set(xt[tok_of_assign])
    buf = buf[:E]                                   # [E, C, dm]

    gate = apply_linear_batched(buf, p["wg"], dget(d, "wg"))
    up = apply_linear_batched(buf, p["wi"], dget(d, "wi"))
    act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
    out = apply_linear_batched(act * up, p["wo"], dget(d, "wo"))  # [E, C, dm]

    # gather back: assignment (t, k) reads out[e, c]
    out_pad = jnp.concatenate([out, jnp.zeros((1, C, dm), out.dtype)], axis=0)
    per_assign = out_pad[slot_e, slot_c]            # [T*K, dm] (dropped -> expert E row? no:)
    per_assign = jnp.where(keep[:, None], per_assign, 0.0)
    w_assign = weights.reshape(-1)[:, None].astype(per_assign.dtype)
    y = jnp.zeros((T, dm), per_assign.dtype).at[tok_of_assign].add(per_assign * w_assign)

    if m.shared_expert:
        from repro.models.layers import glu_mlp
        y = y + glu_mlp(xt, p["shared"], dget(d, "shared"), cfg.act)
    return y.reshape(B, S, dm)


def aux_load_balance_loss(logits: jnp.ndarray, eidx: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (training)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac_routed = jnp.mean(jax.nn.one_hot(eidx[:, 0], n_experts), axis=0)
    frac_prob = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_routed * frac_prob)
