"""Shared neural-net layers for the model zoo (pure JAX, no flax).

All matmuls route through :func:`repro.core.apply.apply_linear` so every
linear site supports the paper's separate-computation delta correction.
Attention is q-blocked (flash-attention-lite at the XLA level) so 32k+
prefill never materializes a full [S, S] score tensor per head.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.apply import apply_linear

_NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x [..., S, H, D]; positions [S] or [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., :, None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def head_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """QK-norm: RMSNorm over head_dim. x [..., H, D], scale [D]."""
    return rmsnorm(x, scale, eps)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def _attend(q, k, v, q_pos, k_pos, window, causal, cap):
    """One q-block of GQA attention.

    q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D]; q_pos [Sq] or [B,Sq]; k_pos [Sk] or
    [B,Sk] (entries < 0 are invalid ring-buffer slots — 2-D forms carry
    per-row positions for continuous-batching slots); window: 0 = global,
    >0 = sliding window (may be a traced scalar).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (D ** -0.5)
    scores = softcap(scores, cap)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]       # [B*, Sq]
    kp = k_pos if k_pos.ndim == 2 else k_pos[None]       # [B*, Sk]
    valid = (kp >= 0)[:, None, :]
    if causal:
        valid = valid & (kp[:, None, :] <= qp[:, :, None])
    window = jnp.asarray(window)
    in_window = jnp.where(window > 0, qp[:, :, None] - kp[:, None, :] < window, True)
    valid = valid & in_window                             # [B*, Sq, Sk]
    scores = jnp.where(valid[:, None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(q, k, v, q_pos, k_pos, *, window=0, causal=True, cap=None,
              block_q: int = 1024):
    """GQA attention, blocked over the query dim to bound live memory."""
    Sq = q.shape[1]
    if Sq <= block_q or Sq % block_q:
        return _attend(q, k, v, q_pos, k_pos, window, causal, cap)
    nb = Sq // block_q
    qb = q.reshape(q.shape[0], nb, block_q, *q.shape[2:]).swapaxes(0, 1)
    if q_pos.ndim == 2:
        pb = q_pos.reshape(q_pos.shape[0], nb, block_q).swapaxes(0, 1)
    else:
        pb = q_pos.reshape(nb, block_q)

    def body(_, qp):
        qi, pi = qp
        return None, _attend(qi, k, v, pi, k_pos, window, causal, cap)

    _, out = jax.lax.scan(body, None, (qb, pb))
    return out.swapaxes(0, 1).reshape(q.shape)


def cross_attention(q, k, v, cap=None):
    """Unmasked attention over a fixed memory (frontend embeddings)."""
    Sk = k.shape[1]
    k_pos = jnp.zeros((Sk,), jnp.int32)
    q_pos = jnp.zeros((q.shape[1],), jnp.int32)
    return _attend(q, k, v, q_pos, k_pos, jnp.int32(0), False, cap)


# ---------------------------------------------------------------------------
# Blocks' inner projections
# ---------------------------------------------------------------------------
def qkv_project(x, p, d, cfg, positions, rope_on=True):
    """x [B,S,d_model] -> q [B,S,Hq,D], k,v [B,S,Hkv,D] (+rope, +qk-norm)."""
    from repro.core.apply import dget
    B, S, _ = x.shape
    q = apply_linear(x, p["wq"], dget(d, "wq")).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = apply_linear(x, p["wk"], dget(d, "wk")).reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = apply_linear(x, p["wv"], dget(d, "wv")).reshape(B, S, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def glu_mlp(x, p, d, act: str):
    """SwiGLU (silu) / GeGLU (gelu) feed-forward."""
    from repro.core.apply import dget
    gate = apply_linear(x, p["wg"], dget(d, "wg"))
    up = apply_linear(x, p["wi"], dget(d, "wi"))
    h = (jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)) * up
    return apply_linear(h, p["wo"], dget(d, "wo"))


def depthwise_conv1d(x, w, state=None):
    """Causal depthwise conv. x [B,S,C], w [W,C]; state [B,W-1,C] or None.

    Returns (y [B,S,C], new_state [B,W-1,C]).
    """
    W = w.shape[0]
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # [B, S+W-1, C]
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return y.astype(x.dtype), new_state
