from repro.models import lm
from repro.models.lm import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_axes,
    param_specs,
    prefill,
)

__all__ = [
    "lm",
    "cache_specs",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "param_axes",
    "param_specs",
    "prefill",
]
