"""RG-LRU recurrent mixer (RecurrentGemma / Griffin).

    r_t = sigmoid(a_gate(x_t));  i_t = sigmoid(i_gate(x_t))
    a_t = exp(-c * r_t * softplus(-Lambda))        (a = sigmoid(Lambda)^(c r))
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mixing uses ``jax.lax.associative_scan`` (log-depth); decode is a
single fused step. Gates are per-channel (diagonal) as in the Griffin
block-diagonal limit; the three 2-D projections (linear_x/y/out) are the
DeltaDQ-compressible weights of this block.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.core.apply import apply_linear, dget
from repro.models.layers import depthwise_conv1d, rmsnorm

_C = 8.0


class RecState(NamedTuple):
    conv: jnp.ndarray   # [B, W-1, lru]
    h: jnp.ndarray      # [B, lru]


def _gates(xb, p):
    r = jax.nn.sigmoid(xb * p["a_gate_w"].astype(jnp.float32) + p["a_gate_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(xb * p["i_gate_w"].astype(jnp.float32) + p["i_gate_b"].astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-p["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb)
    return a, gated_in


def rglru_scan(xb: jnp.ndarray, p: dict,
               h0: Optional[jnp.ndarray]) -> tuple[jnp.ndarray, jnp.ndarray]:
    """xb [B,S,lru] (f32) -> (h [B,S,lru], h_last [B,lru])."""
    a, b = _gates(xb, p)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_block(x, p, d, cfg: ArchConfig, state: Optional[RecState] = None,
                decode: bool = False):
    """Full recurrent block: conv + gated RG-LRU + output projection.

    x [B,S,d_model] (pre-norm applied by caller is NOT assumed; this block
    normalizes internally like the attention blocks). Returns (out, state).
    """
    B, S, _ = x.shape
    lru = cfg.rglru.lru_width or cfg.d_model
    u = rmsnorm(x, p["norm"], cfg.norm_eps)
    xb = apply_linear(u, p["linear_x"], dget(d, "linear_x"))
    yb = jax.nn.gelu(apply_linear(u, p["linear_y"], dget(d, "linear_y")).astype(jnp.float32))

    conv_state = state.conv if state is not None else None
    xb, new_conv = depthwise_conv1d(xb, p["conv_w"], conv_state)
    xb = (xb + p["conv_b"]).astype(jnp.float32)

    if decode:
        # deltalint: allow[DL003] traced-body shape invariant: decode is
        # S=1 by construction; S is static at trace time
        assert S == 1
        h0 = state.h if state is not None else jnp.zeros((B, lru), jnp.float32)
        a, b = _gates(xb[:, 0], p)
        h_last = a * h0.astype(jnp.float32) + b
        h = h_last[:, None]
    else:
        h0 = state.h if state is not None else None
        h, h_last = rglru_scan(xb, p, h0)

    out = (h * yb).astype(x.dtype)
    out = apply_linear(out, p["linear_out"], dget(d, "linear_out"))
    # conv ring lives in the cache-spec dtype (prefill activations may be
    # f32): serving slots must be bit-identical however the row was filled
    return out, RecState(new_conv.astype(jnp.dtype(cfg.param_dtype)),
                         h_last.astype(jnp.float32))
