"""Mamba-2 SSD (state-space duality) mixer, chunked TPU-friendly form.

The chunked algorithm (Dao & Gu 2024, §6): within chunks of length Q the
recurrence is computed as a masked quadratic attention-like matmul (MXU);
across chunks a tiny state recurrence [H, P, N] is scanned. Both decode
(O(1) state update per token) and train/prefill paths are provided.

Projections are split into separate matrices (wz/wx/wB/wC/wdt) rather than
one fused in_proj so tensor-parallel sharding can put heads on the model
axis without slicing through semantic boundaries (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.core.apply import apply_linear, dget
from repro.models.layers import depthwise_conv1d, rmsnorm


class SsmState(NamedTuple):
    conv_x: jnp.ndarray    # [B, W-1, d_inner]
    conv_bc: jnp.ndarray   # [B, W-1, 2*G*N]
    state: jnp.ndarray     # [B, H, P, N]


def dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.d_state, s.n_groups


def _segsum_mask(dA_cum: jnp.ndarray) -> jnp.ndarray:
    """L[..., i, j] = exp(dA_cum_i - dA_cum_j) for j <= i else 0.

    dA_cum [..., l, h] -> [..., h, l, l]
    """
    c = jnp.moveaxis(dA_cum, -1, -2)                       # [..., h, l]
    diff = c[..., :, None] - c[..., None, :]               # [..., h, i, j]
    l = c.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Full-sequence SSD.

    x [b,s,h,p]; dt [b,s,h] (post-softplus); A [h] (negative);
    B, C [b,s,g,n].  Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[-2:]
    hpg = h // g
    if s % chunk:
        raise ValueError(
            f"sequence length {s} must be a multiple of chunk={chunk}")
    nc = s // chunk
    xr = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtr = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    dA = dtr * A.astype(jnp.float32)                       # [b,nc,l,h]
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (quadratic within chunk, MXU) ---
    L = _segsum_mask(dA_cum)                               # [b,nc,h,l,l]
    # scores over shared B/C groups; expand group to its heads
    CB = jnp.einsum("bclgn,bcmgn->bcglm", Cr, Br)          # [b,nc,g,l,m]
    CB = jnp.repeat(CB, hpg, axis=2)                       # [b,nc,h,l,m]
    att = CB * L * jnp.moveaxis(dtr, -1, -2)[..., None, :]  # * dt_j
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", att, xr)

    # --- chunk states ---
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b,nc,l,h]
    weighted_x = xr * (dtr * decay_to_end)[..., None]      # [b,nc,l,h,p]
    Bh = jnp.repeat(Br, hpg, axis=3)                       # [b,nc,l,h,n]
    chunk_states = jnp.einsum("bclhp,bclhn->bchpn", weighted_x, Bh)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # [b,nc,h]

    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, cd = inp
        new = carry * cd[..., None, None] + st
        return new, carry                                   # emit state at chunk START

    final, states_before = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    states_before = jnp.moveaxis(states_before, 0, 1)       # [b,nc,h,p,n]

    # --- inter-chunk contribution ---
    Ch = jnp.repeat(Cr, hpg, axis=3)                        # [b,nc,l,h,n]
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Ch * jnp.exp(dA_cum)[..., None], states_before)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode(x, dt, A, B, C, state):
    """One-token SSD update. x [b,h,p]; dt [b,h]; B,C [b,g,n]; state [b,h,p,n]."""
    g = B.shape[-2]
    hpg = x.shape[1] // g
    Bh = jnp.repeat(B, hpg, axis=1).astype(jnp.float32)     # [b,h,n]
    Ch = jnp.repeat(C, hpg, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # [b,h]
    upd = (dt.astype(jnp.float32) * 1.0)[..., None, None] * \
          x.astype(jnp.float32)[..., None] * Bh[..., None, :]     # [b,h,p,n]
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


def mamba_block(x, p, d, cfg: ArchConfig, state: Optional[SsmState] = None,
                decode: bool = False):
    """Full Mamba-2 block. x [B,S,d_model] (S=1 when decode=True).

    Returns (out [B,S,d_model], new_state).
    """
    s_cfg = cfg.ssm
    d_inner, H, P, N, G = *dims(cfg)[:4], cfg.ssm.n_groups
    B_, S, _ = x.shape

    u = rmsnorm(x, p["norm"], cfg.norm_eps)
    z = apply_linear(u, p["wz"], dget(d, "wz"))
    xin = apply_linear(u, p["wx"], dget(d, "wx"))
    bc = apply_linear(u, p["wbc"], dget(d, "wbc"))          # [B,S,2*G*N]
    dt = apply_linear(u, p["wdt"], dget(d, "wdt"))          # [B,S,H]

    conv_x_state = state.conv_x if state is not None else None
    conv_bc_state = state.conv_bc if state is not None else None
    xin, new_conv_x = depthwise_conv1d(xin, p["conv_x_w"], conv_x_state)
    bc, new_conv_bc = depthwise_conv1d(bc, p["conv_bc_w"], conv_bc_state)
    xin = jax.nn.silu(xin + p["conv_x_b"])
    bc = jax.nn.silu(bc + p["conv_bc_b"])

    Bmat = bc[..., : G * N].reshape(B_, S, G, N)
    Cmat = bc[..., G * N:].reshape(B_, S, G, N)
    xh = xin.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    if decode:
        # deltalint: allow[DL003] traced-body shape invariant: decode is
        # S=1 by construction; S is static at trace time
        assert S == 1
        prev = state.state if state is not None else jnp.zeros((B_, H, P, N), jnp.float32)
        y, new_state = ssd_decode(xh[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0], prev)
        y = y[:, None]
    else:
        init = state.state if state is not None else None
        y, new_state = ssd_chunked(xh, dt.astype(xh.dtype), A, Bmat, Cmat,
                                   min(s_cfg.chunk, S), initial_state=init)

    y = y + xh.astype(jnp.float32)[...] * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["out_norm"], cfg.norm_eps)
    out = apply_linear(y, p["wout"], dget(d, "wout"))
    # conv rings live in the cache-spec dtype (prefill activations may be
    # f32): serving slots must be bit-identical however the row was filled
    cdt = jnp.dtype(cfg.param_dtype)
    return out, SsmState(new_conv_x.astype(cdt), new_conv_bc.astype(cdt), new_state)
