from repro.data.pipeline import (
    FormatOnlyTask,
    MemmapTokens,
    PretrainMixture,
    SortTask,
    SyntheticLM,
)

__all__ = ["FormatOnlyTask", "MemmapTokens", "PretrainMixture", "SortTask", "SyntheticLM"]
