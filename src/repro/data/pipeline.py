"""Deterministic, resumable data pipeline.

Every batch is a pure function of (seed, step): a restarted or elastic-
rescaled worker reproduces exactly the stream it would have seen, which is
the straggler/fault-tolerance fencing mechanism (no torn batches, no
skipped/duplicated data after restore). Three sources:

* ``SyntheticLM``    — random tokens (throughput + dry-run shapes)
* ``MemmapTokens``   — binary token file, strided windows (real corpora)
* ``TaskMixture``    — the synthetic SFT task used by the paper-fidelity
                       benchmarks: prompts of digits, target = sorted digits
                       (an exact-match-scoreable "downstream task" so the
                       Table 1/2/3 reproductions have a real accuracy axis)
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq_len), dtype=np.int32)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class MemmapTokens:
    """Strided windows over a flat binary int32 token file."""
    path: str
    seq_len: int
    batch: int
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._n_windows = max(len(self._data) - self.seq_len - 1, 1)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        starts = rng.integers(0, self._n_windows, self.batch)
        toks = np.stack([self._data[s:s + self.seq_len] for s in starts]).astype(np.int32)
        labels = np.stack([self._data[s + 1:s + self.seq_len + 1] for s in starts]).astype(np.int32)
        return {"tokens": toks, "labels": labels}


# ---------------------------------------------------------------------------
# Synthetic SFT task: sort digit sequences.
# vocab layout: 0..9 digits, 10 = SEP, 11 = EOS, 12 = PAD, 13+ = filler noise
# ---------------------------------------------------------------------------
SEP, EOS, PAD = 10, 11, 12


@dataclasses.dataclass
class SortTask:
    """Prompt: d_1..d_n SEP ; completion: sorted(d) EOS. Exact-match scoreable."""
    vocab: int
    seq_len: int
    batch: int
    n_digits: int = 8
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, 7]))
        n = self.n_digits
        B, S = self.batch, self.seq_len
        if S < 2 * n + 2:
            raise ValueError(
                f"seq_len={S} too short for n_digits={n} addition prompts "
                f"(needs >= {2 * n + 2})")
        toks = np.full((B, S), PAD, np.int32)
        labels = np.full((B, S), PAD, np.int32)
        mask = np.zeros((B, S), np.float32)
        for b in range(B):
            d = rng.integers(0, 10, n)
            seq = np.concatenate([d, [SEP], np.sort(d), [EOS]])
            toks[b, :len(seq)] = seq
            labels[b, :len(seq) - 1] = seq[1:]
            mask[b, n:len(seq) - 1] = 1.0   # loss only on the completion
        return {"tokens": toks, "labels": labels, "loss_mask": mask}

    def prompts_at(self, step: int):
        """(prompt tokens [B, n+1], target digits [B, n]) for generation eval."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, 7]))
        n = self.n_digits
        prompts, targets = [], []
        for b in range(self.batch):
            d = rng.integers(0, 10, n)
            prompts.append(np.concatenate([d, [SEP]]))
            targets.append(np.sort(d))
        return np.stack(prompts).astype(np.int32), np.stack(targets).astype(np.int32)


@dataclasses.dataclass
class FormatOnlyTask:
    """Sort-task FORMAT with random-permutation completions.

    Pretraining on this teaches the base model the prompt structure and
    token statistics but NOT the sorting skill — so the subsequent SFT
    delta is small (structure already known) yet decisive (the capability),
    matching the paper's setting where deltas are tiny relative to W_base.
    """
    vocab: int
    seq_len: int
    batch: int
    n_digits: int = 8
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, 11]))
        n, B, S = self.n_digits, self.batch, self.seq_len
        toks = np.full((B, S), PAD, np.int32)
        labels = np.full((B, S), PAD, np.int32)
        mask = np.zeros((B, S), np.float32)
        for b in range(B):
            d = rng.integers(0, 10, n)
            completion = rng.permutation(d)     # format yes, skill no
            seq = np.concatenate([d, [SEP], completion, [EOS]])
            toks[b, :len(seq)] = seq
            labels[b, :len(seq) - 1] = seq[1:]
            mask[b, n:len(seq) - 1] = 1.0
        return {"tokens": toks, "labels": labels, "loss_mask": mask}


@dataclasses.dataclass
class PretrainMixture:
    """Base-model data: mostly noise with a little task structure, so the
    base model is distinct from the fine-tuned one (delta is meaningful)."""
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, 3]))
        B, S = self.batch, self.seq_len
        # Markov-ish token stream: next token = (prev * a + b) % vocab with noise
        toks = np.zeros((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, B)
        a = 31
        for t in range(1, S):
            noise = rng.random(B) < 0.15
            nxt = (toks[:, t - 1] * a + 7) % self.vocab
            toks[:, t] = np.where(noise, rng.integers(0, self.vocab, B), nxt)
        return {"tokens": toks.astype(np.int32)}
