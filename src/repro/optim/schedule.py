"""LR schedules (multipliers over base LR)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(warmup: int, total: int, min_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant():
    return lambda step: jnp.float32(1.0)


def inverse_sqrt(warmup: int):
    def fn(step):
        step = jnp.maximum(step.astype(jnp.float32), 1.0)
        return jnp.minimum(step / jnp.maximum(warmup, 1), jnp.sqrt(warmup / step))
    return fn
