"""AdamW with decoupled weight decay, global-norm clipping and fp32 master
params — written from scratch (no optax in this environment).

State layout (a pytree mirroring params):
    {"m": .., "v": .., "master": fp32 params, "step": i32 scalar}

``update`` consumes grads in param dtype, runs moments in fp32, applies the
schedule, and casts back. Master fp32 params make bf16 training stable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable] = None   # step -> multiplier


def init(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs: Any) -> dict:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, param_specs),
        "v": jax.tree.map(f32, param_specs),
        "master": jax.tree.map(f32, param_specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def _decay_mask(path: str) -> float:
    """No weight decay on norms / biases / 1-D params (by convention)."""
    toks = path.lower()
    if any(t in toks for t in ("norm", "ln", "bias", "scale", "a_param", "gate")):
        return 0.0
    return 1.0


def update(cfg: AdamWConfig, params: Any, grads: Any, state: dict):
    """Returns (new_params, new_state, metrics)."""
    from repro.utils import map_with_paths

    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    mult = cfg.schedule(step) if cfg.schedule is not None else 1.0
    lr = cfg.lr * mult
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], grads)

    def upd(path, master, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * _decay_mask(path) * master
        return master - lr * delta

    new_master = map_with_paths(upd, state["master"], new_m, new_v)
    new_params = jax.tree.map(lambda p, mst: mst.astype(p.dtype), params, new_master)
    new_state = {"m": new_m, "v": new_v, "master": new_master, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
