"""Pure-XLA delta-correction formulations (the non-Pallas hot path).

On hosts without a TPU (CPU CI, the bench host) the delta correction is
plain XLA, and its formulation dominates the decode-path overhead. Two
mathematically identical formulations with opposite scaling:

* :func:`dense_correction` — scatter the packed delta to a dense
  ``[h_in, h_out]`` matrix, then one dense matmul. The scatter cost is
  paid once regardless of T, so it wins for prefill-sized token counts.
* :func:`gather_correction` — never materialize the dense delta: gather
  each kept element's activation by its (flattened) index and contract
  against the dequantized values directly
  (``y[t,o] = sum_{g,k} x[t, g*h_g + idx[g,k,o]] * val[g,k,o]``).
  Work is ``T * nnz`` instead of ``nnz`` scatter + ``T * h_in * h_out``
  matmul — at decode shapes (T = a handful of slots) this is 5-20x
  faster and is what collapses the serve-time delta overhead.

:func:`correction` picks between them by token count; the crossover is
the autotuned ``gather_max_t`` (kernels/autotune.py).

Mixed-tenant decode adds two more:

* :func:`gather_correction_rows` — per-row deltas (a row-gathered
  ``[B]`` stack): the same gather contraction with per-row values. This
  replaces the old ``[B, h_in, h_out]`` dense reconstruction, whose
  memory blew up B-fold even when every row shared one tenant.
* :func:`segment_correction` — the unique-tenant dispatch: rows sorted
  by tenant, a scan over (statically shaped, possibly empty) tenant
  segments that dequantizes each *unique* delta once and applies it to
  the whole batch with rows outside the segment masked. The per-segment
  contraction is the exact same ``gather_correction`` primitive the
  single-tenant path uses, which keeps mixed-stream decode bit-identical
  to the per-tenant reference engine.

Bit-identity note: the gather contraction is written as an elementwise
multiply followed by ``sum`` over one merged (group, keep) axis — NOT a
dot_general/einsum — because XLA's dot reduction order varies with the
batch extent, while the reduce op's per-(row, column) inner loop does
not. The token-identity contract (mixed-slot decode == per-tenant
reference decode, exact) depends on this: the same row correction must
produce the same bits whether the row is decoded alone, in a tenant
group, or in a mixed slot batch.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.pack import PackedDelta, decode_values, reconstruct_dense


def _note(site: str, **attrs) -> None:
    """Report the chosen formulation to an open trace context (no-op
    otherwise). Lazy import: the serve package's __init__ imports the
    engine, which imports this module."""
    from repro.serve.trace import note_path
    note_path(site, **attrs)


def _flat_gather_idx(d: PackedDelta, idx: jnp.ndarray) -> jnp.ndarray:
    """Local in-group indices [..., G, K, O] -> flat h_in indices."""
    G = d.n_groups
    base = (jnp.arange(G, dtype=jnp.int32) * d.h_g)[:, None, None]
    return idx.astype(jnp.int32) + base


def dense_correction(x2: jnp.ndarray, d: PackedDelta) -> jnp.ndarray:
    """x2 [T, h_in] @ dense(delta) -> [T, h_out] f32 (reconstruct path)."""
    return x2.astype(jnp.float32) @ reconstruct_dense(d)


def gather_correction(x2: jnp.ndarray, d: PackedDelta) -> jnp.ndarray:
    """x2 [T, h_in] -> [T, h_out] f32 without materializing the dense delta."""
    vals = decode_values(d)                          # [G, K, O] f32
    G, K, O = vals.shape
    gidx = _flat_gather_idx(d, d.idx).reshape(-1)    # [G*K*O]
    sel = x2.astype(jnp.float32)[:, gidx].reshape(x2.shape[0], G * K, O)
    # multiply + axis-sum (not einsum): batch-extent-stable bits, see above
    return (sel * vals.reshape(G * K, O)[None]).sum(axis=1)


def correction(x2: jnp.ndarray, d: PackedDelta, *,
               gather_max_t: int = 64) -> jnp.ndarray:
    """Formulation chooser: gather for decode-sized T, dense otherwise."""
    if x2.shape[0] <= gather_max_t:
        _note("correction", formulation="xla-gather", codec=d.codec,
              T=int(x2.shape[0]), gather_max_t=int(gather_max_t))
        return gather_correction(x2, d)
    _note("correction", formulation="xla-dense", codec=d.codec,
          T=int(x2.shape[0]), gather_max_t=int(gather_max_t))
    return dense_correction(x2, d)


def correction_nd(x: jnp.ndarray, d: PackedDelta, *,
                  gather_max_t: Optional[int] = None) -> jnp.ndarray:
    """x [..., h_in] -> [..., h_out] f32: flatten leading dims, choose the
    formulation, restore shape.

    The ONE entry point for every XLA-fallback correction site
    (replicated apply path, out-of-envelope ops path, sharded shard_map
    body) — the token-identity contract requires all of them to choose
    the same formulation with the same autotune key, so the lookup lives
    here. Pass ``gather_max_t`` to pin the decision externally (the
    sharded path decides on the GLOBAL envelope, then applies it to the
    local column slice).
    """
    if gather_max_t is None:
        from repro.kernels import autotune
        gather_max_t = autotune.lookup(
            d.h_g, d.keep, d.k_bits, d.h_in, d.h_out,
            t=x.size // x.shape[-1])["gather_max_t"]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    y = correction(x2, d, gather_max_t=gather_max_t)
    return y.reshape(*lead, d.h_out)


def _rows_core(x_rows: jnp.ndarray, gidx: jnp.ndarray,
               vals: jnp.ndarray) -> jnp.ndarray:
    """Shared per-row contraction: x_rows [N, h_in], gidx [N, G*K*O] flat
    h_in indices, vals [N, G*K, O] -> [N, O] f32.

    Every per-row path (row-gathered stack, segment dispatch) funnels
    through this one function so the gather + reduce shapes — and
    therefore the bits — are identical across dispatch modes.
    """
    N = x_rows.shape[0]
    GK, O = vals.shape[1], vals.shape[2]
    sel = jnp.take_along_axis(x_rows.astype(jnp.float32), gidx, axis=1)
    sel = sel.reshape(N, GK, O)
    return (sel * vals).sum(axis=1)


def gather_correction_rows(x: jnp.ndarray, d: PackedDelta,
                           values: Optional[jnp.ndarray] = None
                           ) -> jnp.ndarray:
    """Per-row deltas: x [B, ..., h_in], d row-stacked [B] -> [B, ..., h_out].

    Peak extra memory is ``B * nnz`` floats (the gathered activations),
    not ``B * h_in * h_out`` — rows sharing a tenant no longer multiply a
    dense reconstruction.

    ``values`` (optional f32 [B, G, K, O]) supplies pre-decoded kept
    values and skips the in-graph code unpack — the residency fast
    path. The decode is elementwise (``(q - z) * s`` after a bit
    unpack), so values decoded ahead of time are bit-identical to
    values decoded in-step, and the contraction below is unchanged —
    which is what lets the residency tier keep the token-identity
    contract.
    """
    B = x.shape[0]
    vals = decode_values(d) if values is None else values   # [B, G, K, O]
    _, G, K, O = vals.shape
    gidx = _flat_gather_idx(d, d.idx)                # [B, G, K, O]
    x2 = x.astype(jnp.float32).reshape(B, -1, d.h_in)
    T = x2.shape[1]
    # flatten (row, token) so the reduce shape matches gather_correction's
    # [rows, G*K, O] exactly — same bits as the shared-tenant path
    x_rows = x2.reshape(B * T, d.h_in)
    gidx_rows = jnp.broadcast_to(
        gidx.reshape(B, 1, G * K * O), (B, T, G * K * O)).reshape(B * T, -1)
    vals_rows = jnp.broadcast_to(
        vals.reshape(B, 1, G * K, O), (B, T, G * K, O)).reshape(B * T, G * K, O)
    y = _rows_core(x_rows, gidx_rows, vals_rows)
    return y.reshape(*x.shape[:-1], d.h_out)


def segment_correction(x2: jnp.ndarray, d: PackedDelta,
                       seg_rows: jnp.ndarray,
                       seg_offsets: jnp.ndarray,
                       values: Optional[jnp.ndarray] = None,
                       res_map: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Unique-tenant dispatch: x2 [T, h_in] rows sorted by tenant.

    ``d`` is the tenant-stacked packed delta [R, ...]; ``seg_rows`` [S]
    maps segment -> tenant row and ``seg_offsets`` [S+1] gives each
    segment's half-open row range (S is a static shape — padding
    segments are empty). The packed (still-compressed) bytes are routed
    to rows through the segment map and contracted by the same
    :func:`_rows_core` the per-row path uses — identical gather/reduce
    shapes, identical bits.

    ``values``/``res_map`` (optional) select the pre-decoded residency
    tier: ``values`` f32 [C, G, K, O] holds decoded kept values for C
    resident tenant rows and ``res_map`` int32 [R] maps tenant row ->
    residency row. The per-step code unpack is skipped entirely — the
    dequant happened once at promotion time with the same elementwise
    math, so the bits entering :func:`_rows_core` are unchanged (the
    residency tier preserves the token-identity contract).

    Note on CPU economics: XLA has no cross-row tile reuse, so the
    unique-tenant *compute* dedup does not pay here on the packed path —
    gathering f32 dequantized values per unique tenant costs more than
    re-unpacking the (8x smaller) packed codes per row. This fallback
    therefore matches the per-row path's work; the genuine dedup lives
    in (a) the Pallas segments kernel, which decodes each [h_g, Ob]
    VMEM tile once per segment instead of once per row (gated by
    kernel_bench), and (b) the residency values path above, which
    removes the unpack from the step altogether.
    """
    T = x2.shape[0]
    _note("segment_correction", formulation="segments-xla", codec=d.codec,
          residency="values" if values is not None else "packed", T=int(T))
    # map each (sorted) row to its segment: count of segment ends <= row
    rows_iota = jnp.arange(T, dtype=jnp.int32)
    row_seg = (rows_iota[:, None] >= seg_offsets[None, 1:]).sum(axis=1)
    tenant_rows = seg_rows[row_seg]                  # [T]
    dl = PackedDelta(
        d.idx[tenant_rows], d.codes[tenant_rows],
        jnp.asarray(d.scale, jnp.float32)[tenant_rows],
        jnp.asarray(d.zero, jnp.int32)[tenant_rows],
        d.h_in, d.h_out, d.h_g, d.keep, d.alpha, d.k_bits, d.m, d.codec)
    vals = None
    if values is not None:
        vals = values[res_map[tenant_rows]]          # [T, G, K, O] f32
    return gather_correction_rows(x2[:, None, :], dl, values=vals)[:, 0]
