from repro.kernels import ops, ref
