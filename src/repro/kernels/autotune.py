"""Autotuned tile/formulation table for the delta-correction hot path.

The kernels expose three knobs — ``tb`` (token tile), ``ob`` (output
tile), ``kc`` (scatter chunk) — and the XLA fallback exposes one more:
``gather_max_t``, the token count below which the gather formulation
(kernels/fallback.py) beats dense reconstruction. The best values depend
on the packing envelope point ``(h_g, keep, k_bits, h_out)`` and on the
backend, so they are swept offline and persisted:

    PYTHONPATH=src python -m repro.kernels.autotune --out results/autotune_kernels.json

The best values depend on the packing envelope point
``(h_g, keep, k_bits, h_in, h_out)`` — ``h_in`` is part of the key
because the gather/dense crossover scales with the contraction width,
not just the packing spec. ``kernels.ops`` consults :func:`lookup`
whenever a caller does not pin the tiles explicitly. A missing table
(or a missing envelope point) falls back to :data:`DEFAULTS`, so the
table is an optimization, never a correctness dependency.

Since v3 the key additionally carries the **token count T** as an
overlay: chunked prefill drives the correction at chunk-sized T (e.g.
16 or the combined decode+chunk row count), and BENCH_kernels.json
shows the gather/dense crossover — and the best kernel tiles — moving
with T, so a prefill-sized call must not inherit decode tiles.
``lookup(..., t=T)`` merges ``DEFAULTS <- base entry <- "@T" entry``
where the T entry's key suffix is the :data:`T_GRID` bucket T snaps to
(:func:`snap_t`). Base entries keep the swept ``gather_max_t``
crossover (the formulation decision stays ONE monotone threshold — the
identity contract's guarantee that a row computes the same bits at any
batch size); T entries overlay per-T tiles (TPU) and record the
measured per-T formulation + timings (CPU), which is what kernel_bench
reports. v2 tables (no ``@T`` entries) still load: the overlay is
simply empty. Table format (JSON)::

    {"version": 3, "backend": "cpu",
     "entries": {"64/8/4/128/256": {"tb": 128, "ob": 128, "kc": 8,
                                    "gather_max_t": 64},
                 "64/8/4/128/256@T16": {"formulation": "gather",
                                        "gather_us": 8.1,
                                        "dense_us": 55.0}}}

``gather_max_t`` is floored at :data:`MIN_GATHER_T`: the segment
dispatch always uses the gather formulation, so the per-tenant
reference path must pick gather for every decode-sized batch too or the
exact token-identity contract breaks — and gather won every measured
envelope point at T <= 32 by >=3x anyway.

On CPU hosts the Pallas kernels only run in interpret mode (validation,
not perf), so the sweep measures the XLA-fallback crossover; on TPU it
additionally times the compiled kernels across the (tb, ob, kc)
candidate grid. Set ``REPRO_AUTOTUNE_TABLE`` to point ops at a
non-default table path.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

DEFAULTS = {"tb": 128, "ob": 128, "kc": 8, "gather_max_t": 64}

# floor for the stored gather/dense crossover: decode batches (n_slots)
# must take the gather formulation on the per-tenant reference path
# because the mixed-slot segment dispatch always does (bit-identity)
MIN_GATHER_T = 32

# candidate grids for the sweep (kept small: the table is per envelope
# point and the envelope has few operating points per deployment)
TB_CANDIDATES = (32, 64, 128, 256)
OB_CANDIDATES = (64, 128, 256)
KC_CANDIDATES = (4, 8, 16)
T_GRID = (1, 4, 8, 16, 32, 64, 128, 256)

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_TABLE_PATH = os.path.join(_REPO, "results", "autotune_kernels.json")

_cached_table: Optional[dict] = None
_cached_path: Optional[str] = None


def table_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_TABLE", DEFAULT_TABLE_PATH)


def snap_t(t: int) -> int:
    """Snap a token count to its :data:`T_GRID` bucket (smallest grid
    point >= t; counts past the grid share the largest bucket)."""
    for g in T_GRID:
        if t <= g:
            return g
    return T_GRID[-1]


def envelope_key(h_g: int, keep: int, k_bits: Optional[int], h_in: int,
                 h_out: int, t: Optional[int] = None) -> str:
    base = f"{h_g}/{keep}/{k_bits}/{h_in}/{h_out}"
    return base if t is None else f"{base}@T{snap_t(t)}"


def load_table(path: Optional[str] = None) -> dict:
    """Load (and cache) the persisted table; {} when absent/unreadable."""
    global _cached_table, _cached_path
    path = path or table_path()
    if _cached_table is not None and _cached_path == path:
        return _cached_table
    try:
        with open(path) as f:
            tab = json.load(f)
        entries = tab.get("entries", {})
    except (OSError, ValueError):
        entries = {}
    _cached_table, _cached_path = entries, path
    return entries


def invalidate_cache() -> None:
    global _cached_table, _cached_path
    _cached_table = _cached_path = None


def lookup(h_g: int, keep: int, k_bits: Optional[int], h_in: int,
           h_out: int, t: Optional[int] = None) -> dict:
    """Tile/formulation parameters for an envelope point (always complete:
    missing keys are filled from :data:`DEFAULTS`).

    ``t`` (the call's token count — static at trace time) overlays the
    per-T entry on top of the base entry: per-T tiles win where swept,
    everything else (notably ``gather_max_t``) comes from the base
    entry, so the formulation threshold stays one monotone crossover.
    """
    entries = load_table()
    key = envelope_key(h_g, keep, k_bits, h_in, h_out)
    got = {**DEFAULTS, **entries.get(key, {})}
    if t is not None:
        overlay = entries.get(envelope_key(h_g, keep, k_bits, h_in, h_out,
                                           t=t), {})
        got.update({k: v for k, v in overlay.items()
                    if k in ("tb", "ob", "kc")})
    # the identity floor survives any table contents (see module doc)
    got["gather_max_t"] = max(int(got["gather_max_t"]), MIN_GATHER_T)
    return got


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------
def _time(fn, *args, n: int = 30) -> float:
    import jax
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = jfn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _sweep_gather_max_t(p, rng) -> tuple:
    """Measure gather vs dense across :data:`T_GRID`.

    Returns ``(gather_max_t, overlays)``: the largest T where gather
    still wins (floored at MIN_GATHER_T, kept monotone — the first
    crossover freezes the threshold), plus the per-T ``@T`` overlay
    entries recording both timings and the formulation the runtime
    actually selects at that T under the threshold."""
    import jax
    from repro.kernels import fallback
    best = 0
    crossed = False
    timings = {}
    for T in T_GRID:
        x = jax.random.normal(rng, (T, p.h_in))
        us_gather = _time(lambda x: fallback.gather_correction(x, p), x)
        us_dense = _time(lambda x: fallback.dense_correction(x, p), x)
        timings[T] = (us_gather, us_dense)
        if not crossed and us_gather > us_dense:
            crossed = True
        if not crossed:
            best = T
    gmax = max(best, MIN_GATHER_T)
    overlays = {T: {"gather_us": round(ug, 2), "dense_us": round(ud, 2),
                    "formulation": "gather" if T <= gmax else "dense"}
                for T, (ug, ud) in timings.items()}
    return gmax, overlays


def _sweep_kernel_tiles(p, rng, T: int = 128) -> dict:
    """Best (tb, ob, kc) for the compiled Pallas kernel (TPU only)."""
    import jax
    from repro.kernels import ops
    x = jax.random.normal(rng, (T, p.h_in))
    # only the kernel-tile keys: returning gather_max_t here would
    # clobber the crossover the caller just measured
    best = {k: DEFAULTS[k] for k in ("tb", "ob", "kc")}
    best_us = float("inf")
    for tb in TB_CANDIDATES:
        for ob in OB_CANDIDATES:
            for kc in KC_CANDIDATES:
                try:
                    us = _time(lambda x: ops.delta_spmm(
                        x, p, tb=tb, ob=ob, kc=kc, interpret=False), x)
                except Exception:
                    continue
                if us < best_us:
                    best_us = us
                    best = {"tb": tb, "ob": ob, "kc": kc}
    return best


def sweep_point(h_g: int, keep: int, k_bits: Optional[int], h_in: int,
                h_out: int, *, seed: int = 0) -> tuple:
    """Measure one envelope point.

    Returns ``(base_entry, overlays)``: the base table entry plus the
    ``{T: entry}`` per-token-count overlay map (v3) — the overlay
    measurements come for free from the crossover sweep, which already
    walks :data:`T_GRID` (so chunk-sized T is always covered). On TPU
    each overlay additionally carries the (tb, ob, kc) swept at that T,
    so prefill-chunk-sized calls stop inheriting decode tiles.
    """
    import jax
    from repro.core import groupwise_dropout_pack
    alpha = max(1, h_g // max(keep, 1))
    rng = jax.random.PRNGKey(seed)
    delta = jax.random.normal(rng, (h_in, h_out)) * 0.01
    p = groupwise_dropout_pack(rng, delta, h_g=h_g, alpha=alpha, k_bits=k_bits)
    entry = dict(DEFAULTS)
    entry["gather_max_t"], overlays = _sweep_gather_max_t(p, rng)
    if jax.default_backend() == "tpu":
        entry.update(_sweep_kernel_tiles(p, rng))
        for T in T_GRID:
            overlays[T].update(_sweep_kernel_tiles(p, rng, T))
    return entry, overlays


# the envelope points the serving configs actually hit: the smoke config
# (d_model 64, d_ff 128) at the RATIO_SPECS h_g=16 packing, the bench
# arch (d_model 128, d_ff 256, heads 128/kv 64) at h_g=64, plus wider
# table-4 h_g* points
DEFAULT_POINTS = [
    (16, 2, 4, 64, 32),
    (16, 2, 4, 64, 64),
    (16, 2, 4, 64, 128),
    (16, 2, 4, 128, 64),
    (64, 8, 4, 128, 64),
    (64, 8, 4, 128, 128),
    (64, 8, 4, 128, 256),
    (64, 8, 4, 256, 128),
    (64, 8, 4, 512, 512),
    (128, 16, 4, 256, 256),
    (16, 2, None, 64, 64),
    (64, 8, 8, 128, 256),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_TABLE_PATH)
    ap.add_argument("--points", default=None,
                    help="comma-separated h_g/keep/k_bits/h_in/h_out keys "
                         "(default: the serving envelope points)")
    args = ap.parse_args()

    import jax
    points = DEFAULT_POINTS
    if args.points:
        points = []
        for key in args.points.split(","):
            h_g, keep, k_bits, h_in, h_out = key.split("/")
            points.append((int(h_g), int(keep),
                           None if k_bits == "None" else int(k_bits),
                           int(h_in), int(h_out)))

    entries = {}
    for (h_g, keep, k_bits, h_in, h_out) in points:
        key = envelope_key(h_g, keep, k_bits, h_in, h_out)
        entries[key], overlays = sweep_point(h_g, keep, k_bits, h_in, h_out)
        print(f"{key}: {entries[key]}")
        for T, ov in overlays.items():
            entries[envelope_key(h_g, keep, k_bits, h_in, h_out, t=T)] = ov

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"version": 3, "backend": jax.default_backend(),
                   "entries": entries}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}")
    invalidate_cache()


if __name__ == "__main__":
    main()
