"""Jit'd public wrappers around the Pallas kernels.

Handle envelope checks (tile divisibility, supported h_g/keep), input
prep (padding, scalar shaping) and the interpret-mode switch used for
CPU validation. Outside the kernel envelope the XLA fallback
(reconstruct-then-matmul) is used — mathematically identical.

Multi-device: :func:`delta_correction_sharded` partitions the packed
delta along its output-column axis over the mesh ``model`` axis with
``shard_map``, so each shard dequantizes only its h_out/n columns —
the kernel's compressed-bytes-only HBM traffic is preserved per shard
and the correction needs no collectives (x is replicated at decode
batch sizes; each output column is produced by exactly one shard).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.pack import PackedDelta, reconstruct_dense
from repro.kernels import delta_spmm as _k

# CPU containers run kernels in interpret mode; real TPUs compile them.
_INTERPRET = jax.default_backend() != "tpu"

MAX_HG = 256
MAX_KEEP = 128


def kernel_supported(d: PackedDelta) -> bool:
    return (not d.stack_shape()) and d.h_g <= MAX_HG and d.keep <= MAX_KEEP \
        and (d.k_bits is None or 1 <= d.k_bits <= 8)


def _scalars(d: PackedDelta):
    s = jnp.asarray(d.scale, jnp.float32).reshape(1, 1)
    z = jnp.asarray(d.zero, jnp.int32).reshape(1, 1)
    return s, z


def _pad_rows(x: jnp.ndarray, mult: int):
    T = x.shape[0]
    pad = (-T) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, T




def delta_spmm(x: jnp.ndarray, d: PackedDelta, *, tb: int = 128, ob: int = 128,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ dequant(d). x [..., h_in] -> [..., h_out] (f32)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return x.reshape(-1, d.h_in).astype(jnp.float32) @ reconstruct_dense(d) \
            if x.ndim == 2 else x @ reconstruct_dense(d, dtype=x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(tb, max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    y = _k.delta_spmm_kernel(x2, d.idx, d.codes, s, z, h_g=d.h_g, keep=d.keep,
                             k_bits=d.k_bits, h_out=d.h_out,
                             tb=tb_eff, ob=ob_eff, interpret=interpret)
    return y[:T].reshape(*lead, d.h_out)


def delta_spmm_slots(x: jnp.ndarray, d: PackedDelta, *, tb: int = 128,
                     ob: int = 128, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-row delta matmul for mixed-tenant decode batches.

    x [B, ..., h_in]; d is a row-gathered PackedDelta stacked [B, ...]
    (one tenant's packed delta per batch row). Row b computes
    ``x[b] @ dequant(d[b])``. On TPU the per-matrix kernel is vmapped over
    the row axis; elsewhere (and in interpret mode, where the batching
    rule is not exercised) the dense XLA fallback is used.
    """
    if interpret is None:
        interpret = _INTERPRET
    B = x.shape[0]
    assert d.stack_shape() == (B,), (d.stack_shape(), x.shape)
    probe = d.index(0)
    if interpret or not kernel_supported(probe):
        dense = reconstruct_dense(d, dtype=x.dtype)   # [B, h_in, h_out]
        return jnp.einsum("b...d,bdf->b...f", x, dense)
    fn = lambda xb, db: delta_spmm(xb, db, tb=tb, ob=ob, interpret=False)
    return jax.vmap(fn)(x, d)


def delta_correction_sharded(x: jnp.ndarray, d: PackedDelta, mesh, *,
                             use_pallas: bool = False,
                             interpret: Optional[bool] = None,
                             tb: int = 128, ob: int = 128) -> Optional[jnp.ndarray]:
    """y = x · dequant(d), with d partitioned along output columns.

    ``d`` is either a shared delta (no stack) or a row-gathered stack
    ``[B]`` matching ``x``'s leading dim (mixed-tenant decode). The
    shard_map body computes its own h_out/n_model column slice with the
    exact same local math as the single-device path (Pallas kernel when
    ``use_pallas``, reconstruct-then-matmul otherwise), so sharded
    serving is bit-identical to the replicated engine.

    Returns None when the mesh/delta layout does not apply (no model
    axis, h_out not divisible, unsupported stack shape) — the caller
    falls back to the replicated path.
    """
    n = mesh.shape.get("model", 1) if mesh is not None else 1
    if n <= 1 or d.h_out % n:
        return None
    stack = d.stack_shape()
    if stack not in ((), (x.shape[0],)):
        return None
    scale = jnp.asarray(d.scale, jnp.float32)
    zero = jnp.asarray(d.zero, jnp.int32)

    def last_model(nd: int) -> P:
        return P(*([None] * (nd - 1) + ["model"]))

    def repl(nd: int) -> P:
        return P(*([None] * nd))

    def body(xb, idx, codes, s, z):
        # local O-slice delta: static meta rebuilt with the shard's h_out
        dl = PackedDelta(idx, codes, s, z, d.h_in, idx.shape[-1], d.h_g,
                         d.keep, d.alpha, d.k_bits, d.m)
        if stack:
            if use_pallas:
                return delta_spmm_slots(xb, dl, tb=tb, ob=ob,
                                        interpret=interpret)
            dense = reconstruct_dense(dl, dtype=xb.dtype)
            return jnp.einsum("b...d,bdf->b...f", xb, dense)
        if use_pallas:
            return delta_spmm(xb, dl, tb=tb, ob=ob, interpret=interpret)
        return xb @ reconstruct_dense(dl, dtype=xb.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(repl(x.ndim), last_model(d.idx.ndim),
                             last_model(d.codes.ndim), repl(scale.ndim),
                             repl(zero.ndim)),
                   out_specs=last_model(x.ndim),
                   check_rep=False)
    return fn(x, d.idx, d.codes, scale, zero)


def fused_base_delta(x: jnp.ndarray, w: jnp.ndarray, d: PackedDelta, *,
                     tb: int = 128, ob: int = 128,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ (w + dequant(d)); reads x once (separate computation, fused)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return (x @ w) + delta_spmm(x, d, interpret=interpret).astype(w.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(tb, max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    y = _k.fused_base_delta_kernel(x2, w, d.idx, d.codes, s, z, h_g=d.h_g,
                                   keep=d.keep, k_bits=d.k_bits,
                                   tb=tb_eff, ob=ob_eff, interpret=interpret)
    return y[:T].reshape(*lead, d.h_out)


def dequant(d: PackedDelta, *, ob: int = 128,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Materialize dense delta [h_in, h_out] (merge path)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return reconstruct_dense(d)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    return _k.dequant_kernel(d.idx, d.codes, s, z, h_g=d.h_g, keep=d.keep,
                             k_bits=d.k_bits, h_out=d.h_out, ob=ob_eff,
                             interpret=interpret)


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _largest_divisor_tile(n: int, cap: int) -> int:
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1
