"""Jit'd public wrappers around the Pallas kernels.

Handle envelope checks (tile divisibility, supported h_g/keep), input
prep (padding, scalar shaping) and the interpret-mode switch used for
CPU validation. Outside the kernel envelope the XLA fallback
(``kernels.fallback``: gather formulation at decode token counts, dense
reconstruct-then-matmul at prefill counts) is used — mathematically
identical. Tile sizes (tb, ob, kc) default to the persisted autotune
table (``kernels.autotune``); explicit arguments always win.

Output columns that don't divide the tile run on the largest reasonable
divisor tile (no padding); only when every divisor is pathologically
small (prime-ish ``h_out``) is the packed column axis padded up to a
pow2 tile and the result sliced — at most one partial tile instead of
degrading to an ``ob=1`` grid.

Multi-device: :func:`delta_correction_sharded` partitions the packed
delta along its output-column axis over the mesh ``model`` axis with
``shard_map``, so each shard dequantizes only its h_out/n columns —
the kernel's compressed-bytes-only HBM traffic is preserved per shard
and the correction needs no collectives (x is replicated at decode
batch sizes; each output column is produced by exactly one shard).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.pack import PackedDelta, reconstruct_dense
from repro.kernels import autotune, fallback
from repro.kernels import delta_spmm as _k

# CPU containers run kernels in interpret mode; real TPUs compile them.
_INTERPRET = jax.default_backend() != "tpu"


def _note(site: str, **attrs) -> None:
    """Report the chosen path to an open trace context (no-op otherwise).
    Lazy import: serve's __init__ imports the engine, which imports us."""
    from repro.serve.trace import note_path
    note_path(site, **attrs)

MAX_HG = 256
MAX_KEEP = 128


def kernel_supported(d: PackedDelta) -> bool:
    return (not d.stack_shape()) and d.h_g <= MAX_HG and d.keep <= MAX_KEEP \
        and (d.k_bits is None or 1 <= d.k_bits <= 8)


def _scalars(d: PackedDelta):
    s = jnp.asarray(d.scale, jnp.float32).reshape(1, 1)
    z = jnp.asarray(d.zero, jnp.int32).reshape(1, 1)
    return s, z


def _pad_rows(x: jnp.ndarray, mult: int):
    T = x.shape[0]
    pad = (-T) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, T


def _tiles(d: PackedDelta, tb, ob, kc, t: Optional[int] = None) -> dict:
    """Resolve tile sizes: explicit args win, else the autotune table.

    ``t`` is the call's token count (a static trace-time int): the v3
    table overlays per-T tiles on the envelope point so prefill-chunk
    sized calls stop inheriting decode tiles. ``gather_max_t`` always
    comes from the base entry (one monotone formulation threshold)."""
    tuned = autotune.lookup(d.h_g, d.keep, d.k_bits, d.h_in, d.h_out, t=t)
    return {"tb": tb if tb is not None else tuned["tb"],
            "ob": ob if ob is not None else tuned["ob"],
            "kc": kc if kc is not None else tuned["kc"],
            "gather_max_t": tuned["gather_max_t"]}


# smallest column tile worth running unpadded; below this a divisor tile
# makes a pathological grid and pad-to-pow2 wins
_MIN_COL_TILE = 32


def _col_tile(h_out: int, ob: int) -> int:
    """Effective column tile for ``h_out`` output columns.

    Prefer a divisor of ``h_out`` (no padding, no wasted columns — in
    the fused kernel padding also copies the whole base matrix); only
    when the best divisor is pathologically small (< _MIN_COL_TILE, e.g.
    prime-ish h_out) fall back to a pow2 tile and let the caller pad
    and slice."""
    cap = min(ob, h_out)
    if h_out % cap == 0:
        return cap
    for t in range(cap, _MIN_COL_TILE - 1, -1):
        if h_out % t == 0:
            return t
    return min(ob, _pow2_ceil(h_out))


def _pad_cols(d: PackedDelta, ob: int) -> PackedDelta:
    """Pad the packed column axis to an ``ob`` multiple (slice the result).

    Padded columns decode to garbage values ((0 - zero) * scale) but are
    sliced off by every caller before the result escapes, so only the
    real columns are ever observed.
    """
    pad = (-d.h_out) % ob
    if not pad:
        return d
    widths = [(0, 0)] * (d.idx.ndim - 1) + [(0, pad)]
    return PackedDelta(jnp.pad(d.idx, widths), jnp.pad(d.codes, widths),
                       d.scale, d.zero, d.h_in, d.h_out + pad, d.h_g,
                       d.keep, d.alpha, d.k_bits, d.m, d.codec)


def delta_spmm(x: jnp.ndarray, d: PackedDelta, *, tb: Optional[int] = None,
               ob: Optional[int] = None, kc: Optional[int] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ dequant(d). x [..., h_in] -> [..., h_out] (f32)."""
    if interpret is None:
        interpret = _INTERPRET
    t = _tiles(d, tb, ob, kc, t=x.size // x.shape[-1])
    if not kernel_supported(d):
        return fallback.correction_nd(x, d,
                                      gather_max_t=t["gather_max_t"])
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(t["tb"], max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = _col_tile(d.h_out, t["ob"])
    _note("delta_spmm", formulation="pallas", codec=d.codec,
          tb=tb_eff, ob=ob_eff, kc=t["kc"])
    dp = _pad_cols(d, ob_eff)
    s, z = _scalars(d)
    y = _k.delta_spmm_kernel(x2, dp.idx, dp.codes, s, z, h_g=d.h_g,
                             keep=d.keep, k_bits=d.k_bits, h_out=dp.h_out,
                             tb=tb_eff, ob=ob_eff, kc=t["kc"],
                             interpret=interpret)
    return y[:T, :d.h_out].reshape(*lead, d.h_out)


def delta_spmm_slots(x: jnp.ndarray, d: PackedDelta, *,
                     tb: Optional[int] = None, ob: Optional[int] = None,
                     kc: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-row delta matmul for mixed-tenant decode batches.

    x [B, ..., h_in]; d is a row-gathered PackedDelta stacked [B, ...]
    (one tenant's packed delta per batch row). Row b computes
    ``x[b] @ dequant(d[b])``. On TPU the per-matrix kernel is vmapped
    over the row axis; elsewhere (and in interpret mode, where the
    batching rule is not exercised) the gather-formulation fallback is
    used — it never materializes a dense ``[B, h_in, h_out]`` tensor, so
    rows sharing a tenant no longer multiply a dense reconstruction.
    """
    if interpret is None:
        interpret = _INTERPRET
    B = x.shape[0]
    if d.stack_shape() != (B,):
        raise ValueError(
            f"stacked delta stack_shape={d.stack_shape()} must equal "
            f"({B},) — one delta row per slot row of x {x.shape}")
    probe = d.index(0)
    if interpret or not kernel_supported(probe):
        _note("delta_spmm_slots", formulation="per-row-gather",
              codec=d.codec, B=int(B))
        return fallback.gather_correction_rows(x, d)
    _note("delta_spmm_slots", formulation="per-row-pallas",
          codec=d.codec, B=int(B))
    fn = lambda xb, db: delta_spmm(xb, db, tb=tb, ob=ob, kc=kc,
                                   interpret=False)
    return jax.vmap(fn)(x, d)


def delta_spmm_segments(x_sorted: jnp.ndarray, d: PackedDelta,
                        seg_rows: jnp.ndarray, seg_offsets: jnp.ndarray, *,
                        values: Optional[jnp.ndarray] = None,
                        res_map: Optional[jnp.ndarray] = None,
                        tb: Optional[int] = None, ob: Optional[int] = None,
                        kc: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Unique-tenant batched slot dispatch: x_sorted rows grouped by tenant.

    x_sorted [T, h_in] (rows pre-sorted so each tenant occupies one
    contiguous segment); d is the tenant-stacked PackedDelta [R, ...];
    seg_rows [S] int32 maps segment -> tenant row; seg_offsets [S+1]
    int32 bounds each segment (empty segments allowed — S is a static
    shape). Each unique delta is dequantized once per step and applied
    to its row segment. On TPU this is the batched slot kernel
    (``delta_spmm_segments_kernel``); elsewhere the scan-over-segments
    XLA fallback.

    Decode fast path: when the whole batch fits one row tile (the decode
    regime — T = n_slots), ``tb`` collapses to the padded batch size and
    the grid has a single row block, skipping the pad-to-pow2 dance.

    ``values``/``res_map`` (pre-decoded residency tier) route to the
    values-given XLA formulation: the Pallas segments kernel already
    decodes each [h_g, Ob] VMEM tile once per segment, so the per-step
    unpack the residency tier removes is the XLA/CPU host cost — a
    values-consuming kernel variant is not worth a second TPU code
    path. Packed-only (values=None) stays the always-correct fallback.
    """
    if interpret is None:
        interpret = _INTERPRET
    if values is not None:
        return fallback.segment_correction(x_sorted, d, seg_rows, seg_offsets,
                                           values=values, res_map=res_map)
    probe = d.index(0)
    t = _tiles(probe, tb, ob, kc, t=x_sorted.shape[0])
    if not kernel_supported(probe):
        return fallback.segment_correction(x_sorted, d, seg_rows, seg_offsets)
    T = x_sorted.shape[0]
    if T <= t["tb"]:
        tb_eff = max(8, -(-T // 8) * 8)     # decode fast path: one row block
    else:
        tb_eff = min(t["tb"], max(_pow2_floor(T), 8))
    x2, T = _pad_rows(x_sorted, tb_eff)
    ob_eff = _col_tile(d.h_out, t["ob"])
    _note("delta_spmm_segments", formulation="segments-pallas",
          codec=d.codec, residency="packed", tb=tb_eff, ob=ob_eff,
          kc=t["kc"])
    dp = _pad_cols(d, ob_eff)
    scale = jnp.asarray(d.scale, jnp.float32).reshape(-1, 1)
    zero = jnp.asarray(d.zero, jnp.int32).reshape(-1, 1)
    y = _k.delta_spmm_segments_kernel(
        x2, dp.idx, dp.codes, scale, zero,
        seg_rows.astype(jnp.int32), seg_offsets.astype(jnp.int32),
        h_g=d.h_g, keep=d.keep, k_bits=d.k_bits, h_out=dp.h_out,
        tb=tb_eff, ob=ob_eff, kc=t["kc"], interpret=interpret)
    return y[:T, :d.h_out]


def delta_correction_sharded(x: jnp.ndarray, d: PackedDelta, mesh, *,
                             use_pallas: bool = False,
                             interpret: Optional[bool] = None,
                             tb: Optional[int] = None,
                             ob: Optional[int] = None,
                             segments: Optional[tuple] = None,
                             values: Optional[jnp.ndarray] = None,
                             res_map: Optional[jnp.ndarray] = None
                             ) -> Optional[jnp.ndarray]:
    """y = x · dequant(d), with d partitioned along output columns.

    ``d`` is a shared delta (no stack), a row-gathered stack ``[B]``
    matching ``x``'s leading dim (per-row mixed-tenant decode), or — with
    ``segments=(seg_rows, seg_offsets)`` — the tenant stack ``[R]``
    consumed by the unique-tenant dispatch (x rows pre-sorted by
    tenant). Segment arrays may be the global ``[S]``/``[S+1]`` layout
    or the per-data-shard ``[D, B_s]``/``[D, B_s+1]`` layout (detected
    by ndim): the per-shard form additionally partitions x's rows over
    the mesh ``data`` axis, so each (data, model) device computes its
    own pool's rows for its own column slice — and dequantizes only the
    tenants its pool hosts. With ``values``/``res_map`` (segments mode
    only) the pre-decoded residency tier shards exactly like the codes
    — values partition along their output-column axis, so each shard
    reads only its slice of the decoded f32 bytes and skips the
    per-step unpack. The shard_map body computes its slice with
    the exact same local math as the single-device path (Pallas kernel
    when ``use_pallas``, the gather/segment fallback otherwise), so
    sharded serving is bit-identical to the replicated engine: the
    contraction for every output element is unchanged, only *which
    shard* produces it differs.

    Returns None when the mesh/delta layout does not apply (no model
    axis, h_out not divisible, unsupported stack shape, per-shard
    layout not matching the mesh data axis) — the caller falls back to
    the replicated path.
    """
    n = mesh.shape.get("model", 1) if mesh is not None else 1
    if n <= 1 or d.h_out % n:
        return None
    stack = d.stack_shape()
    if segments is not None:
        if len(stack) != 1:
            return None
    elif stack not in ((), (x.shape[0],)):
        return None
    scale = jnp.asarray(d.scale, jnp.float32)
    zero = jnp.asarray(d.zero, jnp.int32)

    def last_model(nd: int) -> P:
        return P(*([None] * (nd - 1) + ["model"]))

    def repl(nd: int) -> P:
        return P(*([None] * nd))

    def local_delta(idx, codes, s, z) -> PackedDelta:
        # local O-slice delta: static meta rebuilt with the shard's h_out
        return PackedDelta(idx, codes, s, z, d.h_in, idx.shape[-1], d.h_g,
                           d.keep, d.alpha, d.k_bits, d.m, d.codec)

    # tiles and formulation decided on the GLOBAL envelope point (the
    # local slice has a different h_out key: it must not flip the
    # formulation — sharded and replicated serving would use different
    # arithmetic — and has no swept autotune entry of its own). Hoisted
    # above the segments branch: its kernel body needs kc too. The
    # token-count overlay keys on the GLOBAL row count for the same
    # reason (per-shard rows would change the key with the data extent).
    t_glob = _tiles(d, tb, ob, None, t=x.size // x.shape[-1])
    tb, ob = t_glob["tb"], t_glob["ob"]
    kc = t_glob["kc"]
    _note("delta_correction_sharded", sharded=True, codec=d.codec,
          model_shards=int(n),
          per_shard_segments=segments is not None
          and jnp.ndim(segments[0]) == 2)

    if segments is not None:
        seg_rows, seg_offsets = segments
        seg_rows = jnp.asarray(seg_rows, jnp.int32)
        seg_offsets = jnp.asarray(seg_offsets, jnp.int32)
        have_values = values is not None

        def body_seg(xb, idx, codes, s, z, sr, so, *vr):
            if sr.ndim == 2:               # per-shard block: [1, B_s(+1)]
                sr, so = sr[0], so[0]
            v, rm = vr if vr else (None, None)
            dl = local_delta(idx, codes, s, z)
            if use_pallas:
                return delta_spmm_segments(xb, dl, sr, so, values=v,
                                           res_map=rm, tb=tb, ob=ob,
                                           kc=kc, interpret=interpret)
            return fallback.segment_correction(xb, dl, sr, so, values=v,
                                               res_map=rm)

        # NOTE: dtype round-trip happens in the caller (apply.py) for the
        # segments path; the body stays f32 like its local fallback.

        # residency values shard their output-column axis with the codes
        # (each shard reads only its decoded slice); res_map replicates
        val_specs = (last_model(values.ndim), repl(1)) if have_values else ()
        val_args = (values, res_map) if have_values else ()
        if seg_rows.ndim == 2:
            # per-data-shard layout: rows partition over `data`, each
            # shard consumes its own pool-local segment block
            n_data = mesh.shape.get("data", 1)
            if seg_rows.shape[0] != n_data or x.shape[0] % n_data:
                return None
            fn = shard_map(body_seg, mesh=mesh,
                           in_specs=(P(*(["data"] + [None] * (x.ndim - 1))),
                                     last_model(d.idx.ndim),
                                     last_model(d.codes.ndim),
                                     repl(scale.ndim), repl(zero.ndim),
                                     P("data", None), P("data", None),
                                     *val_specs),
                           out_specs=P(*(["data"] + [None] * (x.ndim - 2)
                                         + ["model"])),
                           check_rep=False)
        else:
            fn = shard_map(body_seg, mesh=mesh,
                           in_specs=(repl(x.ndim), last_model(d.idx.ndim),
                                     last_model(d.codes.ndim),
                                     repl(scale.ndim), repl(zero.ndim),
                                     repl(1), repl(1), *val_specs),
                           out_specs=last_model(x.ndim),
                           check_rep=False)
        return fn(x, d.idx, d.codes, scale, zero, seg_rows, seg_offsets,
                  *val_args)
    gather_max_t = t_glob["gather_max_t"]

    def body(xb, idx, codes, s, z):
        dl = local_delta(idx, codes, s, z)
        if stack:
            if use_pallas:
                return delta_spmm_slots(xb, dl, tb=tb, ob=ob,
                                        interpret=interpret)
            y = fallback.gather_correction_rows(xb, dl)
        elif use_pallas:
            y = delta_spmm(xb, dl, tb=tb, ob=ob, interpret=interpret)
        else:
            y = fallback.correction_nd(xb, dl, gather_max_t=gather_max_t)
        # same dtype round-trip as the replicated path (bit-identity)
        return y.astype(xb.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(repl(x.ndim), last_model(d.idx.ndim),
                             last_model(d.codes.ndim), repl(scale.ndim),
                             repl(zero.ndim)),
                   out_specs=last_model(x.ndim),
                   check_rep=False)
    return fn(x, d.idx, d.codes, scale, zero)


def fused_base_delta(x: jnp.ndarray, w: jnp.ndarray, d: PackedDelta, *,
                     tb: Optional[int] = None, ob: Optional[int] = None,
                     kc: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ (w + dequant(d)); reads x once (separate computation, fused)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return (x @ w) + delta_spmm(x, d, interpret=interpret).astype(w.dtype)
    t = _tiles(d, tb, ob, kc, t=x.size // x.shape[-1])
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(t["tb"], max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = _col_tile(d.h_out, t["ob"])
    dp = _pad_cols(d, ob_eff)
    wp = w if dp.h_out == d.h_out else jnp.pad(
        w, ((0, 0), (0, dp.h_out - d.h_out)))
    s, z = _scalars(d)
    y = _k.fused_base_delta_kernel(x2, wp, dp.idx, dp.codes, s, z, h_g=d.h_g,
                                   keep=d.keep, k_bits=d.k_bits,
                                   tb=tb_eff, ob=ob_eff, kc=t["kc"],
                                   interpret=interpret)
    return y[:T, :d.h_out].reshape(*lead, d.h_out)


def dequant(d: PackedDelta, *, ob: Optional[int] = None,
            kc: Optional[int] = None,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Materialize dense delta [h_in, h_out] (merge path)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return reconstruct_dense(d)
    t = _tiles(d, None, ob, kc)
    ob_eff = _col_tile(d.h_out, t["ob"])
    dp = _pad_cols(d, ob_eff)
    s, z = _scalars(d)
    y = _k.dequant_kernel(dp.idx, dp.codes, s, z, h_g=d.h_g, keep=d.keep,
                          k_bits=d.k_bits, h_out=dp.h_out, ob=ob_eff,
                          kc=t["kc"], interpret=interpret)
    return y[:, :d.h_out]


def segment_decode_tiles(seg_offsets, *, n_groups: int, h_out: int,
                         tb: int, ob: int) -> int:
    """Decode-tile work the segments kernel executes for one step.

    Counts (segment, row-block, column-tile, group) grid points whose
    ``pl.when`` guard fires — i.e. how many [h_g, Ob] tiles are actually
    dequantized. The vmapped per-row kernel decodes
    ``B * n_groups * ceil(h_out / ob)`` tiles regardless of duplication;
    the segments kernel decodes per *unique* tenant per overlapped row
    block. This is the deterministic accounting behind the
    "segments beats per-row on duplicate-tenant batches" invariant
    (kernel_bench gates on it; wall-clock on CPU interpret mode is too
    noisy to gate)."""
    import numpy as np
    offs = np.asarray(seg_offsets)
    col_tiles = -(-h_out // ob)
    total = 0
    T = int(offs[-1])
    for s in range(len(offs) - 1):
        start, end = int(offs[s]), int(offs[s + 1])
        if end <= start:
            continue
        for row0 in range(0, T, tb):
            if start < row0 + tb and end > row0:
                total += n_groups * col_tiles
    return total


def per_row_decode_tiles(batch: int, *, n_groups: int, h_out: int,
                         ob: int) -> int:
    """Decode-tile work of the vmapped per-row kernel (T=1 rows)."""
    return batch * n_groups * (-(-h_out // ob))


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
