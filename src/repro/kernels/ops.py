"""Jit'd public wrappers around the Pallas kernels.

Handle envelope checks (tile divisibility, supported h_g/keep), input
prep (padding, scalar shaping) and the interpret-mode switch used for
CPU validation. Outside the kernel envelope the XLA fallback
(reconstruct-then-matmul) is used — mathematically identical.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pack import PackedDelta, reconstruct_dense
from repro.kernels import delta_spmm as _k

# CPU containers run kernels in interpret mode; real TPUs compile them.
_INTERPRET = jax.default_backend() != "tpu"

MAX_HG = 256
MAX_KEEP = 128


def kernel_supported(d: PackedDelta) -> bool:
    return (not d.stack_shape()) and d.h_g <= MAX_HG and d.keep <= MAX_KEEP \
        and (d.k_bits is None or 1 <= d.k_bits <= 8)


def _scalars(d: PackedDelta):
    s = jnp.asarray(d.scale, jnp.float32).reshape(1, 1)
    z = jnp.asarray(d.zero, jnp.int32).reshape(1, 1)
    return s, z


def _pad_rows(x: jnp.ndarray, mult: int):
    T = x.shape[0]
    pad = (-T) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, T




def delta_spmm(x: jnp.ndarray, d: PackedDelta, *, tb: int = 128, ob: int = 128,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ dequant(d). x [..., h_in] -> [..., h_out] (f32)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return x.reshape(-1, d.h_in).astype(jnp.float32) @ reconstruct_dense(d) \
            if x.ndim == 2 else x @ reconstruct_dense(d, dtype=x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(tb, max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    y = _k.delta_spmm_kernel(x2, d.idx, d.codes, s, z, h_g=d.h_g, keep=d.keep,
                             k_bits=d.k_bits, h_out=d.h_out,
                             tb=tb_eff, ob=ob_eff, interpret=interpret)
    return y[:T].reshape(*lead, d.h_out)


def delta_spmm_slots(x: jnp.ndarray, d: PackedDelta, *, tb: int = 128,
                     ob: int = 128, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-row delta matmul for mixed-tenant decode batches.

    x [B, ..., h_in]; d is a row-gathered PackedDelta stacked [B, ...]
    (one tenant's packed delta per batch row). Row b computes
    ``x[b] @ dequant(d[b])``. On TPU the per-matrix kernel is vmapped over
    the row axis; elsewhere (and in interpret mode, where the batching
    rule is not exercised) the dense XLA fallback is used.
    """
    if interpret is None:
        interpret = _INTERPRET
    B = x.shape[0]
    assert d.stack_shape() == (B,), (d.stack_shape(), x.shape)
    probe = d.index(0)
    if interpret or not kernel_supported(probe):
        dense = reconstruct_dense(d, dtype=x.dtype)   # [B, h_in, h_out]
        return jnp.einsum("b...d,bdf->b...f", x, dense)
    fn = lambda xb, db: delta_spmm(xb, db, tb=tb, ob=ob, interpret=False)
    return jax.vmap(fn)(x, d)


def fused_base_delta(x: jnp.ndarray, w: jnp.ndarray, d: PackedDelta, *,
                     tb: int = 128, ob: int = 128,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = x @ (w + dequant(d)); reads x once (separate computation, fused)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return (x @ w) + delta_spmm(x, d, interpret=interpret).astype(w.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d.h_in)
    tb_eff = min(tb, max(_pow2_floor(x2.shape[0]), 8))
    x2, T = _pad_rows(x2, tb_eff)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    y = _k.fused_base_delta_kernel(x2, w, d.idx, d.codes, s, z, h_g=d.h_g,
                                   keep=d.keep, k_bits=d.k_bits,
                                   tb=tb_eff, ob=ob_eff, interpret=interpret)
    return y[:T].reshape(*lead, d.h_out)


def dequant(d: PackedDelta, *, ob: int = 128,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Materialize dense delta [h_in, h_out] (merge path)."""
    if interpret is None:
        interpret = _INTERPRET
    if not kernel_supported(d):
        return reconstruct_dense(d)
    ob_eff = ob if d.h_out % ob == 0 else _largest_divisor_tile(d.h_out, ob)
    s, z = _scalars(d)
    return _k.dequant_kernel(d.idx, d.codes, s, z, h_g=d.h_g, keep=d.keep,
                             k_bits=d.k_bits, h_out=d.h_out, ob=ob_eff,
                             interpret=interpret)


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _largest_divisor_tile(n: int, cap: int) -> int:
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1
