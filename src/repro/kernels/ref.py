"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package has a ref twin here; kernel tests sweep
shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.pack import PackedDelta, reconstruct_dense


def delta_spmm_ref(x: jnp.ndarray, d: PackedDelta) -> jnp.ndarray:
    """x [T, h_in] @ dequant(delta) [h_in, h_out] -> [T, h_out] (f32)."""
    dense = reconstruct_dense(d, dtype=jnp.float32)
    return x.astype(jnp.float32) @ dense


def fused_base_delta_ref(x: jnp.ndarray, w: jnp.ndarray, d: PackedDelta) -> jnp.ndarray:
    """x @ (W_base + dequant(delta)) in one pass -> [T, h_out] (f32)."""
    dense = reconstruct_dense(d, dtype=jnp.float32)
    return x.astype(jnp.float32) @ (w.astype(jnp.float32) + dense)


def dequant_tile_ref(d: PackedDelta) -> jnp.ndarray:
    """Materialize the dense delta [h_in, h_out] (f32)."""
    return reconstruct_dense(d, dtype=jnp.float32)
