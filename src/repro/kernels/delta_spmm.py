"""Pallas TPU kernels for the DeltaDQ hot path.

TPU adaptation of the paper's CSR SpMM (DESIGN.md §3): the packed,
quantized, *structured*-sparse delta streams HBM->VMEM at compressed
width; inside VMEM each (group x out-tile) block is dequantized and
scattered to a dense [h_g, Ob] tile via the one-hot-compare idiom (TPU's
scatter), which then feeds the MXU as a regular dense matmul. HBM traffic
is compressed bytes only; the dense tile never leaves VMEM.

Kernels
    delta_spmm_kernel       y = x @ dequant(delta)
    fused_base_delta_kernel y = x @ (W_base + dequant(delta))   (x read once)
    dequant_kernel          dense delta tile materialization

Grid: (T/Tb, O/Ob, G) with the group axis innermost ("arbitrary") so the
output tile accumulates in VMEM across groups. Supported envelope (checked
by ops.py, XLA fallback otherwise): h_g <= 256, keep <= 128 — the paper's
optimal h_g* is 16..256 (Table 4), so the envelope covers the method's
operating range; row-wise h_g == h_in is the fallback's job.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# kept-values-per-chunk for the in-VMEM scatter loop; bounds the one-hot
# working set to KC * h_g * Ob * 4B (= 1 MiB at 8 x 256 x 128)
_KC = 8


def _unpack_codes(codes, k_bits: int, keep: int):
    """[Kp, Ob] uint8 -> [keep, Ob] int32 codes (w = physical pack width)."""
    w = 1 if k_bits <= 1 else 2 if k_bits <= 2 else 4 if k_bits <= 4 else 8
    if w == 8:
        return codes.astype(jnp.int32)
    per = 8 // w
    mask = jnp.uint8(2**w - 1)
    cols = [(codes >> jnp.uint8(i * w)) & mask for i in range(per)]
    q = jnp.stack(cols, axis=1)                      # [Kp, per, Ob]
    q = q.reshape(codes.shape[0] * per, codes.shape[1])
    return q[:keep].astype(jnp.int32)


def _scatter_dense(idx, vals, h_g: int, keep: int):
    """Build the dense [h_g, Ob] tile from (idx, vals) [keep, Ob] in VMEM.

    One-hot-compare scatter, chunked over `keep` to bound the working set.
    """
    Ob = idx.shape[-1]
    iota_h = jax.lax.broadcasted_iota(jnp.int32, (1, h_g, 1), 1)
    n_chunks = (keep + _KC - 1) // _KC
    pad = n_chunks * _KC - keep
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    idx = idx.reshape(n_chunks, _KC, Ob)
    vals = vals.reshape(n_chunks, _KC, Ob)

    def body(c, dense):
        sel_i = idx[c][:, None, :]                   # [KC, 1, Ob]
        sel_v = vals[c][:, None, :]
        oh = (sel_i == iota_h).astype(jnp.float32)   # [KC, h_g, Ob]
        return dense + jnp.sum(oh * sel_v, axis=0)

    dense0 = jnp.zeros((h_g, Ob), jnp.float32)
    return jax.lax.fori_loop(0, n_chunks, body, dense0)


def _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref, *, k_bits, keep, h_g):
    idx = idx_ref[0].astype(jnp.int32)               # [keep, Ob]
    if k_bits is None:
        vals = codes_ref[0].astype(jnp.float32)
    else:
        q = _unpack_codes(codes_ref[0], k_bits, keep)
        s = scale_ref[0, 0]
        z = zero_ref[0, 0].astype(jnp.float32)
        vals = (q.astype(jnp.float32) - z) * s
    return _scatter_dense(idx, vals, h_g, keep)


# ---------------------------------------------------------------------------
# y = x @ dequant(delta)
# ---------------------------------------------------------------------------
def _spmm_body(x_ref, idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
               k_bits, keep, h_g):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                         k_bits=k_bits, keep=keep, h_g=h_g)
    x = x_ref[...].astype(jnp.float32)               # [Tb, h_g]
    o_ref[...] += jnp.dot(x, dense, preferred_element_type=jnp.float32)


def delta_spmm_kernel(x, idx, codes, scale, zero, *, h_g: int, keep: int,
                      k_bits: Optional[int], h_out: int,
                      tb: int = 128, ob: int = 128, interpret: bool = False):
    """x [T, h_in]; idx [G, keep, O]; codes [G, Kp|keep, O]; -> [T, O] f32."""
    T, h_in = x.shape
    G = h_in // h_g
    Kp = codes.shape[1]
    tb = min(tb, T)
    ob = min(ob, h_out)
    assert T % tb == 0 and h_out % ob == 0, (T, tb, h_out, ob)
    grid = (T // tb, h_out // ob, G)
    return pl.pallas_call(
        functools.partial(_spmm_body, k_bits=k_bits, keep=keep, h_g=h_g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, h_g), lambda t, o, g: (t, g)),
            pl.BlockSpec((1, keep, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda t, o, g: (t, o)),
        out_shape=jax.ShapeDtypeStruct((T, h_out), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, idx, codes, scale, zero)


# ---------------------------------------------------------------------------
# y = x @ (W + dequant(delta))  — separate computation fused into one pass
# ---------------------------------------------------------------------------
def _fused_body(x_ref, w_ref, idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
                k_bits, keep, h_g):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                         k_bits=k_bits, keep=keep, h_g=h_g)
    w = w_ref[...].astype(jnp.float32)               # [h_g, Ob]
    x = x_ref[...].astype(jnp.float32)               # [Tb, h_g]
    o_ref[...] += jnp.dot(x, w + dense, preferred_element_type=jnp.float32)


def fused_base_delta_kernel(x, w, idx, codes, scale, zero, *, h_g: int, keep: int,
                            k_bits: Optional[int],
                            tb: int = 128, ob: int = 128, interpret: bool = False):
    """x [T, h_in]; w [h_in, h_out]; packed delta -> [T, h_out] f32."""
    T, h_in = x.shape
    h_out = w.shape[1]
    G = h_in // h_g
    Kp = codes.shape[1]
    tb = min(tb, T)
    ob = min(ob, h_out)
    assert T % tb == 0 and h_out % ob == 0
    grid = (T // tb, h_out // ob, G)
    return pl.pallas_call(
        functools.partial(_fused_body, k_bits=k_bits, keep=keep, h_g=h_g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, h_g), lambda t, o, g: (t, g)),
            pl.BlockSpec((h_g, ob), lambda t, o, g: (g, o)),
            pl.BlockSpec((1, keep, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda t, o, g: (t, o)),
        out_shape=jax.ShapeDtypeStruct((T, h_out), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, idx, codes, scale, zero)


# ---------------------------------------------------------------------------
# dense delta materialization (merge / eval path)
# ---------------------------------------------------------------------------
def _dequant_body(idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
                  k_bits, keep, h_g):
    o_ref[...] = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                              k_bits=k_bits, keep=keep, h_g=h_g)


def dequant_kernel(idx, codes, scale, zero, *, h_g: int, keep: int,
                   k_bits: Optional[int], h_out: int,
                   ob: int = 128, interpret: bool = False):
    """Packed delta -> dense [h_in, h_out] f32."""
    G = idx.shape[0]
    Kp = codes.shape[1]
    ob = min(ob, h_out)
    assert h_out % ob == 0
    grid = (G, h_out // ob)
    return pl.pallas_call(
        functools.partial(_dequant_body, k_bits=k_bits, keep=keep, h_g=h_g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, keep, ob), lambda g, o: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda g, o: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda g, o: (0, 0)),
            pl.BlockSpec((1, 1), lambda g, o: (0, 0)),
        ],
        out_specs=pl.BlockSpec((h_g, ob), lambda g, o: (g, o)),
        out_shape=jax.ShapeDtypeStruct((G * h_g, h_out), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )(idx, codes, scale, zero)
