"""Pallas TPU kernels for the DeltaDQ hot path.

TPU adaptation of the paper's CSR SpMM (DESIGN.md §3): the packed,
quantized, *structured*-sparse delta streams HBM->VMEM at compressed
width; inside VMEM each (group x out-tile) block is dequantized and
scattered to a dense [h_g, Ob] tile via the one-hot-compare idiom (TPU's
scatter), which then feeds the MXU as a regular dense matmul. HBM traffic
is compressed bytes only; the dense tile never leaves VMEM.

Kernels
    delta_spmm_kernel           y = x @ dequant(delta)
    fused_base_delta_kernel     y = x @ (W_base + dequant(delta))  (x read once)
    delta_spmm_segments_kernel  mixed-tenant decode: rows sorted by tenant,
                                each tenant's tile decoded ONCE per segment
    dequant_kernel              dense delta tile materialization

Grid: (T/Tb, O/Ob, G) with the group axis innermost ("arbitrary") so the
output tile accumulates in VMEM across groups; the segments kernel adds a
segment axis next to G (both "arbitrary", consecutive for a fixed output
block) and scalar-prefetches the tenant-segment layout so BlockSpec index
maps can route each segment to its tenant's compressed bytes. Supported
envelope (checked by ops.py, XLA fallback otherwise): h_g <= 256,
keep <= 128 — the paper's optimal h_g* is 16..256 (Table 4), so the
envelope covers the method's operating range; row-wise h_g == h_in is the
fallback's job.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
# so the pinned CI jax (0.4.x) and the latest-jax canary both compile.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

# default kept-values-per-chunk for the in-VMEM scatter loop; bounds the
# one-hot working set to KC * h_g * Ob * 4B (= 1 MiB at 8 x 256 x 128).
# Autotune (kernels/autotune.py) can override per envelope point.
_KC = 8


def _unpack_codes(codes, k_bits: int, keep: int):
    """[Kp, Ob] uint8 -> [keep, Ob] int32 codes (w = physical pack width)."""
    w = 1 if k_bits <= 1 else 2 if k_bits <= 2 else 4 if k_bits <= 4 else 8
    if w == 8:
        return codes.astype(jnp.int32)
    per = 8 // w
    mask = jnp.uint8(2**w - 1)
    cols = [(codes >> jnp.uint8(i * w)) & mask for i in range(per)]
    q = jnp.stack(cols, axis=1)                      # [Kp, per, Ob]
    q = q.reshape(codes.shape[0] * per, codes.shape[1])
    return q[:keep].astype(jnp.int32)


def _scatter_dense(idx, vals, h_g: int, keep: int, kc: int = _KC):
    """Build the dense [h_g, Ob] tile from (idx, vals) [keep, Ob] in VMEM.

    One-hot-compare scatter, chunked over `keep` (chunk size ``kc``) to
    bound the working set.
    """
    Ob = idx.shape[-1]
    iota_h = jax.lax.broadcasted_iota(jnp.int32, (1, h_g, 1), 1)
    n_chunks = (keep + kc - 1) // kc
    pad = n_chunks * kc - keep
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    idx = idx.reshape(n_chunks, kc, Ob)
    vals = vals.reshape(n_chunks, kc, Ob)

    def body(c, dense):
        sel_i = idx[c][:, None, :]                   # [KC, 1, Ob]
        sel_v = vals[c][:, None, :]
        oh = (sel_i == iota_h).astype(jnp.float32)   # [KC, h_g, Ob]
        return dense + jnp.sum(oh * sel_v, axis=0)

    dense0 = jnp.zeros((h_g, Ob), jnp.float32)
    return jax.lax.fori_loop(0, n_chunks, body, dense0)


def _decode_arrays(idx, codes, scale, zero, *, k_bits, keep, h_g, kc=_KC):
    """(idx [keep, Ob], codes [Kp|keep, Ob], scalars) -> dense [h_g, Ob]."""
    idx = idx.astype(jnp.int32)
    if k_bits is None:
        vals = codes.astype(jnp.float32)
    else:
        q = _unpack_codes(codes, k_bits, keep)
        vals = (q.astype(jnp.float32) - zero.astype(jnp.float32)) * scale
    return _scatter_dense(idx, vals, h_g, keep, kc)


def _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref, *, k_bits, keep,
                 h_g, kc=_KC):
    return _decode_arrays(idx_ref[0], codes_ref[0], scale_ref[0, 0],
                          zero_ref[0, 0], k_bits=k_bits, keep=keep, h_g=h_g,
                          kc=kc)


# ---------------------------------------------------------------------------
# y = x @ dequant(delta)
# ---------------------------------------------------------------------------
def _spmm_body(x_ref, idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
               k_bits, keep, h_g, kc):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                         k_bits=k_bits, keep=keep, h_g=h_g, kc=kc)
    x = x_ref[...].astype(jnp.float32)               # [Tb, h_g]
    o_ref[...] += jnp.dot(x, dense, preferred_element_type=jnp.float32)


def delta_spmm_kernel(x, idx, codes, scale, zero, *, h_g: int, keep: int,
                      k_bits: Optional[int], h_out: int,
                      tb: int = 128, ob: int = 128, kc: int = _KC,
                      interpret: bool = False):
    """x [T, h_in]; idx [G, keep, O]; codes [G, Kp|keep, O]; -> [T, O] f32."""
    T, h_in = x.shape
    G = h_in // h_g
    Kp = codes.shape[1]
    tb = min(tb, T)
    ob = min(ob, h_out)
    if T % tb or h_out % ob:
        raise ValueError(
            f"kernel tiles must divide extents: T={T} %% tb={tb} and "
            f"h_out={h_out} %% ob={ob} must both be 0")
    grid = (T // tb, h_out // ob, G)
    return pl.pallas_call(
        functools.partial(_spmm_body, k_bits=k_bits, keep=keep, h_g=h_g, kc=kc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, h_g), lambda t, o, g: (t, g)),
            pl.BlockSpec((1, keep, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda t, o, g: (t, o)),
        out_shape=jax.ShapeDtypeStruct((T, h_out), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, idx, codes, scale, zero)


# ---------------------------------------------------------------------------
# y = x @ (W + dequant(delta))  — separate computation fused into one pass
# ---------------------------------------------------------------------------
def _fused_body(x_ref, w_ref, idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
                k_bits, keep, h_g, kc):
    gi = pl.program_id(2)

    @pl.when(gi == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                         k_bits=k_bits, keep=keep, h_g=h_g, kc=kc)
    w = w_ref[...].astype(jnp.float32)               # [h_g, Ob]
    x = x_ref[...].astype(jnp.float32)               # [Tb, h_g]
    o_ref[...] += jnp.dot(x, w + dense, preferred_element_type=jnp.float32)


def fused_base_delta_kernel(x, w, idx, codes, scale, zero, *, h_g: int, keep: int,
                            k_bits: Optional[int],
                            tb: int = 128, ob: int = 128, kc: int = _KC,
                            interpret: bool = False):
    """x [T, h_in]; w [h_in, h_out]; packed delta -> [T, h_out] f32."""
    T, h_in = x.shape
    h_out = w.shape[1]
    G = h_in // h_g
    Kp = codes.shape[1]
    tb = min(tb, T)
    ob = min(ob, h_out)
    if T % tb or h_out % ob:
        raise ValueError(
            f"kernel tiles must divide extents: T={T} %% tb={tb} and "
            f"h_out={h_out} %% ob={ob} must both be 0")
    grid = (T // tb, h_out // ob, G)
    return pl.pallas_call(
        functools.partial(_fused_body, k_bits=k_bits, keep=keep, h_g=h_g, kc=kc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, h_g), lambda t, o, g: (t, g)),
            pl.BlockSpec((h_g, ob), lambda t, o, g: (g, o)),
            pl.BlockSpec((1, keep, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda t, o, g: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
            pl.BlockSpec((1, 1), lambda t, o, g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda t, o, g: (t, o)),
        out_shape=jax.ShapeDtypeStruct((T, h_out), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, idx, codes, scale, zero)


# ---------------------------------------------------------------------------
# mixed-tenant decode: batched slot kernel over tenant segments
# ---------------------------------------------------------------------------
def _segments_body(seg_rows_ref, seg_offs_ref, x_ref, idx_ref, codes_ref,
                   scale_ref, zero_ref, o_ref, *, k_bits, keep, h_g, tb, kc):
    t = pl.program_id(0)
    s = pl.program_id(2)
    gi = pl.program_id(3)

    @pl.when((s == 0) & (gi == 0))
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    start = seg_offs_ref[s]
    end = seg_offs_ref[s + 1]
    row0 = t * tb

    # skip empty segments and segments disjoint from this row block — the
    # decode work for each tenant happens once per (segment, tile), not
    # once per batch row
    @pl.when((end > start) & (start < row0 + tb) & (end > row0))
    def _():
        dense = _decode_arrays(idx_ref[0, 0], codes_ref[0, 0],
                               scale_ref[0, 0], zero_ref[0, 0],
                               k_bits=k_bits, keep=keep, h_g=h_g, kc=kc)
        x = x_ref[...].astype(jnp.float32)            # [tb, h_g]
        y = jnp.dot(x, dense, preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (tb, 1), 0)
        mask = (rows >= start) & (rows < end)
        o_ref[...] += jnp.where(mask, y, 0.0)


def delta_spmm_segments_kernel(x, idx, codes, scale, zero, seg_rows,
                               seg_offsets, *, h_g: int, keep: int,
                               k_bits: Optional[int], h_out: int,
                               tb: int = 128, ob: int = 128, kc: int = _KC,
                               interpret: bool = False):
    """Mixed-tenant matmul with per-segment tile reuse.

    x [T, h_in] rows **sorted by tenant**; idx [R, G, keep, O] /
    codes [R, G, Kp, O] / scale,zero [R, 1] are the tenant-stacked packed
    delta; seg_rows [S] int32 maps segment -> tenant row; seg_offsets
    [S+1] int32 gives each segment's half-open row range (empty segments
    have equal offsets). Output [T, h_out] f32 where row r gets
    ``x[r] @ dequant(delta[tenant_of(r)])``.

    Grid: (T/Tb, O/Ob, S, G) — the segment and group axes are innermost
    and consecutive for a fixed output block, so the [Tb, Ob] accumulator
    stays in VMEM across every (segment, group) visit and each tenant's
    [h_g, Ob] tile is decoded exactly once per (segment, tile) instead of
    once per batch row. seg_rows/seg_offsets are scalar-prefetched so the
    idx/codes BlockSpec index maps can select the segment's tenant row.
    """
    T, h_in = x.shape
    G = h_in // h_g
    Kp = codes.shape[2]
    S = seg_rows.shape[0]
    tb = min(tb, T)
    ob = min(ob, h_out)
    if T % tb or h_out % ob:
        raise ValueError(
            f"kernel tiles must divide extents: T={T} %% tb={tb} and "
            f"h_out={h_out} %% ob={ob} must both be 0")
    if seg_offsets.shape[0] != S + 1:
        raise ValueError(
            f"seg_offsets has {seg_offsets.shape[0]} entries for {S} "
            f"segments (needs S+1={S + 1} fenceposts)")
    grid = (T // tb, h_out // ob, S, G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, h_g), lambda t, o, s, g, sr, so: (t, g)),
            pl.BlockSpec((1, 1, keep, ob),
                         lambda t, o, s, g, sr, so: (sr[s], g, 0, o)),
            pl.BlockSpec((1, 1, Kp, ob),
                         lambda t, o, s, g, sr, so: (sr[s], g, 0, o)),
            pl.BlockSpec((1, 1), lambda t, o, s, g, sr, so: (sr[s], 0)),
            pl.BlockSpec((1, 1), lambda t, o, s, g, sr, so: (sr[s], 0)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda t, o, s, g, sr, so: (t, o)),
    )
    return pl.pallas_call(
        functools.partial(_segments_body, k_bits=k_bits, keep=keep, h_g=h_g,
                          tb=tb, kc=kc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, h_out), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(seg_rows, seg_offsets, x, idx, codes, scale, zero)


# ---------------------------------------------------------------------------
# dense delta materialization (merge / eval path)
# ---------------------------------------------------------------------------
def _dequant_body(idx_ref, codes_ref, scale_ref, zero_ref, o_ref, *,
                  k_bits, keep, h_g, kc):
    o_ref[...] = _decode_tile(idx_ref, codes_ref, scale_ref, zero_ref,
                              k_bits=k_bits, keep=keep, h_g=h_g, kc=kc)


def dequant_kernel(idx, codes, scale, zero, *, h_g: int, keep: int,
                   k_bits: Optional[int], h_out: int,
                   ob: int = 128, kc: int = _KC, interpret: bool = False):
    """Packed delta -> dense [h_in, h_out] f32."""
    G = idx.shape[0]
    Kp = codes.shape[1]
    ob = min(ob, h_out)
    if h_out % ob:
        raise ValueError(f"ob={ob} must divide h_out={h_out}")
    grid = (G, h_out // ob)
    return pl.pallas_call(
        functools.partial(_dequant_body, k_bits=k_bits, keep=keep, h_g=h_g,
                          kc=kc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, keep, ob), lambda g, o: (g, 0, o)),
            pl.BlockSpec((1, Kp, ob), lambda g, o: (g, 0, o)),
            pl.BlockSpec((1, 1), lambda g, o: (0, 0)),
            pl.BlockSpec((1, 1), lambda g, o: (0, 0)),
        ],
        out_specs=pl.BlockSpec((h_g, ob), lambda g, o: (g, o)),
        out_shape=jax.ShapeDtypeStruct((G * h_g, h_out), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )(idx, codes, scale, zero)
