"""Static analysis + runtime sanitizers for the repo's coded contracts.

Two pieces:

* :mod:`repro.analysis.lint` — ``deltalint``, an AST-based lint pass
  (``python -m repro.analysis.lint src/repro``) whose rules encode the
  identity/determinism invariants this codebase has fought for: no
  dot-family reductions in the bit-identity correction paths, no
  process-seeded randomness in compression, typed exceptions in runtime
  paths, a closed event-name schema, recompile-risk jit patterns,
  complete codec registrations, deterministic storage iteration, and
  value-naming error messages. Pure stdlib: importing (and running) it
  never pulls in jax, so the CI lint job finishes in seconds.

* :mod:`repro.analysis.compile_guard` — :class:`CompileGuard`, the ONE
  recompile-detection implementation: snapshots every jitted-entry
  cache size on an engine, asserts declared budgets, and (attached to
  the engine's event bus) can raise the moment a ``jit_trace`` retrace
  event fires outside a declared warmup phase.
"""
from repro.analysis.compile_guard import (
    CompileBudgetError, CompileGuard, count_recompiles)

__all__ = [
    "CompileBudgetError", "CompileGuard", "count_recompiles",
    "Finding", "lint_paths", "lint_source",
]

_LINT_NAMES = ("Finding", "lint_paths", "lint_source", "RULES")


def __getattr__(name):
    # Lazy so `python -m repro.analysis.lint` doesn't import the lint
    # module twice (package import + runpy execution -> RuntimeWarning).
    if name in _LINT_NAMES:
        from repro.analysis import lint
        return getattr(lint, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
