"""CompileGuard: the one recompile-detection implementation.

The static-decode-shape contract (PR 9) says the engine's jitted entry
points compile a *fixed* number of times — warmup traces them once per
shape family, and after that every register/rollout/retire/decode step
reuses a cached executable. Before this module, that contract was
checked three different ways: hand-rolled ``_cache_size()`` deltas in
``launch/serve.py --lifecycle``, ad-hoc ``assert eng._decode.
_cache_size() == 1`` lines in the lifecycle/scheduler tests, and a
bench-side recount for the ``tenant_lifecycle`` row's
``decode_recompiles == 0`` gate. CompileGuard replaces all three.

Two detection modes, composable:

* **Cache-size budgets** (always on): :meth:`snapshot` records every
  resolvable jitted entry's compile-cache size; :meth:`check` (also run
  by ``__exit__``) compares against declared ``budgets`` (max *total*
  sizes) and/or ``max_new`` (max *new* compiles since the last
  snapshot) and raises :class:`CompileBudgetError` naming the entry,
  the observed count, and the budget.

* **Event-bus strict mode** (``strict=True`` or :meth:`attach`): the
  guard registers as an EventBus consumer and watches ``jit_trace``
  events; a retrace (``first=False``) outside a declared
  :meth:`warmup` phase raises immediately at the emit site — the
  stack trace points at the call that retraced, not at teardown.

No jax import: entries are duck-typed via ``_cache_size()`` (the
AOT-cache introspection hook every jitted entry in this codebase
exposes), so the module stays importable from the lint/CI layer.

Usage::

    guard = CompileGuard(eng, budgets={"decode": 1})
    with guard:
        with guard.warmup():
            eng.step(); eng.step()      # traces allowed + re-baselined
        for _ in range(100):
            eng.step()                  # any decode retrace -> raises
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

__all__ = ["CompileBudgetError", "CompileGuard", "ENTRY_PATHS"]


class CompileBudgetError(RuntimeError):
    """A jitted entry compiled more than its declared budget allows."""


# Attribute chains from an engine to each guarded jitted entry. Entries
# that don't resolve on a given engine (e.g. no residency tier, table
# mode off) are simply skipped; lazily-built ones baseline at 0.
ENTRY_PATHS: Dict[str, Tuple[str, ...]] = {
    "decode": ("_decode",),
    "prefill": ("_prefill",),
    "decode_masked": ("_decode_masked",),
    "combined": ("_combined",),
    "promote": ("residency", "_promote"),
    "table_write": ("_table", "_write_jit"),
}


def _resolve(engine: Any, chain: Tuple[str, ...]) -> Optional[Any]:
    obj = engine
    for attr in chain:
        obj = getattr(obj, attr, None)
        if obj is None:
            return None
    return obj if hasattr(obj, "_cache_size") else None


class CompileGuard:
    """Snapshot jitted-entry cache sizes and enforce compile budgets.

    Parameters
    ----------
    engine:
        Anything exposing the :data:`ENTRY_PATHS` attributes (a
        ``ServeEngine``; entries that don't resolve are skipped). May
        also expose ``.bus`` for strict mode.
    budgets:
        ``entry name -> max total cache size`` checked by
        :meth:`check` / ``__exit__``.
    max_new:
        ``entry name -> max NEW compiles since the last snapshot``.
        ``{"decode": 0}`` is the lifecycle drill's "hot path never
        retraces" gate.
    strict:
        Attach to ``engine.bus`` on ``__enter__`` and raise the moment
        a ``jit_trace`` retrace event (``first=False``) fires outside
        a :meth:`warmup` phase.
    label:
        Prefixed to error messages so multi-guard tests read cleanly.
    """

    def __init__(self, engine: Any, *,
                 budgets: Optional[Dict[str, int]] = None,
                 max_new: Optional[Dict[str, int]] = None,
                 strict: bool = False, label: str = "") -> None:
        self.engine = engine
        self.budgets = dict(budgets or {})
        self.max_new = dict(max_new or {})
        self.strict = strict
        self.label = label
        self._baseline: Dict[str, int] = {}
        self._in_warmup = False
        self._attached = False
        self._retrace_events: List[Any] = []
        unknown = sorted((set(self.budgets) | set(self.max_new))
                         - set(ENTRY_PATHS))
        if unknown:
            raise ValueError(
                f"unknown CompileGuard entries {unknown}; known entries are "
                f"{sorted(ENTRY_PATHS)}")
        self.snapshot()

    # -- introspection ----------------------------------------------------
    def entries(self) -> Dict[str, Any]:
        """Resolvable jitted entries on this engine, by name."""
        out = {}
        for name, chain in ENTRY_PATHS.items():
            fn = _resolve(self.engine, chain)
            if fn is not None:
                out[name] = fn
        return out

    def sizes(self) -> Dict[str, int]:
        """Current compile-cache size per resolvable entry."""
        return {name: int(fn._cache_size())
                for name, fn in self.entries().items()}

    def snapshot(self) -> Dict[str, int]:
        """Re-baseline: subsequent :meth:`new_compiles` counts from here."""
        self._baseline = self.sizes()
        return dict(self._baseline)

    def new_compiles(self, name: str) -> int:
        """Compiles of ``name`` since the last :meth:`snapshot` (0 for
        entries that didn't exist at baseline and still don't)."""
        return self.sizes().get(name, 0) - self._baseline.get(name, 0)

    def report(self) -> Dict[str, Dict[str, int]]:
        """``{entry: {"total": n, "new": m}}`` for every live entry."""
        return {name: {"total": total,
                       "new": total - self._baseline.get(name, 0)}
                for name, total in self.sizes().items()}

    # -- event-bus strict mode --------------------------------------------
    def attach(self) -> "CompileGuard":
        """Register as an EventBus consumer on ``engine.bus``."""
        bus = getattr(self.engine, "bus", None)
        if bus is None:
            raise ValueError(
                f"{self._tag}engine {type(self.engine).__name__} has no "
                ".bus — strict mode needs the serve EventBus")
        if not self._attached:
            bus.attach(self)       # EventBus duck-types consume(ev) on us
            self._attached = True
        return self

    def detach(self) -> None:
        bus = getattr(self.engine, "bus", None)
        if bus is not None and self._attached:
            consumers = getattr(bus, "consumers", None)
            if consumers is not None and self in consumers:
                consumers.remove(self)
        self._attached = False

    def consume(self, ev: Any) -> None:
        """EventBus consumer: record ``jit_trace`` retraces; in strict
        mode, raise at the emit site unless inside :meth:`warmup`."""
        if getattr(ev, "kind", None) != "jit_trace":
            return
        attrs = getattr(ev, "attrs", None) or {}
        if attrs.get("first", True):
            return
        self._retrace_events.append(ev)
        if self.strict and not self._in_warmup:
            raise CompileBudgetError(
                f"{self._tag}jit retrace outside warmup: "
                f"path={attrs.get('path', '?')!r} "
                f"sig={attrs.get('sig', '?')!r} — the static-decode-shape "
                "contract says hot-path shapes never change; find the "
                "dynamic extent in this stack")

    @property
    def retraces(self) -> List[Any]:
        """``jit_trace`` retrace events observed while attached."""
        return list(self._retrace_events)

    @contextmanager
    def warmup(self) -> Iterator["CompileGuard"]:
        """Suspend strict-mode raising; re-:meth:`snapshot` on exit so
        warmup traces don't count against ``max_new``."""
        prev = self._in_warmup
        self._in_warmup = True
        try:
            yield self
        finally:
            self._in_warmup = prev
            if not prev:
                self._retrace_events.clear()
                self.snapshot()

    # -- budget enforcement -----------------------------------------------
    @property
    def _tag(self) -> str:
        return f"[{self.label}] " if self.label else ""

    def check(self) -> Dict[str, Dict[str, int]]:
        """Enforce ``budgets`` / ``max_new``; returns :meth:`report`."""
        rep = self.report()
        problems: List[str] = []
        for name, budget in sorted(self.budgets.items()):
            total = rep.get(name, {}).get("total", 0)
            if total > budget:
                problems.append(
                    f"entry {name!r} compiled {total} time(s), budget "
                    f"{budget}")
        for name, budget in sorted(self.max_new.items()):
            new = rep.get(name, {}).get("new", 0)
            if new > budget:
                problems.append(
                    f"entry {name!r} recompiled {new} time(s) since "
                    f"baseline, budget {budget}")
        if problems:
            raise CompileBudgetError(
                f"{self._tag}compile budget exceeded: "
                + "; ".join(problems)
                + f" (full report: {rep})")
        return rep

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "CompileGuard":
        self.snapshot()
        if self.strict:
            self.attach()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        self.detach()
        if exc_type is None:
            self.check()


def count_recompiles(engine: Any, run: Callable[[], Any], *,
                     entry: str = "decode") -> int:
    """Run ``run()`` and return how many times ``entry`` recompiled —
    the drop-in replacement for hand-rolled before/after
    ``_cache_size()`` arithmetic."""
    guard = CompileGuard(engine)
    run()
    return guard.new_compiles(entry)
