"""deltalint: contract-checking static analysis for this repository.

Generic style is ruff's job (pyproject ``[tool.ruff]``); these rules
encode the *domain* contracts that keep DeltaDQ serving token-identical
and deterministic — each one exists because its bug family has already
cost a PR's worth of debugging:

========  ==============================================================
DL001     No ``einsum`` / ``dot_general`` / ``jnp.dot`` family calls in
          the bit-identity correction paths (``kernels/fallback.py``,
          ``core/apply.py``). XLA's dot reduction order varies with the
          batch extent; the elementwise multiply + axis-``sum``
          formulation does not, and the token-identity contract (mixed
          batch == per-tenant reference, exact) rides on it. The
          audited MoE einsum sites carry an explicit escape hatch.
DL002     No ``hash()`` / ``time.time`` / process-global numpy RNG in
          ``core/`` + ``serve/``. ``hash()`` is PYTHONHASHSEED-
          randomized (the PR 5 compression-seed bug: zlib.crc32 is the
          sanctioned replacement); engine time must come from the
          injectable clock (VirtualClock determinism), and randomness
          must be explicitly seeded to keep compression bit-
          reproducible across processes.
DL003     No bare ``assert`` in runtime ``src/repro`` paths — ``python
          -O`` strips asserts, silently disabling the check (the PR 9
          ``kv.py`` fix, generalized). Raise a typed exception naming
          the offending values instead; genuinely-internal invariants
          inside jit-traced bodies may stay asserts behind the escape
          hatch.
DL004     Every ``bus.emit("<name>", ...)`` event name must appear in
          ``serve/trace.py``'s ``EVENT_SCHEMA`` and vice-versa — the
          static twin of the runtime trace validator. A typo'd event
          name silently drops metrics/trace/SLO accounting; an
          unde-emitted schema entry is dead documentation.
DL005     Recompile-risk jit patterns: ``jax.jit`` built inside a loop
          (fresh cache every iteration) or immediately invoked
          (``jax.jit(f)(x)`` — compiles every call). Decode-step jits
          must be built once and reused; CompileGuard enforces the
          runtime half of this contract.
DL006     A class registered via ``register_codec`` must implement the
          full DeltaCodec protocol surface — a partial codec fails at
          serving time deep inside pack/apply instead of at
          registration.
DL007     Deterministic storage paths (``core/pack.py``,
          ``core/codecs.py``): no mutable default arguments, no
          iteration over ``set`` literals/calls (string hashing is
          PYTHONHASHSEED-dependent, so iteration order is not
          reproducible across processes — sort first).
DL008     Public ``serve/`` functions raising on user input must name
          the offending value in the message (f-string / ``.format`` /
          ``%`` — the PR 6 ``record_shard_token`` convention): "bad
          value" without the value turns a one-look diagnosis into a
          debugging session.
========  ==============================================================

Escape hatch: ``# deltalint: allow[DL001] <reason>`` on the offending
line (or alone on the line above it) suppresses that rule there; the
reason is mandatory (an allow without one is reported as DL000). Rules
may be comma-separated: ``allow[DL003,DL005] <reason>``.

CLI::

    python -m repro.analysis.lint src/repro [--json findings.json]

Exits 0 when clean, 1 when any finding survives. Pure stdlib — no jax
import — so the CI lint job runs in seconds, before the test matrix.
"""
from __future__ import annotations

import ast
import json
import re
import sys
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "RULES", "lint_paths", "lint_source", "main"]


# ---------------------------------------------------------------------------
# Findings + per-file context
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str          # display path (as given on the CLI / virtual rel)
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


RULES: Dict[str, str] = {
    "DL000": "deltalint allow-comment without a reason",
    "DL001": "dot-family reduction in a bit-identity correction path",
    "DL002": "process-seeded randomness / wall clock in core+serve",
    "DL003": "bare assert in a runtime path (stripped by python -O)",
    "DL004": "bus.emit event name not in the trace EVENT_SCHEMA (or unused schema entry)",
    "DL005": "recompile-risk jax.jit pattern (jit in a loop / immediately invoked)",
    "DL006": "register_codec class missing part of the DeltaCodec protocol",
    "DL007": "non-deterministic storage-path construct (mutable default / set iteration)",
    "DL008": "public serve/ raise does not name the offending value",
}

_ALLOW_RE = re.compile(
    r"#\s*deltalint:\s*allow\[([A-Za-z0-9,\s]+)\]\s*(.*?)\s*$")


class _FileCtx:
    """Parsed file + allow-comment map + collected cross-file facts."""

    def __init__(self, display: str, rel: str, source: str):
        self.display = display
        self.rel = rel                     # normalized "repro/..." posix path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=display)
        self.findings: List[Finding] = []
        # line -> set of allowed rule ids ("*" = all)
        self.allows: Dict[int, set] = {}
        # cross-file facts for DL004
        self.emit_sites: List[Tuple[str, int, int]] = []   # (name, line, col)
        self.event_schema: Optional[Dict[str, int]] = None  # name -> line
        self._scan_allows()

    def _scan_allows(self) -> None:
        for i, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            if not m.group(2):
                self.findings.append(Finding(
                    "DL000", self.display, i, text.index("#"),
                    "allow[...] needs a reason: say WHY this site is "
                    "exempt (audited, traced-body invariant, ...)"))
            target = i
            if text.lstrip().startswith("#"):
                # comment-only line: the allow covers the next code line
                # (skipping blank lines and comment continuations)
                for j in range(i + 1, len(self.lines) + 1):
                    nxt = self.lines[j - 1].strip()
                    if nxt and not nxt.startswith("#"):
                        target = j
                        break
            self.allows.setdefault(target, set()).update(rules)

    def allowed(self, rule: str, line: int) -> bool:
        got = self.allows.get(line, ())
        return rule in got or "*" in got

    def add(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        if self.allowed(rule, line):
            return
        self.findings.append(Finding(
            rule, self.display, line, getattr(node, "col_offset", 0), message))


def _rel_of(path: Path) -> str:
    """Normalize to a 'repro/...' posix path for rule scoping (falls back
    to the basename for files outside a repro package)."""
    parts = path.resolve().parts
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i:])
    return path.name


def _chain(node: ast.AST) -> Optional[str]:
    """Dotted attribute chain as a string ('jnp.dot'), None if dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _in_scope(rel: str, prefixes: Sequence[str]) -> bool:
    return any(rel == p or rel.startswith(p) for p in prefixes)


# ---------------------------------------------------------------------------
# DL001 — dot-family reductions in bit-identity paths
# ---------------------------------------------------------------------------
_DL001_FILES = ("repro/kernels/fallback.py", "repro/core/apply.py")
_DOT_TAILS = {"einsum", "dot_general", "tensordot"}
_DOT_FNS = {"dot", "matmul", "vdot"}
_ARRAY_MODULES = {"jnp", "np", "jax", "numpy", "lax"}


def _rule_dl001(ctx: _FileCtx) -> None:
    if not _in_scope(ctx.rel, _DL001_FILES):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _chain(node.func)
        if chain is None:
            continue
        head, _, tail = chain.rpartition(".")
        name = tail or chain
        banned = name in _DOT_TAILS or (
            name in _DOT_FNS and head.split(".")[0] in _ARRAY_MODULES)
        if banned:
            ctx.add("DL001", node,
                    f"{chain}() in a bit-identity correction path: XLA dot "
                    "reduction order varies with the batch extent; use the "
                    "elementwise multiply + axis-sum formulation "
                    "(kernels/fallback.py module doc) or add an audited "
                    "allow[DL001] with a reason")


# ---------------------------------------------------------------------------
# DL002 — nondeterminism sources in core/ + serve/
# ---------------------------------------------------------------------------
_DL002_SCOPE = ("repro/core/", "repro/serve/")
_NP_GLOBAL_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "normal",
    "uniform", "choice", "shuffle", "permutation", "seed",
}


def _rule_dl002(ctx: _FileCtx) -> None:
    if not _in_scope(ctx.rel, _DL002_SCOPE):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id == "hash":
                ctx.add("DL002", node,
                        "hash() is PYTHONHASHSEED-randomized across "
                        "processes (the PR 5 compression-seed bug); use "
                        "zlib.crc32 for deterministic seeds")
                continue
            chain = _chain(node.func)
            if chain is None:
                continue
            if (chain.startswith("np.random.")
                    or chain.startswith("numpy.random.")):
                tail = chain.rpartition(".")[2]
                if tail in _NP_GLOBAL_RNG:
                    ctx.add("DL002", node,
                            f"{chain}() uses the process-global numpy RNG; "
                            "seed an explicit Generator "
                            "(np.random.default_rng(seed)) instead")
                elif tail in ("default_rng", "SeedSequence") and not (
                        node.args or node.keywords):
                    ctx.add("DL002", node,
                            f"{chain}() without a seed draws OS entropy — "
                            "compression/serving must be bit-reproducible; "
                            "pass an explicit seed")
        elif isinstance(node, ast.Attribute):
            if _chain(node) == "time.time":
                ctx.add("DL002", node,
                        "time.time reads the wall clock; engine code must "
                        "use the injectable clock (VirtualClock contract) — "
                        "launch/ timing loops live outside this scope")


# ---------------------------------------------------------------------------
# DL003 — bare asserts in runtime paths
# ---------------------------------------------------------------------------
def _rule_dl003(ctx: _FileCtx) -> None:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert):
            ctx.add("DL003", node,
                    "bare assert is stripped by python -O (the PR 9 kv.py "
                    "bug class); raise ValueError/RuntimeError naming the "
                    "offending values, or allow[DL003] a genuinely-internal "
                    "traced-body invariant with a reason")


# ---------------------------------------------------------------------------
# DL004 — bus.emit names <-> trace.py EVENT_SCHEMA
# ---------------------------------------------------------------------------
_TRACE_FILE = "repro/serve/trace.py"
_ENGINE_FILE = "repro/serve/engine.py"


def _collect_dl004(ctx: _FileCtx) -> None:
    """Per-file half: collect emit sites and (in trace.py) the schema."""
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "emit"):
            owner = node.func.value
            owner_chain = _chain(owner) or ""
            if not (owner_chain == "bus" or owner_chain.endswith(".bus")):
                continue
            if not node.args:
                continue
            first = node.args[0]
            if isinstance(first, ast.Constant) and isinstance(first.value, str):
                ctx.emit_sites.append((first.value, node.lineno,
                                       node.col_offset))
            elif (isinstance(first, ast.IfExp)
                  and isinstance(first.body, ast.Constant)
                  and isinstance(first.orelse, ast.Constant)):
                ctx.emit_sites.append((str(first.body.value), node.lineno,
                                       node.col_offset))
                ctx.emit_sites.append((str(first.orelse.value), node.lineno,
                                       node.col_offset))
            else:
                ctx.add("DL004", first,
                        "bus.emit event name must be a string literal (or a "
                        "literal conditional) so the schema cross-check can "
                        "see it")
    if ctx.rel == _TRACE_FILE:
        for node in ast.walk(ctx.tree):
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
                value = node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
                value = node.value
            else:
                continue
            names = [t.id for t in targets if isinstance(t, ast.Name)]
            if "EVENT_SCHEMA" in names and isinstance(value, ast.Dict):
                ctx.event_schema = {}
                for k in value.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        ctx.event_schema[k.value] = k.lineno


def _finish_dl004(ctxs: List[_FileCtx]) -> None:
    """Cross-file half: run once over all analyzed files."""
    schema_ctx = next((c for c in ctxs if c.event_schema is not None), None)
    if schema_ctx is None:
        return      # trace.py (or its schema) not in this lint scope
    schema = schema_ctx.event_schema or {}
    emitted: Dict[str, List[Tuple[_FileCtx, int, int]]] = {}
    for c in ctxs:
        for name, line, col in c.emit_sites:
            emitted.setdefault(name, []).append((c, line, col))
    for name, sites in sorted(emitted.items()):
        if name in schema:
            continue
        for c, line, col in sites:
            if not c.allowed("DL004", line):
                c.findings.append(Finding(
                    "DL004", c.display, line, col,
                    f"event {name!r} is not in serve/trace.py EVENT_SCHEMA "
                    f"(known: {sorted(schema)}); typo'd names silently drop "
                    "metrics/trace/SLO accounting"))
    # the reverse direction only means something when the emitting layer
    # is actually part of this lint run
    if any(c.rel == _ENGINE_FILE for c in ctxs):
        for name, line in sorted(schema.items()):
            if name not in emitted and not schema_ctx.allowed("DL004", line):
                schema_ctx.findings.append(Finding(
                    "DL004", schema_ctx.display, line, 0,
                    f"EVENT_SCHEMA entry {name!r} is never emitted by any "
                    "analyzed bus.emit site — dead schema documents events "
                    "that cannot happen"))


# ---------------------------------------------------------------------------
# DL005 — recompile-risk jit patterns
# ---------------------------------------------------------------------------
_DL005_EXCLUDE = ("repro/launch/",)
_JIT_CHAINS = {"jax.jit", "jax.pmap"}


def _is_jit_call(node: ast.AST, jit_names: set) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _chain(node.func)
    if chain in _JIT_CHAINS:
        return True
    return isinstance(node.func, ast.Name) and node.func.id in jit_names


def _rule_dl005(ctx: _FileCtx) -> None:
    if _in_scope(ctx.rel, _DL005_EXCLUDE):
        return
    jit_names = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "jax":
            for alias in node.names:
                if alias.name in ("jit", "pmap"):
                    jit_names.add(alias.asname or alias.name)

    class V(ast.NodeVisitor):
        def __init__(self) -> None:
            self.loop_depth = 0

        def _loop(self, node: ast.AST) -> None:
            self.loop_depth += 1
            self.generic_visit(node)
            self.loop_depth -= 1

        visit_For = visit_While = visit_AsyncFor = _loop

        def visit_Call(self, node: ast.Call) -> None:
            if _is_jit_call(node.func, jit_names):
                ctx.add("DL005", node,
                        "jax.jit(...)(...) immediately invoked: a fresh "
                        "compile cache per call — bind the jitted callable "
                        "once (engine __init__ pattern) and reuse it")
            elif _is_jit_call(node, jit_names) and self.loop_depth:
                ctx.add("DL005", node,
                        "jax.jit built inside a loop: each iteration gets "
                        "an empty cache, so every call recompiles — hoist "
                        "the jit out of the loop (or allow[DL005] a "
                        "deliberate benchmark/sweep site)")
            self.generic_visit(node)

    V().visit(ctx.tree)


# ---------------------------------------------------------------------------
# DL006 — register_codec protocol completeness
# ---------------------------------------------------------------------------
_CODEC_METHODS = {
    "compress_leaf", "reconstruct_dense", "runtime_packed", "storage_bits",
    "to_storage_parts", "from_storage_parts", "leaf_spec", "leaf_axes",
}
_CODEC_ATTRS = {"name", "spec_cls", "leaf_cls"}
_PROTOCOL_ROOTS = {"DeltaCodec"}    # bases whose stubs don't count


def _class_members(cls: ast.ClassDef) -> set:
    got = set()
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            got.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            got.update(t.id for t in stmt.targets if isinstance(t, ast.Name))
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                            ast.Name):
            if stmt.value is not None:
                got.add(stmt.target.id)
    return got


def _rule_dl006(ctx: _FileCtx) -> None:
    classes = {n.name: n for n in ast.walk(ctx.tree)
               if isinstance(n, ast.ClassDef)}
    registered: List[Tuple[ast.ClassDef, ast.Call]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _chain(node.func) or ""
        if chain.rpartition(".")[2] != "register_codec" or not node.args:
            continue
        arg = node.args[0]
        if (isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name)
                and arg.func.id in classes):
            registered.append((classes[arg.func.id], node))
    for cls, site in registered:
        members: set = set()
        seen = set()
        stack = [cls]
        while stack:
            c = stack.pop()
            if c.name in seen:
                continue
            seen.add(c.name)
            members |= _class_members(c)
            for b in c.bases:
                bname = _chain(b) or ""
                bname = bname.rpartition(".")[2]
                if bname in classes and bname not in _PROTOCOL_ROOTS:
                    stack.append(classes[bname])
        missing = sorted((_CODEC_METHODS | _CODEC_ATTRS) - members)
        if missing:
            ctx.add("DL006", cls,
                    f"codec class {cls.name} (registered at line "
                    f"{site.lineno}) is missing DeltaCodec protocol "
                    f"members: {missing} — a partial codec fails at "
                    "serving time instead of at registration")


# ---------------------------------------------------------------------------
# DL007 — deterministic storage paths
# ---------------------------------------------------------------------------
_DL007_FILES = ("repro/core/pack.py", "repro/core/codecs.py")
_MUTABLE_CTORS = {"list", "dict", "set"}


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in _MUTABLE_CTORS)


def _rule_dl007(ctx: _FileCtx) -> None:
    if not _in_scope(ctx.rel, _DL007_FILES):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if _is_mutable_default(d):
                    ctx.add("DL007", d,
                            "mutable default argument is shared across "
                            "calls — storage-layer state must not leak "
                            "between leaves; default to None and build "
                            "inside")
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            it = node.iter
            is_set = isinstance(it, ast.Set) or (
                isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id == "set")
            if is_set:
                ctx.add("DL007", it,
                        "iterating a set: order is PYTHONHASHSEED-dependent "
                        "for str keys, so pytree/storage layouts would vary "
                        "across processes — iterate sorted(...) instead")


# ---------------------------------------------------------------------------
# DL008 — value-naming raise messages in public serve/ functions
# ---------------------------------------------------------------------------
_DL008_SCOPE = ("repro/serve/",)
_EXC_NAMES = {"ValueError", "TypeError", "KeyError", "RuntimeError",
              "IndexError"}


def _is_static_string(node: ast.AST) -> bool:
    """True when the expression can only ever produce one fixed string."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str)
    if isinstance(node, ast.JoinedStr):
        return not any(isinstance(v, ast.FormattedValue) for v in node.values)
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add, ast.Mod)):
        return _is_static_string(node.left) and _is_static_string(node.right)
    return False


def _public(name: str) -> bool:
    return not name.startswith("_") or (
        name.startswith("__") and name.endswith("__"))


def _rule_dl008(ctx: _FileCtx) -> None:
    if not _in_scope(ctx.rel, _DL008_SCOPE):
        return

    class V(ast.NodeVisitor):
        def __init__(self) -> None:
            self.fn_stack: List[str] = []

        def _fn(self, node: ast.AST) -> None:
            self.fn_stack.append(node.name)
            self.generic_visit(node)
            self.fn_stack.pop()

        visit_FunctionDef = visit_AsyncFunctionDef = _fn

        def visit_Raise(self, node: ast.Raise) -> None:
            self.generic_visit(node)
            if not self.fn_stack or not _public(self.fn_stack[-1]):
                return
            exc = node.exc
            if not isinstance(exc, ast.Call):
                return
            name = (_chain(exc.func) or "").rpartition(".")[2]
            if name not in _EXC_NAMES:
                return
            if not exc.args or _is_static_string(exc.args[0]):
                ctx.add("DL008", node,
                        f"{name} raised from public "
                        f"{'.'.join(self.fn_stack)}() must NAME the "
                        "offending value in its message (f-string the "
                        "value in, per the record_shard_token convention) "
                        "— 'bad value' without the value is a debugging "
                        "session, not a diagnosis")

    V().visit(ctx.tree)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
_PER_FILE_RULES = (_rule_dl001, _rule_dl002, _rule_dl003, _rule_dl005,
                   _rule_dl006, _rule_dl007, _rule_dl008)


def lint_source(source: str, rel: str, display: Optional[str] = None
                ) -> List[Finding]:
    """Lint one in-memory source blob. ``rel`` is the virtual
    'repro/...'-style path used for rule scoping (fixture tests use
    this to place snippets inside any rule's jurisdiction)."""
    ctx = _FileCtx(display or rel, rel, source)
    for rule in _PER_FILE_RULES:
        rule(ctx)
    _collect_dl004(ctx)
    _finish_dl004([ctx])
    return ctx.findings


def _iter_py(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        else:
            out.append(path)
    return out


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint files/directories; runs the cross-file DL004 check over the
    whole set. Returns findings sorted by (path, line)."""
    ctxs: List[_FileCtx] = []
    findings: List[Finding] = []
    for path in _iter_py(paths):
        try:
            source = path.read_text()
            ctx = _FileCtx(str(path), _rel_of(path), source)
        except (OSError, SyntaxError) as e:
            findings.append(Finding("DL000", str(path), 1, 0,
                                    f"cannot lint: {e}"))
            continue
        for rule in _PER_FILE_RULES:
            rule(ctx)
        _collect_dl004(ctx)
        ctxs.append(ctx)
    _finish_dl004(ctxs)
    for ctx in ctxs:
        findings.extend(ctx.findings)
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="deltalint: contract-checking static analysis "
                    "(identity/determinism invariants)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories (default: src/repro)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write a machine-readable findings report")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, summary in sorted(RULES.items()):
            print(f"{rule}  {summary}")
        return 0
    findings = lint_paths(args.paths or ["src/repro"])
    n_files = len(_iter_py(args.paths or ["src/repro"]))
    if args.json:
        counts: Dict[str, int] = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        report = {"version": 1, "files": n_files,
                  "findings": [asdict(f) for f in findings],
                  "counts": counts}
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
    for f in findings:
        print(f.format())
    if findings:
        print(f"deltalint: {len(findings)} finding(s) in {n_files} file(s)",
              file=sys.stderr)
        return 1
    print(f"deltalint: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
