"""Pytree path utilities shared across the framework.

Params, deltas, shardings and checkpoints all address leaves by a
"/"-joined path string, e.g. ``"blocks/attn/wq"``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def flatten_with_paths(tree: Any, is_leaf: Callable | None = None) -> dict[str, Any]:
    """Flatten a pytree into {path: leaf}."""
    leaves = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    return {path_str(p): v for p, v in leaves}


def map_with_paths(fn: Callable[[str, Any], Any], tree: Any, *rest: Any, is_leaf=None) -> Any:
    """tree_map where fn also receives the path string of each leaf."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x, *r: fn(path_str(p), x, *r), tree, *rest, is_leaf=is_leaf
    )


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total element count of all array leaves."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape))
    return total
