from repro.utils.pytree import (
    flatten_with_paths,
    map_with_paths,
    path_str,
    tree_bytes,
    tree_params,
)

__all__ = [
    "flatten_with_paths",
    "map_with_paths",
    "path_str",
    "tree_bytes",
    "tree_params",
]
